//! Stub of the `xla` (PJRT) bindings used by the runtime layer.
//!
//! The offline build environment has no libxla/PJRT shared library, so this
//! crate keeps the coordinator compiling and testable while gating artifact
//! *execution* behind a runtime error: [`PjRtClient::cpu`] (the first call
//! on every execution path) fails with a clear message, and the integration
//! tests skip gracefully because `artifacts/` is never built here. The
//! [`Literal`] container is implemented for real — shape/dtype bookkeeping,
//! reshape validation, tuple access — so host-side plumbing stays honest.

use std::fmt;

/// Stub error type (also what the real bindings' fallible calls produce).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT runtime unavailable: this build uses the offline `xla` stub crate \
     (no libxla). Simulator, collectives, and analytic training paths are \
     unaffected; AOT artifact execution needs the real bindings";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Sized + Copy {
    fn to_literal(v: &[Self], dims: Vec<i64>) -> Literal;
    fn from_literal(l: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_literal(v: &[Self], dims: Vec<i64>) -> Literal {
        Literal::F32 { values: v.to_vec(), dims }
    }

    fn from_literal(l: &Literal) -> Result<Vec<Self>> {
        match l {
            Literal::F32 { values, .. } => Ok(values.clone()),
            other => Err(Error(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn to_literal(v: &[Self], dims: Vec<i64>) -> Literal {
        Literal::I32 { values: v.to_vec(), dims }
    }

    fn from_literal(l: &Literal) -> Result<Vec<Self>> {
        match l {
            Literal::I32 { values, .. } => Ok(values.clone()),
            other => Err(Error(format!("literal is not i32: {other:?}"))),
        }
    }
}

/// A host-side tensor (or tuple of tensors).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { values: Vec<f32>, dims: Vec<i64> },
    I32 { values: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::to_literal(v, vec![v.len() as i64])
    }

    /// Element count (sum over tuple members).
    pub fn element_count(&self) -> usize {
        match self {
            Literal::F32 { values, .. } => values.len(),
            Literal::I32 { values, .. } => values.len(),
            Literal::Tuple(ts) => ts.iter().map(Literal::element_count).sum(),
        }
    }

    /// Reshape to `dims` (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        match self {
            Literal::F32 { values, .. } => {
                if values.len() as i64 != want {
                    return Err(Error(format!(
                        "reshape {} elements to {dims:?}",
                        values.len()
                    )));
                }
                Ok(Literal::F32 { values: values.clone(), dims: dims.to_vec() })
            }
            Literal::I32 { values, .. } => {
                if values.len() as i64 != want {
                    return Err(Error(format!(
                        "reshape {} elements to {dims:?}",
                        values.len()
                    )));
                }
                Ok(Literal::I32 { values: values.clone(), dims: dims.to_vec() })
            }
            Literal::Tuple(_) => Err(Error("cannot reshape a tuple".to_string())),
        }
    }

    /// Flatten to a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_literal(self)
    }

    /// Single-element tuple access (non-tuples pass through, matching the
    /// bindings' tolerance for unwrapped single outputs).
    pub fn to_tuple1(&self) -> Result<Literal> {
        match self {
            Literal::Tuple(ts) if ts.len() == 1 => Ok(ts[0].clone()),
            Literal::Tuple(ts) => Err(Error(format!("expected 1-tuple, got {}-tuple", ts.len()))),
            other => Ok(other.clone()),
        }
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        match self {
            Literal::Tuple(ts) if ts.len() == 2 => Ok((ts[0].clone(), ts[1].clone())),
            other => Err(Error(format!("expected 2-tuple, got {other:?}"))),
        }
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        match self {
            Literal::Tuple(ts) if ts.len() == 3 => {
                Ok((ts[0].clone(), ts[1].clone(), ts[2].clone()))
            }
            other => Err(Error(format!("expected 3-tuple, got {other:?}"))),
        }
    }
}

impl From<f32> for Literal {
    fn from(x: f32) -> Literal {
        Literal::F32 { values: vec![x], dims: Vec::new() }
    }
}

/// Parsed HLO module (never constructible in the stub: parsing requires the
/// real bindings, and nothing downstream can run without it).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping a parsed HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (construction fails in the stub).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Compiled executable handle (unreachable in the stub: no client exists).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        let i = Literal::vec1(&[1i32, 2]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
        let s = Literal::from(0.5f32);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn tuples() {
        let t = Literal::Tuple(vec![Literal::from(1.0), Literal::from(2.0)]);
        let (a, b) = t.to_tuple2().unwrap();
        assert_eq!(a.to_vec::<f32>().unwrap(), vec![1.0]);
        assert_eq!(b.to_vec::<f32>().unwrap(), vec![2.0]);
        assert!(t.to_tuple3().is_err());
        // Non-tuple passes through to_tuple1.
        assert_eq!(Literal::from(3.0).to_tuple1().unwrap(), Literal::from(3.0));
    }

    #[test]
    fn execution_is_gated() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
