//! Minimal vendored reimplementation of the `anyhow` API surface this
//! workspace uses (the build environment is offline, so the real crate is
//! unavailable). Provides [`Error`], [`Result`], the [`Context`] extension
//! trait for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Errors are flattened to a message chain — no backtraces, no
//! downcasting — which is all the coordinator needs for its diagnostics.

use std::fmt;

/// A flattened error: the full context chain rendered into one message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (`context: original`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, so this blanket conversion stays coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: c.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($e:expr $(,)?) => {
        $crate::Error::msg($e)
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/9f2c").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_and_converts() {
        let e = io_fail().unwrap_err();
        let text = format!("{e}");
        assert!(text.starts_with("reading config: "), "{text}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        let ok: Option<u32> = Some(3);
        assert_eq!(ok.with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too large: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too large: 101");
        let e = anyhow!("plain {}", 7);
        assert_eq!(format!("{e:?}"), "plain 7");
        let from_string = anyhow!(String::from("already built"));
        assert_eq!(format!("{from_string}"), "already built");
    }
}
