//! Quickstart: train a small classifier with WAGMA-SGD on 4 in-process
//! workers through the full three-layer stack (Rust coordinator → AOT HLO
//! artifact → Pallas kernels).
//!
//! Build artifacts first: `make artifacts`
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use wagma::optim::engine::EngineFactory;
use wagma::optim::pjrt_engine::PjrtEngine;
use wagma::optim::{run_training, Algorithm, TrainConfig};
use wagma::runtime::ModelRuntime;

fn main() -> anyhow::Result<()> {
    let model = "mlp_tiny";
    let rt = ModelRuntime::load("artifacts", model)?;
    println!(
        "loaded {model}: {} params, batch {}, kind {}",
        rt.meta.param_count, rt.meta.batch, rt.meta.kind
    );
    let init = rt.init_params()?;
    let batch = rt.meta.batch;
    drop(rt);

    let factory: EngineFactory =
        Arc::new(|rank| Box::new(PjrtEngine::new("artifacts", "mlp_tiny", rank, 42).unwrap()));

    let cfg = TrainConfig {
        algo: Algorithm::Wagma,
        p: 4,
        steps: 120,
        lr: 0.05,
        tau: 10,       // global model sync every 10 iterations
        group_size: 2, // √P
        eval_every: 20,
        init,
        ..Default::default()
    };
    println!(
        "training with WAGMA-SGD: P={}, S={}, tau={} ...",
        cfg.p,
        cfg.resolved_group_size(),
        cfg.tau
    );
    let r = run_training(&cfg, factory);

    println!("\naccuracy over training:");
    for (step, acc) in r.eval_curve() {
        println!("  step {step:>4}: {:.1}%", acc * 100.0);
    }
    println!(
        "\ndone in {:.1}s — {:.0} samples/s, mean staleness {:.2}, final divergence {:.1e}",
        r.wall_seconds,
        r.throughput(batch),
        r.mean_staleness(),
        r.model_divergence()
    );
    let final_acc = r.eval_curve().last().map(|(_, a)| *a).unwrap_or(0.0);
    anyhow::ensure!(final_acc > 0.6, "training failed to reach 60% accuracy");
    println!("quickstart OK");
    Ok(())
}
