//! At-scale simulation example: the paper's headline Fig. 10 numbers at
//! P=1,024 (where no amount of laptop hardware would do), via the
//! discrete-event simulator.
//!
//! Run: `cargo run --release --example simulate_scale -- [--p 1024]`

use wagma::config::preset;
use wagma::optim::Algorithm;
use wagma::simulator::simulate;
use wagma::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let p = args.usize_or("p", 1024);
    let pre = preset("fig10").unwrap();
    println!("Fig. 10 at P={p}: {}", pre.description);
    println!(
        "{:<14} {:>16} {:>16} {:>8} {:>12}",
        "algorithm", "exp-steps/s", "ideal/s", "eff%", "mean skew"
    );
    let mut wagma_thr = 0.0;
    let mut rows = Vec::new();
    for &algo in pre.algos {
        let r = simulate(&pre.sim_config(algo, p, 42));
        let thr = r.throughput(pre.batch);
        if algo == Algorithm::Wagma {
            wagma_thr = thr;
        }
        rows.push((algo, thr));
        println!(
            "{:<14} {:>16.0} {:>16.0} {:>7.1}% {:>11.2}s",
            algo.name(),
            thr,
            r.ideal_throughput(pre.batch),
            100.0 * thr / r.ideal_throughput(pre.batch),
            r.mean_skew
        );
    }
    println!("\nWAGMA speedups (paper at 1,024 GPUs: 2.33x local, 1.88x dpsgd, 2.10x sgp):");
    for (algo, thr) in rows {
        if algo != Algorithm::Wagma {
            println!("  vs {:<12} {:>5.2}x", algo.name(), wagma_thr / thr);
        }
    }
    Ok(())
}
