//! End-to-end driver (DESIGN.md §4, EXPERIMENTS.md §E2E): train the
//! transformer LM artifact across 4 workers with WAGMA-SGD on a synthetic
//! Markov/Zipf corpus with WMT-style bucketed-length imbalance, for a few
//! hundred steps, and log the loss curve.
//!
//! This exercises every layer: Pallas optimizer kernel (L1) inside the AOT
//! step artifact (L2), driven by the wait-avoiding group-averaging
//! coordinator (L3) with real passive/stale participation under injected
//! imbalance.
//!
//! Run: `cargo run --release --example train_transformer -- [--model lm_small]
//!       [--steps 300] [--p 4] [--algo wagma] [--out results]`

use std::sync::Arc;

use wagma::data::ImbalanceModel;
use wagma::figures::TIME_SCALE;
use wagma::metrics::CsvWriter;
use wagma::optim::engine::EngineFactory;
use wagma::optim::pjrt_engine::PjrtEngine;
use wagma::optim::{run_training, Algorithm, SleepEngine, TrainConfig};
use wagma::runtime::ModelRuntime;
use wagma::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model: &'static str = Box::leak(args.str_or("model", "lm_small").into_boxed_str());
    let p = args.usize_or("p", 4);
    let steps = args.u64_or("steps", 300);
    let algo: Algorithm =
        args.str_or("algo", "wagma").parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let out = args.str_or("out", "results");

    let rt = ModelRuntime::load("artifacts", model)?;
    println!(
        "end-to-end driver: {model} ({} params, vocab {}, seq {}), {} on P={p}, {steps} steps",
        rt.meta.param_count,
        rt.meta.dims["vocab"],
        rt.meta.dims["seq_len"],
        algo.name()
    );
    let init = rt.init_params()?;
    let tokens_per_step = rt.meta.batch * rt.meta.dims["seq_len"];
    drop(rt);

    // WMT-style bucketed-length compute imbalance, scaled for wall-clock.
    let schedule =
        SleepEngine::<PjrtEngine>::schedule(ImbalanceModel::fig7(), p, steps as usize, 42);
    let factory: EngineFactory = {
        let schedule = schedule.clone();
        Arc::new(move |rank| {
            let eng = PjrtEngine::new("artifacts", model, rank, 42).expect("load engine");
            Box::new(SleepEngine::new(eng, rank, schedule.clone(), TIME_SCALE))
        })
    };

    let cfg = TrainConfig {
        algo,
        p,
        steps,
        lr: args.f64_or("lr", 0.1) as f32,
        tau: 8, // the paper's Transformer setting
        eval_every: (steps / 25).max(1),
        init,
        ..Default::default()
    };
    let r = run_training(&cfg, factory);

    std::fs::create_dir_all(&out)?;
    let csv_path = std::path::Path::new(&out).join(format!("e2e_{}_{}.csv", algo.name(), model));
    let mut csv = CsvWriter::create(&csv_path, &["step", "train_loss", "eval_loss"])?;
    let evals = r.eval_curve();
    println!("\nloss curve (train / held-out eval):");
    let losses = r.loss_curve();
    for (step, eval_loss) in &evals {
        let train_loss = losses.get(*step as usize).map(|(_, l)| *l).unwrap_or(f32::NAN);
        println!("  step {step:>5}: train {train_loss:.4}  eval {eval_loss:.4}");
        csv.row(&[step.to_string(), format!("{train_loss}"), format!("{eval_loss}")])?;
    }
    let first = losses[0].1;
    let last = losses.last().unwrap().1;
    println!(
        "\ndone in {:.1}s — {:.0} tokens/s, loss {first:.3} → {last:.3}, \
         mean staleness {:.2}, divergence {:.2e}",
        r.wall_seconds,
        r.throughput(tokens_per_step),
        r.mean_staleness(),
        r.model_divergence()
    );
    println!("wrote {csv_path:?}");
    anyhow::ensure!(last < first * 0.8, "loss did not drop ≥20%: {first} -> {last}");
    println!("train_transformer OK");
    Ok(())
}
