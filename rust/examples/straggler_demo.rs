//! Fig. 3 as an executable: P=4, S=2, rank 1 is a persistent straggler.
//! Prints the per-iteration timeline showing fresh vs. stale (passive)
//! contributions and the τ-sync catch-up — the execution snapshot from
//! the paper, live.
//!
//! Run: `cargo run --release --example straggler_demo`

use std::sync::mpsc::channel;
use std::thread;
use std::time::Duration;

use wagma::collectives::allreduce::AllreduceAlgo;
use wagma::collectives::engine::{ActivationMode, CollectiveEngine, EngineConfig};
use wagma::comm::world;
use wagma::compress::Compression;

fn main() {
    let p = 4;
    let tau = 4u64;
    let steps = 12u64;
    let cfg = EngineConfig {
        p,
        group_size: 2,
        tau,
        dynamic_groups: true,
        sync_algo: AllreduceAlgo::Auto,
        activation: ActivationMode::Solo,
        chunk_elems: 0,
        compression: Compression::None,
        trace: true,
        recv_deadline_ns: 0,
        recv_retries: 0,
    };
    println!("Fig. 3 demo: P=4, S=2, tau={tau}; rank 1 is the straggler\n");
    let (log_tx, log_rx) = channel::<(u64, usize, String)>();
    let engines: Vec<CollectiveEngine> = world(p)
        .into_iter()
        .map(|ep| CollectiveEngine::spawn(ep, cfg, vec![0.0]))
        .collect();
    let handles: Vec<_> = engines
        .into_iter()
        .map(|eng| {
            let log = log_tx.clone();
            thread::spawn(move || {
                let rank = eng.rank();
                let mut w = vec![rank as f32];
                for t in 0..steps {
                    if rank == 1 {
                        thread::sleep(Duration::from_millis(25)); // straggler
                    } else {
                        thread::sleep(Duration::from_millis(2));
                    }
                    w[0] += 1.0; // "local update" W'_t
                    eng.publish(&w, t);
                    if eng.config().is_sync_iter(t) {
                        let sum = eng.global_sync(t);
                        w = vec![sum[0] / p as f32];
                        log.send((t, rank, format!("GLOBAL SYNC  -> W={:.2}", w[0]))).unwrap();
                    } else {
                        let res = eng.group_allreduce(t);
                        if res.is_fresh(t) {
                            w = vec![res.sum[0] / 2.0];
                            log.send((t, rank, format!("fresh  W_sum/S      -> W={:.2}", w[0])))
                                .unwrap();
                        } else {
                            w = vec![(res.sum[0] + w[0]) / 3.0];
                            log.send((
                                t,
                                rank,
                                format!(
                                    "STALE (lag {})  (W_sum+W')/(S+1) -> W={:.2}",
                                    res.staleness(t),
                                    w[0]
                                ),
                            ))
                            .unwrap();
                        }
                    }
                }
                eng.shutdown()
            })
        })
        .collect();
    drop(log_tx);

    let mut events: Vec<(u64, usize, String)> = log_rx.iter().collect();
    events.sort();
    let mut last_t = u64::MAX;
    for (t, rank, msg) in events {
        if t != last_t {
            println!("--- iteration {t} ---");
            last_t = t;
        }
        println!("  P{rank}: {msg}");
    }
    let mut passives = 0;
    for h in handles {
        passives += h.join().unwrap().passive_executions;
    }
    println!("\ntotal passive (engine-executed) collectives: {passives}");
    println!("straggler_demo OK");
}
