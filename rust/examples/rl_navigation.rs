//! RL example (Fig. 10/11 workload): distributed PPO on procedurally
//! generated gridworld navigation — the Habitat analogue — with WAGMA-SGD
//! absorbing the naturally heavy-tailed experience-collection times.
//!
//! Run: `cargo run --release --example rl_navigation -- [--iters 200]
//!       [--p 4] [--algo wagma]`

use std::sync::Arc;

use wagma::optim::engine::EngineFactory;
use wagma::optim::pjrt_engine::RlEngine;
use wagma::optim::{run_training, Algorithm, TrainConfig};
use wagma::runtime::ModelRuntime;
use wagma::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let p = args.usize_or("p", 4);
    let iters = args.u64_or("iters", 300);
    let algo: Algorithm =
        args.str_or("algo", "wagma").parse().map_err(|e: String| anyhow::anyhow!(e))?;

    let rt = ModelRuntime::load("artifacts", "policy_tiny")?;
    println!(
        "RL navigation: policy {} params, {} actions; {} on P={p}, {iters} PPO iterations",
        rt.meta.param_count,
        rt.meta.dims["actions"],
        algo.name()
    );
    let init = rt.init_params()?;
    let exp_per_iter = rt.meta.batch;
    drop(rt);

    let factory: EngineFactory = Arc::new(move |rank| {
        Box::new(RlEngine::new("artifacts", "policy_tiny", rank, 42).expect("load RL engine"))
    });

    let cfg = TrainConfig {
        algo,
        p,
        steps: iters,
        lr: args.f64_or("lr", 0.003) as f32,
        tau: 8, // the paper's RL setting
        eval_every: (iters / 20).max(1),
        init,
        ..Default::default()
    };
    let r = run_training(&cfg, factory);

    println!("\nmean episode return over training:");
    for (step, ret) in r.eval_curve() {
        println!("  iter {step:>4}: {ret:+.3}");
    }
    let curve = r.eval_curve();
    let early: f32 =
        curve.iter().take(3).map(|(_, v)| v).sum::<f32>() / curve.len().min(3).max(1) as f32;
    let late: f32 =
        curve.iter().rev().take(3).map(|(_, v)| v).sum::<f32>() / curve.len().min(3).max(1) as f32;
    println!(
        "\ndone in {:.1}s — {:.0} experience steps/s, return {early:+.3} → {late:+.3}",
        r.wall_seconds,
        r.throughput(exp_per_iter)
    );
    anyhow::ensure!(late > early, "policy did not improve: {early} -> {late}");
    println!("rl_navigation OK");
    Ok(())
}
