//! Wait-time attribution: decompose each rank's exposed communication
//! into wait-for-peer / codec / transfer components.
//!
//! The app-lane [`TraceKind::Wait`] spans are, by construction, exactly
//! the time each rank's application was blocked on a collective result —
//! its *exposed* communication. Attribution intersects the engine-lane
//! spans with those windows and splits the exposed time into:
//!
//! * **wait-for-peer** — engine blocked in a matched receive (the
//!   partner had not sent yet): skew, not network;
//! * **codec** — compression encode + decode time (the δ term of the
//!   compressed cost model);
//! * **transfer** — the remainder of exchange/sync span time: actual
//!   send/receive/reduce work. This is further priced into the network
//!   model's α (per-message latency) and β (per-byte bandwidth) shares
//!   using the recorded span/byte counts;
//! * **other** — exposed time not covered by any engine span (request
//!   routing, thread wakeup).
//!
//! The four components partition the exposed total exactly (each is an
//! intersection with the same windows, and sub-spans nest inside their
//! exchange spans), which is what makes the report trustworthy: a
//! regression must show up in a named component.
//!
//! The simulator emits the same schema from its analytic timeline, so
//! [`diff_json`] can compare a measured attribution against a simulated
//! one component by component.

use crate::simulator::NetworkModel;
use crate::util::json::{num, obj, Json};

use super::{Lane, TraceEvent, TraceKind};

/// Attribution report over one event stream (all ranks).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Attribution {
    /// Ranks contributing app-lane wait windows.
    pub ranks: usize,
    /// Total exposed communication: Σ app-lane `Wait` span durations (s).
    pub exposed_s: f64,
    /// Engine blocked on a peer inside the exposed windows (s).
    pub wait_for_peer_s: f64,
    /// Codec encode+decode inside the exposed windows (s).
    pub codec_s: f64,
    /// Exchange/sync span time inside the windows minus the two above (s).
    pub transfer_s: f64,
    /// Exposed time under no engine span at all (s).
    pub other_s: f64,
    /// Model-priced α (latency) share of `transfer_s`.
    pub alpha_model_s: f64,
    /// Model-priced β (bandwidth) share of `transfer_s`.
    pub beta_model_s: f64,
    /// Deterministic accounting: total butterfly-phase spans recorded.
    pub phase_spans: u64,
    /// Deterministic accounting: total every-τ sync spans recorded.
    pub tau_sync_spans: u64,
    /// Deterministic accounting: bytes-on-wire over all phase spans.
    pub phase_wire_bytes: u64,
    /// Deterministic accounting: bytes-on-wire over all sync spans.
    pub sync_wire_bytes: u64,
    /// Fault/degraded spans recorded ([`TraceKind::Fault`]): skipped
    /// butterfly phases, crash markers, simulator fault penalties. Side
    /// accounting — fault spans do NOT enter the four-way partition.
    pub fault_spans: u64,
    /// Total duration of those fault spans (s): time attributable to
    /// injected faults (deadlines burned on missing peers, modeled
    /// stall penalties).
    pub degraded_s: f64,
}

impl Attribution {
    /// Sum of the four components — equals `exposed_s` up to float
    /// rounding (the partition property the 5% acceptance bound checks).
    pub fn components_sum_s(&self) -> f64 {
        self.wait_for_peer_s + self.codec_s + self.transfer_s + self.other_s
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("ranks", num(self.ranks as f64)),
            ("exposed_s", num(self.exposed_s)),
            ("wait_for_peer_s", num(self.wait_for_peer_s)),
            ("codec_s", num(self.codec_s)),
            ("transfer_s", num(self.transfer_s)),
            ("other_s", num(self.other_s)),
            ("alpha_model_s", num(self.alpha_model_s)),
            ("beta_model_s", num(self.beta_model_s)),
            ("components_sum_s", num(self.components_sum_s())),
            ("fault_spans", num(self.fault_spans as f64)),
            ("degraded_s", num(self.degraded_s)),
        ])
    }

    /// Terminal-friendly report.
    pub fn report(&self, label: &str) -> String {
        let share = |x: f64| if self.exposed_s > 0.0 { 100.0 * x / self.exposed_s } else { 0.0 };
        let mut out = String::new();
        out.push_str(&format!(
            "wait-time attribution [{label}] — exposed comm {:.4} s over {} ranks\n",
            self.exposed_s, self.ranks
        ));
        out.push_str(&format!(
            "  wait-for-peer {:>9.4} s ({:5.1}%)\n",
            self.wait_for_peer_s,
            share(self.wait_for_peer_s)
        ));
        out.push_str(&format!(
            "  codec (delta) {:>9.4} s ({:5.1}%)\n",
            self.codec_s,
            share(self.codec_s)
        ));
        out.push_str(&format!(
            "  transfer      {:>9.4} s ({:5.1}%)  [model: alpha {:.2e} s / beta {:.2e} s]\n",
            self.transfer_s,
            share(self.transfer_s),
            self.alpha_model_s,
            self.beta_model_s
        ));
        out.push_str(&format!(
            "  other         {:>9.4} s ({:5.1}%)\n",
            self.other_s,
            share(self.other_s)
        ));
        if self.fault_spans > 0 {
            out.push_str(&format!(
                "  faults        {:>9.4} s degraded over {} fault spans (side accounting)\n",
                self.degraded_s, self.fault_spans
            ));
        }
        out
    }
}

/// Overlap of `[a0, a1)` with the union of disjoint sorted `windows`.
fn overlap_ns(windows: &[(u64, u64)], a0: u64, a1: u64) -> u64 {
    if a1 <= a0 {
        return 0;
    }
    // First window whose end is past the span start.
    let start = windows.partition_point(|&(_, e)| e <= a0);
    let mut total = 0u64;
    for &(w0, w1) in &windows[start..] {
        if w0 >= a1 {
            break;
        }
        total += a1.min(w1).saturating_sub(a0.max(w0));
    }
    total
}

/// Compute the attribution over an event stream. Works identically for
/// measured (wall-clock) and simulated (analytic) events — that is the
/// point: both producers share one schema.
pub fn attribute(events: &[TraceEvent], net: &NetworkModel) -> Attribution {
    let mut att = Attribution::default();
    let max_rank = events.iter().map(|e| e.rank).max().map_or(0, |r| r as usize + 1);
    let mut windows: Vec<Vec<(u64, u64)>> = vec![Vec::new(); max_rank];
    for ev in events {
        match (ev.lane, ev.kind) {
            (Lane::App, TraceKind::Wait) => {
                windows[ev.rank as usize].push((ev.t_ns, ev.end_ns()));
            }
            (Lane::Engine, TraceKind::GroupExchangePhase) => {
                att.phase_spans += 1;
                att.phase_wire_bytes += ev.bytes;
            }
            (Lane::Engine, TraceKind::TauSync) => {
                att.tau_sync_spans += 1;
                att.sync_wire_bytes += ev.bytes;
            }
            (_, TraceKind::Fault) => {
                att.fault_spans += 1;
                att.degraded_s += ev.dur_ns as f64 / 1e9;
            }
            _ => {}
        }
    }
    let mut exposed = 0u64;
    for w in &mut windows {
        w.sort_unstable();
        exposed += w.iter().map(|&(a, b)| b - a).sum::<u64>();
    }
    att.ranks = windows.iter().filter(|w| !w.is_empty()).count();
    let (mut span_ov, mut wait_ov, mut codec_ov) = (0u64, 0u64, 0u64);
    for ev in events {
        if ev.lane != Lane::Engine {
            continue;
        }
        let ov = overlap_ns(&windows[ev.rank as usize], ev.t_ns, ev.end_ns());
        match ev.kind {
            TraceKind::GroupExchangePhase | TraceKind::TauSync => span_ov += ov,
            TraceKind::Wait => wait_ov += ov,
            TraceKind::Encode | TraceKind::Decode => codec_ov += ov,
            _ => {}
        }
    }
    let sec = |ns: u64| ns as f64 / 1e9;
    att.exposed_s = sec(exposed);
    att.wait_for_peer_s = sec(wait_ov);
    att.codec_s = sec(codec_ov);
    // Sub-spans nest inside their exchange span, so span_ov bounds them;
    // saturate anyway to keep the partition non-negative under rounding.
    att.transfer_s = sec(span_ov.saturating_sub(wait_ov).saturating_sub(codec_ov));
    att.other_s = sec(exposed.saturating_sub(span_ov));
    // Price the transfer residual into the network model's α/β terms
    // using the recorded message/byte accounting.
    let alpha_w = (att.phase_spans + att.tau_sync_spans) as f64 * net.alpha;
    let beta_w = (att.phase_wire_bytes + att.sync_wire_bytes) as f64 * net.beta;
    if alpha_w + beta_w > 0.0 {
        att.alpha_model_s = att.transfer_s * alpha_w / (alpha_w + beta_w);
        att.beta_model_s = att.transfer_s * beta_w / (alpha_w + beta_w);
    }
    att
}

const COMPONENTS: [&str; 4] = ["wait_for_peer", "codec", "transfer", "other"];

fn component(att: &Attribution, name: &str) -> f64 {
    match name {
        "wait_for_peer" => att.wait_for_peer_s,
        "codec" => att.codec_s,
        "transfer" => att.transfer_s,
        "other" => att.other_s,
        _ => unreachable!(),
    }
}

/// Component-by-component diff of a measured attribution against a
/// simulated one. Absolute seconds differ (the simulator models a
/// cluster, the measured run is in-process threads), so the comparison
/// is on each component's *share* of its own exposed total.
pub fn diff_json(measured: &Attribution, simulated: &Attribution) -> Json {
    let share = |att: &Attribution, x: f64| if att.exposed_s > 0.0 { x / att.exposed_s } else { 0.0 };
    let comps = COMPONENTS.map(|name| {
        let m = component(measured, name);
        let s = component(simulated, name);
        (
            name,
            obj(vec![
                ("measured_s", num(m)),
                ("simulated_s", num(s)),
                ("measured_share", num(share(measured, m))),
                ("simulated_share", num(share(simulated, s))),
                ("share_delta", num(share(measured, m) - share(simulated, s))),
            ]),
        )
    });
    obj(vec![
        ("measured_exposed_s", num(measured.exposed_s)),
        ("simulated_exposed_s", num(simulated.exposed_s)),
        ("components", obj(comps.into_iter().collect())),
    ])
}

/// Terminal rendering of [`diff_json`].
pub fn render_diff(measured: &Attribution, simulated: &Attribution) -> String {
    let share = |att: &Attribution, x: f64| if att.exposed_s > 0.0 { 100.0 * x / att.exposed_s } else { 0.0 };
    let mut out = String::from(
        "sim-vs-measured exposed-comm decomposition (share of each run's exposed total):\n",
    );
    out.push_str(&format!(
        "  {:<14} {:>12} {:>12} {:>8}\n",
        "component", "measured", "simulated", "delta"
    ));
    for name in COMPONENTS {
        let m = share(measured, component(measured, name));
        let s = share(simulated, component(simulated, name));
        out.push_str(&format!("  {name:<14} {m:>11.1}% {s:>11.1}% {:>7.1}%\n", m - s));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NO_VERSION;

    fn ev(kind: TraceKind, lane: Lane, rank: u32, t: u64, dur: u64) -> TraceEvent {
        let mut e = TraceEvent::new(kind, lane, t, dur);
        e.rank = rank;
        e
    }

    #[test]
    fn overlap_respects_window_union() {
        let w = vec![(10, 20), (30, 40)];
        assert_eq!(overlap_ns(&w, 0, 5), 0);
        assert_eq!(overlap_ns(&w, 0, 100), 20);
        assert_eq!(overlap_ns(&w, 15, 35), 10);
        assert_eq!(overlap_ns(&w, 20, 30), 0);
        assert_eq!(overlap_ns(&w, 12, 12), 0);
    }

    #[test]
    fn components_partition_exposed_exactly() {
        // Rank 0: app waits [100, 1100). Engine: one phase span
        // [200, 900) containing a 300 ns peer wait and 100 ns of codec.
        let events = vec![
            ev(TraceKind::Wait, Lane::App, 0, 100, 1000),
            {
                let mut e = ev(TraceKind::GroupExchangePhase, Lane::Engine, 0, 200, 700);
                e.bytes = 4096;
                e
            },
            ev(TraceKind::Wait, Lane::Engine, 0, 200, 300),
            ev(TraceKind::Encode, Lane::Engine, 0, 200, 60),
            ev(TraceKind::Decode, Lane::Engine, 0, 200, 40),
        ];
        let att = attribute(&events, &NetworkModel::aries());
        assert!((att.exposed_s - 1000e-9).abs() < 1e-15);
        assert!((att.wait_for_peer_s - 300e-9).abs() < 1e-15);
        assert!((att.codec_s - 100e-9).abs() < 1e-15);
        assert!((att.transfer_s - 300e-9).abs() < 1e-15);
        assert!((att.other_s - 300e-9).abs() < 1e-15);
        assert!((att.components_sum_s() - att.exposed_s).abs() < 1e-12 * att.exposed_s.max(1e-9));
        assert!((att.alpha_model_s + att.beta_model_s - att.transfer_s).abs() < 1e-15);
        assert_eq!(att.phase_spans, 1);
        assert_eq!(att.phase_wire_bytes, 4096);
    }

    #[test]
    fn fault_spans_are_side_accounting_only() {
        let events = vec![
            ev(TraceKind::Wait, Lane::App, 0, 0, 1000),
            ev(TraceKind::Fault, Lane::Engine, 0, 100, 400),
        ];
        let att = attribute(&events, &NetworkModel::aries());
        assert_eq!(att.fault_spans, 1);
        assert!((att.degraded_s - 400e-9).abs() < 1e-15);
        // The four-way partition is untouched: with no exchange spans the
        // whole window stays `other`, fault time is reported beside it.
        assert!((att.components_sum_s() - att.exposed_s).abs() < 1e-15);
        assert!((att.other_s - 1000e-9).abs() < 1e-15);
        assert!(att.report("faulty").contains("fault spans"));
    }

    #[test]
    fn engine_activity_outside_app_windows_is_hidden_not_exposed() {
        // The engine runs a passive collective while the app computes:
        // nothing of it lands in the exposed decomposition.
        let events = vec![
            ev(TraceKind::Compute, Lane::App, 0, 0, 1000),
            ev(TraceKind::GroupExchangePhase, Lane::Engine, 0, 100, 500),
            ev(TraceKind::Wait, Lane::App, 0, 2000, 10),
        ];
        let att = attribute(&events, &NetworkModel::aries());
        assert!((att.exposed_s - 10e-9).abs() < 1e-15);
        assert_eq!(att.transfer_s, 0.0);
        assert!((att.other_s - 10e-9).abs() < 1e-15);
        // ... but the deterministic accounting still sees the span.
        assert_eq!(att.phase_spans, 1);
    }

    #[test]
    fn multiple_ranks_sum() {
        let mut events = Vec::new();
        for r in 0..4u32 {
            events.push(ev(TraceKind::Wait, Lane::App, r, 100 * r as u64, 50));
        }
        let att = attribute(&events, &NetworkModel::aries());
        assert_eq!(att.ranks, 4);
        assert!((att.exposed_s - 200e-9).abs() < 1e-15);
    }

    #[test]
    fn diff_shares_are_comparable() {
        let events = vec![
            ev(TraceKind::Wait, Lane::App, 0, 0, 100),
            ev(TraceKind::TauSync, Lane::Engine, 0, 0, 100),
        ];
        let att = attribute(&events, &NetworkModel::aries());
        let d = diff_json(&att, &att);
        let t = d.get("components").unwrap().get("transfer").unwrap();
        assert_eq!(t.get("share_delta").unwrap().as_f64(), Some(0.0));
        assert!(render_diff(&att, &att).contains("transfer"));
        // Versionless events attribute fine (no NaN from sentinels).
        assert_eq!(events[0].version, NO_VERSION);
        assert!(att.components_sum_s().is_finite());
    }

    #[test]
    fn empty_stream_yields_zero_report() {
        let att = attribute(&[], &NetworkModel::aries());
        assert_eq!(att, Attribution::default());
        assert!(att.report("empty").contains("0.0000 s"));
    }
}
