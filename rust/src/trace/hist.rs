//! Log-bucketed histograms and the shared percentile helper.
//!
//! [`LogHistogram`] buckets `u64` samples by bit length (powers of two):
//! constant memory, O(1) record, exact count/sum/min/max, and quantiles
//! accurate to the bucket's span. [`HistogramRegistry`] keeps one
//! histogram per [`TraceKind`], fed by the recorder on every span.
//!
//! [`percentile_sorted`] is the single linear-interpolated percentile
//! implementation in the tree; `util::stats` re-exports it, so the bench
//! summaries and the trace registry agree on percentile semantics.

use super::{TraceEvent, TraceKind, N_KINDS};

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Number of log2 buckets: bucket 0 holds the value 0, bucket `b ≥ 1`
/// holds values with bit length `b`, i.e. `[2^(b-1), 2^b)`. Public so the
/// live-telemetry registry's atomic histograms share the exact bucketing.
pub const N_BUCKETS: usize = 65;

/// Fixed-size log2-bucketed histogram of `u64` samples (durations in
/// nanoseconds, staleness in iterations, ...). Zero allocations; merging
/// two histograms is elementwise addition.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram { counts: [0; N_BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

/// Bucket index of a sample: its bit length (0 for the value 0).
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive value bounds of bucket `b`.
pub fn bucket_bounds(b: usize) -> (u64, u64) {
    if b == 0 {
        (0, 0)
    } else {
        (1u64 << (b - 1), if b == 64 { u64::MAX } else { (1u64 << b) - 1 })
    }
}

impl LogHistogram {
    /// Rebuild a histogram from externally accumulated bucket counts
    /// (the telemetry registry records into per-bucket atomics with the
    /// same [`bucket_of`] indexing, then snapshots through here so all
    /// quantile math stays in one place). `min`/`max` follow the
    /// [`Default`] convention: `u64::MAX`/`0` when `counts` is all-zero.
    pub fn from_parts(counts: [u64; N_BUCKETS], sum: u64, min: u64, max: u64) -> LogHistogram {
        let count: u64 = counts.iter().sum();
        LogHistogram { counts, count, sum, min, max }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all recorded samples (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Quantile estimate: locate the bucket holding rank `q·(count−1)`
    /// and interpolate linearly across the bucket's value span. Exact for
    /// q = 0 / q = 1 (tracked min/max); within a factor of 2 elsewhere.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = (q * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c > target {
                let (lo, hi) = bucket_bounds(b);
                let idx_in = (target - cum) as f64;
                let est = lo as f64 + (hi - lo) as f64 * ((idx_in + 0.5) / c as f64);
                return est.clamp(self.min() as f64, self.max as f64);
            }
            cum += c;
        }
        self.max as f64
    }
}

/// One [`LogHistogram`] per span kind — the registry the recorder feeds
/// on every recorded event.
#[derive(Debug, Clone, Default)]
pub struct HistogramRegistry {
    hists: [LogHistogram; N_KINDS],
}

impl HistogramRegistry {
    pub fn record(&mut self, kind: TraceKind, v: u64) {
        self.hists[kind.index()].record(v);
    }

    pub fn kind(&self, kind: TraceKind) -> &LogHistogram {
        &self.hists[kind.index()]
    }

    pub fn merge(&mut self, other: &HistogramRegistry) {
        for (a, b) in self.hists.iter_mut().zip(&other.hists) {
            a.merge(b);
        }
    }

    /// Build a registry of span durations from an event list (e.g. after
    /// filtering by lane or rank).
    pub fn from_events<'a, I: IntoIterator<Item = &'a TraceEvent>>(events: I) -> Self {
        let mut out = HistogramRegistry::default();
        for ev in events {
            out.record(ev.kind, ev.dur_ns);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_moved_here_still_interpolates() {
        let s: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile_sorted(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 0.5) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn buckets_partition_u64() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        for b in 0..N_BUCKETS {
            let (lo, hi) = bucket_bounds(b);
            assert_eq!(bucket_of(lo), b);
            assert_eq!(bucket_of(hi), b);
        }
    }

    #[test]
    fn exact_aggregates() {
        let mut h = LogHistogram::default();
        for v in [5u64, 0, 100, 7, 3] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 115);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 23.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_within_bucket_resolution() {
        let mut h = LogHistogram::default();
        let mut xs: Vec<u64> = (1..=1000).collect();
        for &x in &xs {
            h.record(x);
        }
        xs.sort_unstable();
        for q in [0.1, 0.5, 0.9, 0.99] {
            let sorted: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
            let exact = percentile_sorted(&sorted, q);
            let est = h.quantile(q);
            // Log2 buckets: the estimate is within a factor of 2.
            assert!(
                est >= exact / 2.0 && est <= exact * 2.0,
                "q={q}: est {est} vs exact {exact}"
            );
        }
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(1.0), 1000.0);
    }

    #[test]
    fn merge_is_elementwise_sum() {
        let mut a = LogHistogram::default();
        let mut b = LogHistogram::default();
        for v in 0..100u64 {
            a.record(v);
            b.record(v * 10);
        }
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.count(), 200);
        assert_eq!(m.sum(), a.sum() + b.sum());
        assert_eq!(m.min(), 0);
        assert_eq!(m.max(), 990);
    }

    #[test]
    fn registry_routes_by_kind() {
        let mut r = HistogramRegistry::default();
        r.record(TraceKind::Wait, 10);
        r.record(TraceKind::Wait, 20);
        r.record(TraceKind::Compute, 5);
        assert_eq!(r.kind(TraceKind::Wait).count(), 2);
        assert_eq!(r.kind(TraceKind::Wait).sum(), 30);
        assert_eq!(r.kind(TraceKind::Compute).count(), 1);
        assert_eq!(r.kind(TraceKind::Encode).count(), 0);
    }

    #[test]
    fn empty_histogram_is_sane() {
        let h = LogHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }
}
