//! Iteration-by-iteration critical path over the causal DAG, partitioned
//! exactly into the attribution classes × rank × phase — plus the
//! automated regression explainer (`wagma critpath --explain`).
//!
//! The walk is a *backward timeline cover*: starting from the global sink
//! (the span with the latest end), it repeatedly finds the span covering
//! the current instant on the current rank and consumes time down to that
//! span's start, emitting one contiguous [`Segment`] per covered stretch.
//! Cover preference per instant is app-lane work (`Compute`/`Publish`,
//! real local progress) over engine spans (which explain blocked time)
//! over app-lane `Wait` (engine idle — waiting on a remote activation).
//! When a consumed stretch dips into an exchange span's blocked-receive
//! zone and the span names its causal peer (the wire stamp), the walk
//! *jumps* to that peer's timeline — the producing side's work is what
//! the wait was really made of — so the path crosses ranks exactly where
//! the happens-before edges do. Gaps (no span at all) become `other`.
//!
//! Because consecutive segments share endpoints by construction, the
//! segments tile `[t_start, t_end]` exactly: the per-class nanosecond
//! totals partition the makespan **bit-exactly, at every P** (the P=1
//! acceptance pin is just the race-free special case where the walk is
//! also schedule-deterministic). That is the property that makes the
//! shares gateable and the explainer's diffs trustworthy: a regression
//! must show up in a named (rank, phase, class) cell.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::json::{arr, num, obj, s, Json};

use super::causal::CausalGraph;
use super::{Lane, TraceEvent, TraceKind, NO_PEER, NO_PHASE, NO_VERSION};

/// Critical-path attribution classes — the trace attribution taxonomy
/// ([`super::attrib`]) plus `compute` (on-path local work).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Class {
    Compute,
    WaitForPeer,
    Codec,
    Transfer,
    Other,
}

/// Number of classes (array-indexed totals).
pub const N_CLASSES: usize = 5;

impl Class {
    pub const ALL: [Class; N_CLASSES] =
        [Class::Compute, Class::WaitForPeer, Class::Codec, Class::Transfer, Class::Other];

    pub fn index(self) -> usize {
        match self {
            Class::Compute => 0,
            Class::WaitForPeer => 1,
            Class::Codec => 2,
            Class::Transfer => 3,
            Class::Other => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Class::Compute => "compute",
            Class::WaitForPeer => "wait_for_peer",
            Class::Codec => "codec",
            Class::Transfer => "transfer",
            Class::Other => "other",
        }
    }
}

/// One contiguous stretch of the critical path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub rank: u32,
    pub class: Class,
    /// Version of the covering span ([`NO_VERSION`] for gaps).
    pub version: u64,
    /// Phase of the covering span ([`NO_PHASE`] if none).
    pub phase: u32,
    pub t0: u64,
    pub t1: u64,
    /// Covering span (index into [`CausalGraph::spans`]); `None` for gaps.
    pub span: Option<usize>,
}

impl Segment {
    pub fn dur_ns(&self) -> u64 {
        self.t1 - self.t0
    }
}

/// The computed critical path and its exact partition.
#[derive(Debug, Clone, Default)]
pub struct CritPath {
    pub t_start: u64,
    pub t_end: u64,
    /// Forward time order; consecutive segments share endpoints, so the
    /// segments tile `[t_start, t_end]` exactly.
    pub segments: Vec<Segment>,
    /// Nanoseconds per class ([`Class::index`]); sums to the makespan.
    pub class_ns: [u64; N_CLASSES],
    /// Nanoseconds per rank; sums to the makespan.
    pub rank_ns: Vec<u64>,
    /// (rank, phase, class) → on-path ns. Phase is [`NO_PHASE`] for
    /// compute/sync/gap stretches.
    pub cells: BTreeMap<(u32, u32, Class), u64>,
    /// version → on-path ns (the iteration-by-iteration view).
    pub iter_ns: BTreeMap<u64, u64>,
    /// Distinct spans the path ran through, sorted (overlay input).
    pub onpath_span_idx: Vec<usize>,
    /// Bytes-on-wire of the distinct on-path exchange/sync spans.
    pub onpath_wire_bytes: u64,
}

/// Per-(rank, priority-lane) cover index: spans sorted by start with
/// prefix-max ends, so "best span starting before t" is a binary search.
#[derive(Debug, Default)]
struct LaneIdx {
    starts: Vec<u64>,
    idx: Vec<usize>,
    pref_end: Vec<u64>,
    pref_arg: Vec<usize>,
}

impl LaneIdx {
    fn push(&mut self, span_idx: usize, t_ns: u64, end_ns: u64) {
        match (self.pref_end.last().copied(), self.pref_arg.last().copied()) {
            (Some(e), Some(a)) if end_ns <= e => {
                self.pref_end.push(e);
                self.pref_arg.push(a);
            }
            _ => {
                self.pref_end.push(end_ns);
                self.pref_arg.push(self.idx.len());
            }
        }
        self.starts.push(t_ns);
        self.idx.push(span_idx);
    }

    /// Max-end span among those starting strictly before `t`.
    fn best_before(&self, t: u64) -> Option<(usize, u64)> {
        let k = self.starts.partition_point(|&x| x < t);
        if k == 0 {
            None
        } else {
            Some((self.idx[self.pref_arg[k - 1]], self.pref_end[k - 1]))
        }
    }
}

/// Work (app compute/publish) > engine > app wait.
const N_PRI: usize = 3;

fn priority_of(ev: &TraceEvent) -> usize {
    match (ev.lane, ev.kind) {
        (Lane::App, TraceKind::Compute | TraceKind::Publish) => 0,
        (Lane::Engine, _) => 1,
        (Lane::App, _) => 2,
    }
}

/// Convenience: graph construction + walk in one call.
pub fn critical_path_events(events: &[TraceEvent]) -> CritPath {
    critical_path(&CausalGraph::build(events))
}

/// Compute the critical path of a causal graph (see module docs for the
/// walk). Deterministic given the events; at P=1 the events themselves
/// are schedule-deterministic, which is what the bench gates.
pub fn critical_path(g: &CausalGraph) -> CritPath {
    let mut cp = CritPath { rank_ns: vec![0; g.p], ..CritPath::default() };
    if g.spans.is_empty() {
        return cp;
    }
    let mut lanes: Vec<[LaneIdx; N_PRI]> = (0..g.p).map(|_| Default::default()).collect();
    for (i, ev) in g.spans.iter().enumerate() {
        lanes[ev.rank as usize][priority_of(ev)].push(i, ev.t_ns, ev.end_ns());
    }
    let t_min = g.spans.iter().map(|e| e.t_ns).min().unwrap_or(0);
    let (mut t, mut rank, _) = g
        .spans
        .iter()
        .map(|e| (e.end_ns(), e.rank, ()))
        .max_by_key(|&(end, r, _)| (end, std::cmp::Reverse(r)))
        .unwrap_or((0, 0, ()));
    cp.t_start = t_min;
    cp.t_end = t;

    let mut rev_segments: Vec<Segment> = Vec::new();
    let mut onpath: BTreeSet<usize> = BTreeSet::new();
    while t > t_min {
        // Find the covering span at instant t on `rank`, in priority
        // order; clamp its consumed stretch at any higher-priority
        // span's end so local work always wins the overlap.
        let mut cover: Option<(usize, usize)> = None; // (span idx, priority)
        for (pri, lane) in lanes[rank as usize].iter().enumerate() {
            if let Some((i, end)) = lane.best_before(t) {
                if end >= t {
                    cover = Some((i, pri));
                    break;
                }
            }
        }
        match cover {
            None => {
                // Gap: no span covers t. Fall to the latest end below t
                // (or the global start) as `other` time.
                let bottom = lanes[rank as usize]
                    .iter()
                    .filter_map(|l| l.best_before(t).map(|(_, e)| e))
                    .max()
                    .unwrap_or(t_min)
                    .min(t)
                    .max(t_min);
                rev_segments.push(Segment {
                    rank,
                    class: Class::Other,
                    version: NO_VERSION,
                    phase: NO_PHASE,
                    t0: bottom,
                    t1: t,
                    span: None,
                });
                t = bottom;
            }
            Some((i, pri)) => {
                let sp = &g.spans[i];
                let mut bottom = sp.t_ns;
                for higher in lanes[rank as usize].iter().take(pri) {
                    if let Some((_, e)) = higher.best_before(t) {
                        // e < t here, else `higher` would have covered t.
                        bottom = bottom.max(e);
                    }
                }
                onpath.insert(i);
                let consumed_wait =
                    emit_span_segments(&mut rev_segments, g, i, bottom, t, rank);
                t = bottom;
                // Cross-rank jump: the blocked stretch was made of the
                // causal peer's concurrent work — continue on its
                // timeline if it has history before this instant.
                if consumed_wait && sp.peer != NO_PEER && sp.peer != sp.rank {
                    let q = sp.peer as usize;
                    if q < g.p
                        && lanes[q].iter().any(|l| l.best_before(t).is_some())
                    {
                        rank = sp.peer;
                    }
                }
            }
        }
    }

    rev_segments.reverse();
    for seg in &rev_segments {
        let d = seg.dur_ns();
        cp.class_ns[seg.class.index()] += d;
        if (seg.rank as usize) < cp.rank_ns.len() {
            cp.rank_ns[seg.rank as usize] += d;
        }
        *cp.cells.entry((seg.rank, seg.phase, seg.class)).or_insert(0) += d;
        if seg.version != NO_VERSION {
            *cp.iter_ns.entry(seg.version).or_insert(0) += d;
        }
    }
    for &i in &onpath {
        let sp = &g.spans[i];
        if sp.lane == Lane::Engine
            && matches!(sp.kind, TraceKind::GroupExchangePhase | TraceKind::TauSync)
        {
            cp.onpath_wire_bytes += sp.bytes;
        }
    }
    cp.onpath_span_idx = onpath.into_iter().collect();
    cp.segments = rev_segments;
    debug_assert!(cp.partition_exact(), "segments must tile the makespan");
    cp
}

/// Emit the class segments for consuming `[bottom, t]` of span `i`
/// (top-down, reverse time order). Returns whether the consumed stretch
/// dipped into the span's blocked-receive zone (jump trigger).
fn emit_span_segments(
    out: &mut Vec<Segment>,
    g: &CausalGraph,
    i: usize,
    bottom: u64,
    t: u64,
    rank: u32,
) -> bool {
    let sp = &g.spans[i];
    let seg = |class: Class, t0: u64, t1: u64| Segment {
        rank,
        class,
        version: sp.version,
        phase: sp.phase,
        t0,
        t1,
        span: Some(i),
    };
    match (sp.lane, sp.kind) {
        (Lane::App, TraceKind::Compute) => {
            out.push(seg(Class::Compute, bottom, t));
            false
        }
        (Lane::App, TraceKind::Publish) => {
            out.push(seg(Class::Other, bottom, t));
            false
        }
        (Lane::App, _) => {
            // App-lane wait with no engine span under it: the engine was
            // idle — at P>1 that is waiting on a remote activation; at
            // P=1 there are no peers, it is dispatch latency.
            let class = if g.p > 1 { Class::WaitForPeer } else { Class::Other };
            out.push(seg(class, bottom, t));
            false
        }
        (Lane::Engine, TraceKind::GroupExchangePhase | TraceKind::TauSync) => {
            // Subtractive zones anchored at the span start: blocked
            // receive at the bottom, then codec, then transfer — the
            // same split `attrib` makes, localized to this span.
            let n = &g.nested[i];
            let dur = sp.dur_ns;
            let wait = n.wait_ns.min(dur);
            let codec = (n.encode_ns + n.decode_ns).min(dur - wait);
            let z1 = sp.t_ns + wait;
            let z2 = z1 + codec;
            let mut push_zone = |class: Class, lo: u64, hi: u64| {
                let a = lo.max(bottom);
                let b = hi.min(t);
                if b > a {
                    out.push(seg(class, a, b));
                }
            };
            push_zone(Class::Transfer, z2, t.max(z2));
            push_zone(Class::Codec, z1, z2);
            push_zone(Class::WaitForPeer, sp.t_ns, z1);
            wait > 0 && bottom < z1
        }
        (Lane::Engine, TraceKind::Fault) => {
            // Deadline burned on a missing peer.
            out.push(seg(Class::WaitForPeer, bottom, t));
            true
        }
        (Lane::Engine, TraceKind::Wait) => {
            out.push(seg(Class::WaitForPeer, bottom, t));
            true
        }
        (Lane::Engine, TraceKind::Encode | TraceKind::Decode) => {
            out.push(seg(Class::Codec, bottom, t));
            false
        }
        (Lane::Engine, _) => {
            out.push(seg(Class::Other, bottom, t));
            false
        }
    }
}

impl CritPath {
    pub fn makespan_ns(&self) -> u64 {
        self.t_end - self.t_start
    }

    /// The exactness property: class totals partition the makespan
    /// bit-exactly (true by construction; pinned by tests at P=1).
    pub fn partition_exact(&self) -> bool {
        self.class_ns.iter().sum::<u64>() == self.makespan_ns()
    }

    pub fn onpath_spans(&self) -> usize {
        self.onpath_span_idx.len()
    }

    /// Per-event on-path marks for a Chrome overlay over the *original*
    /// event stream the graph was built from. Top-level on-path spans are
    /// matched by identity; nested engine sub-spans are marked when their
    /// enclosing exchange span is on the path.
    pub fn onpath_marks(&self, g: &CausalGraph, events: &[TraceEvent]) -> Vec<bool> {
        type Key = (usize, usize, u32, u64, u64, u64, u32);
        let key = |e: &TraceEvent| -> Key {
            (e.kind.index(), e.lane.index(), e.rank, e.t_ns, e.dur_ns, e.version, e.phase)
        };
        let mut tops: BTreeSet<Key> = BTreeSet::new();
        let mut nested_keys: BTreeSet<(u32, u64, u32)> = BTreeSet::new();
        for &i in &self.onpath_span_idx {
            let sp = &g.spans[i];
            tops.insert(key(sp));
            if sp.lane == Lane::Engine
                && matches!(sp.kind, TraceKind::GroupExchangePhase | TraceKind::TauSync)
            {
                nested_keys.insert((sp.rank, sp.version, sp.phase));
            }
        }
        events
            .iter()
            .map(|e| {
                tops.contains(&key(e))
                    || (e.lane == Lane::Engine
                        && matches!(
                            e.kind,
                            TraceKind::Wait | TraceKind::Encode | TraceKind::Decode
                        )
                        && nested_keys.contains(&(e.rank, e.version, e.phase)))
            })
            .collect()
    }

    /// Report JSON — the `critpath` block shape shared by `BENCH_engine.json`
    /// and `wagma critpath` outputs; [`explain`] diffs two of these.
    pub fn to_json(&self) -> Json {
        let makespan = self.makespan_ns().max(1) as f64;
        let class_obj = |scale: f64| {
            obj(Class::ALL
                .iter()
                .map(|c| (c.name(), num(self.class_ns[c.index()] as f64 * scale)))
                .collect())
        };
        let mut cells: Vec<(&(u32, u32, Class), &u64)> = self.cells.iter().collect();
        cells.sort_by_key(|&(k, ns)| (std::cmp::Reverse(*ns), *k));
        let cells_json: Vec<Json> = cells
            .iter()
            .take(64)
            .map(|&(&(rank, phase, class), &ns)| {
                obj(vec![
                    ("rank", num(rank as f64)),
                    ("phase", if phase == NO_PHASE { Json::Null } else { num(phase as f64) }),
                    ("class", s(class.name())),
                    ("ns", num(ns as f64)),
                ])
            })
            .collect();
        let iters: Vec<Json> = self
            .iter_ns
            .iter()
            .map(|(&v, &ns)| obj(vec![("v", num(v as f64)), ("ns", num(ns as f64))]))
            .collect();
        obj(vec![
            ("makespan_ns", num(self.makespan_ns() as f64)),
            ("onpath_spans", num(self.onpath_spans() as f64)),
            ("onpath_wire_bytes", num(self.onpath_wire_bytes as f64)),
            ("class_ns", class_obj(1.0)),
            ("class_share", class_obj(1.0 / makespan)),
            ("rank_ns", arr(self.rank_ns.iter().map(|&n| num(n as f64)).collect())),
            ("cells", arr(cells_json)),
            ("iters", arr(iters)),
        ])
    }

    /// Terminal report: top-k segments + per-class/per-rank share table.
    pub fn render(&self, label: &str, k: usize) -> String {
        let makespan = self.makespan_ns();
        let pct = |ns: u64| {
            if makespan > 0 { 100.0 * ns as f64 / makespan as f64 } else { 0.0 }
        };
        let mut out = format!(
            "critical path [{label}] — makespan {:.3} ms, {} on-path spans, {} wire bytes on path\n",
            makespan as f64 * 1e-6,
            self.onpath_spans(),
            self.onpath_wire_bytes,
        );
        out.push_str("  class shares:");
        for c in Class::ALL {
            out.push_str(&format!(" {} {:.1}%", c.name(), pct(self.class_ns[c.index()])));
        }
        out.push('\n');
        out.push_str("  rank shares: ");
        for (r, &ns) in self.rank_ns.iter().enumerate() {
            out.push_str(&format!(" r{r} {:.1}%", pct(ns)));
        }
        out.push('\n');
        let mut top: Vec<&Segment> = self.segments.iter().collect();
        top.sort_by_key(|seg| std::cmp::Reverse(seg.dur_ns()));
        out.push_str(&format!("  top {} segments:\n", k.min(top.len())));
        for seg in top.iter().take(k) {
            let phase = if seg.phase == NO_PHASE {
                "-".to_string()
            } else {
                seg.phase.to_string()
            };
            let version = if seg.version == NO_VERSION {
                "-".to_string()
            } else {
                seg.version.to_string()
            };
            out.push_str(&format!(
                "    rank {:>2}  v {:>4}  phase {:>2}  {:<13} {:>10.3} ms ({:4.1}%)\n",
                seg.rank,
                version,
                phase,
                seg.class.name(),
                seg.dur_ns() as f64 * 1e-6,
                pct(seg.dur_ns()),
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Regression explainer
// ---------------------------------------------------------------------------

/// One comparable critpath report pulled out of a JSON document.
struct Extracted<'a> {
    label: String,
    crit: &'a Json,
}

/// Accepts either a bare critpath report (has `makespan_ns`), a bench
/// report (`presets` array with per-preset `critpath` blocks), or a
/// critpath-CLI output (`runs` array). The bench block nests arms
/// (`layered` / `p1`); the layered arm is the one diffed.
fn extract<'a>(doc: &'a Json, which: &str) -> Result<Vec<Extracted<'a>>, String> {
    fn arm(block: &Json) -> Option<&Json> {
        if block.get("makespan_ns").is_some() {
            return Some(block);
        }
        block.get("layered").filter(|b| b.get("makespan_ns").is_some())
    }
    if doc.get("makespan_ns").is_some() {
        return Ok(vec![Extracted { label: "trace".into(), crit: doc }]);
    }
    for key in ["presets", "runs"] {
        if let Some(cases) = doc.get(key).and_then(Json::as_arr) {
            let mut out = Vec::new();
            for case in cases {
                let label = case
                    .get("preset")
                    .or_else(|| case.get("label"))
                    .and_then(Json::as_str)
                    .unwrap_or("run")
                    .to_string();
                if let Some(crit) = case.get("critpath").and_then(arm) {
                    out.push(Extracted { label, crit });
                }
            }
            if out.is_empty() {
                return Err(format!(
                    "{which}: no critpath block in any {key} entry (regenerate with a \
                     critpath-aware build)"
                ));
            }
            return Ok(out);
        }
    }
    if let Some(crit) = doc.get("critpath").and_then(arm) {
        return Ok(vec![Extracted { label: "trace".into(), crit }]);
    }
    Err(format!("{which}: not a critpath report or bench output (no makespan_ns/presets)"))
}

fn cell_map(crit: &Json) -> BTreeMap<(i64, i64, String), f64> {
    let mut out = BTreeMap::new();
    if let Some(cells) = crit.get("cells").and_then(Json::as_arr) {
        for c in cells {
            let rank = c.get("rank").and_then(Json::as_f64).unwrap_or(-1.0) as i64;
            let phase = c.get("phase").and_then(Json::as_f64).map_or(-1, |p| p as i64);
            let class = c.get("class").and_then(Json::as_str).unwrap_or("?").to_string();
            let ns = c.get("ns").and_then(Json::as_f64).unwrap_or(0.0);
            *out.entry((rank, phase, class)).or_insert(0.0) += ns;
        }
    }
    out
}

fn f(crit: &Json, key: &str) -> f64 {
    crit.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// Diff two bench/trace outputs and name the component that moved. The
/// first output line is the verdict, e.g.
/// `critical path grew 18%: rank 2 phase 1 transfer, wire bytes +2.1x`.
/// CI perf gates invoke this on failure so a red job states *why*.
pub fn explain(old: &Json, new: &Json) -> Result<String, String> {
    let olds = extract(old, "OLD")?;
    let news = extract(new, "NEW")?;
    // Pair by label; diff every pair, lead with the biggest mover.
    struct Delta {
        label: String,
        old_ms: f64,
        new_ms: f64,
        growth_pct: f64,
        culprit: String,
        wire_ratio: f64,
        detail: Vec<String>,
    }
    let mut deltas: Vec<Delta> = Vec::new();
    for o in &olds {
        let Some(n) = news.iter().find(|n| n.label == o.label) else { continue };
        let old_make = f(o.crit, "makespan_ns");
        let new_make = f(n.crit, "makespan_ns");
        if old_make <= 0.0 {
            continue;
        }
        let growth_pct = 100.0 * (new_make - old_make) / old_make;
        let oc = cell_map(o.crit);
        let nc = cell_map(n.crit);
        // The moved component: the (rank, phase, class) cell whose
        // on-path time grew the most.
        let mut culprit = String::from("no cell attribution");
        let mut best = f64::NEG_INFINITY;
        let mut detail: Vec<String> = Vec::new();
        let keys: BTreeSet<_> = oc.keys().chain(nc.keys()).cloned().collect();
        let mut moves: Vec<(f64, String)> = Vec::new();
        for k in keys {
            let d = nc.get(&k).unwrap_or(&0.0) - oc.get(&k).unwrap_or(&0.0);
            let (rank, phase, class) = &k;
            let name = if *phase < 0 {
                format!("rank {rank} {class}")
            } else {
                format!("rank {rank} phase {phase} {class}")
            };
            if d > best {
                best = d;
                culprit = name.clone();
            }
            moves.push((d, name));
        }
        moves.sort_by(|a, b| b.0.abs().partial_cmp(&a.0.abs()).unwrap_or(std::cmp::Ordering::Equal));
        for (d, name) in moves.iter().take(3) {
            detail.push(format!("    {name}: {:+.3} ms on-path", d * 1e-6));
        }
        let old_wire = f(o.crit, "onpath_wire_bytes");
        let new_wire = f(n.crit, "onpath_wire_bytes");
        let wire_ratio = if old_wire > 0.0 { new_wire / old_wire } else { 1.0 };
        deltas.push(Delta {
            label: o.label.clone(),
            old_ms: old_make * 1e-6,
            new_ms: new_make * 1e-6,
            growth_pct,
            culprit,
            wire_ratio,
            detail,
        });
    }
    if deltas.is_empty() {
        return Err("no comparable critpath reports between OLD and NEW (label mismatch?)".into());
    }
    deltas.sort_by(|a, b| {
        b.growth_pct.abs().partial_cmp(&a.growth_pct.abs()).unwrap_or(std::cmp::Ordering::Equal)
    });
    let lead = &deltas[0];
    let verb = if lead.growth_pct >= 1.0 {
        format!("grew {:.0}%", lead.growth_pct)
    } else if lead.growth_pct <= -1.0 {
        format!("shrank {:.0}%", -lead.growth_pct)
    } else {
        format!("unchanged ({:+.1}%)", lead.growth_pct)
    };
    let wire = if lead.wire_ratio >= 1.0 {
        format!("+{:.1}x", lead.wire_ratio)
    } else {
        format!("{:.1}x", lead.wire_ratio)
    };
    let mut out =
        format!("critical path {verb}: {}, wire bytes {wire}\n", lead.culprit);
    for d in &deltas {
        out.push_str(&format!(
            "  [{}] makespan {:.3} ms -> {:.3} ms ({:+.1}%), on-path wire bytes x{:.2}\n",
            d.label, d.old_ms, d.new_ms, d.growth_pct, d.wire_ratio,
        ));
        for line in &d.detail {
            out.push_str(line);
            out.push('\n');
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: TraceKind, lane: Lane, rank: u32, t: u64, dur: u64) -> TraceEvent {
        let mut e = TraceEvent::new(kind, lane, t, dur);
        e.rank = rank;
        e
    }

    /// Serial single-rank timeline: compute [0,100), publish [100,110),
    /// app wait [110,300) containing a sync span [150,250).
    fn p1_events() -> Vec<TraceEvent> {
        let mut c = ev(TraceKind::Compute, Lane::App, 0, 0, 100);
        c.version = 0;
        let mut p = ev(TraceKind::Publish, Lane::App, 0, 100, 10);
        p.version = 0;
        let mut w = ev(TraceKind::Wait, Lane::App, 0, 110, 190);
        w.version = 0;
        let mut ts = ev(TraceKind::TauSync, Lane::Engine, 0, 150, 100);
        ts.version = 0;
        ts.bytes = 0;
        vec![c, p, w, ts]
    }

    #[test]
    fn p1_partition_is_bit_exact() {
        let cp = critical_path_events(&p1_events());
        assert_eq!(cp.makespan_ns(), 300);
        assert!(cp.partition_exact());
        assert_eq!(cp.class_ns.iter().sum::<u64>(), 300);
        // Compute 100, publish (other) 10, transfer 100 (sync span with
        // no nested waits), and the app-wait remainder is dispatch
        // latency (`other` at P=1): 40 + 50.
        assert_eq!(cp.class_ns[Class::Compute.index()], 100);
        assert_eq!(cp.class_ns[Class::Transfer.index()], 100);
        assert_eq!(cp.class_ns[Class::Other.index()], 100);
        assert_eq!(cp.class_ns[Class::WaitForPeer.index()], 0, "no peers at P=1");
        assert_eq!(cp.onpath_spans(), 4);
    }

    #[test]
    fn blocked_receive_jumps_to_the_causal_peer() {
        // Rank 0 computes late; rank 1's exchange span blocks on rank 0
        // (wire-stamped peer). The path must cross from rank 1's wait to
        // rank 0's compute.
        let mut c0 = ev(TraceKind::Compute, Lane::App, 0, 0, 500);
        c0.version = 0;
        let mut x0 = ev(TraceKind::GroupExchangePhase, Lane::Engine, 0, 500, 100);
        x0.version = 0;
        x0.phase = 0;
        x0.peer = 1;
        let mut c1 = ev(TraceKind::Compute, Lane::App, 1, 0, 100);
        c1.version = 0;
        let mut x1 = ev(TraceKind::GroupExchangePhase, Lane::Engine, 1, 100, 520);
        x1.version = 0;
        x1.phase = 0;
        x1.peer = 0;
        let mut w1 = ev(TraceKind::Wait, Lane::Engine, 1, 100, 400);
        w1.version = 0;
        w1.phase = 0;
        w1.peer = 0;
        let cp = critical_path_events(&[c0, x0, c1, x1, w1]);
        assert!(cp.partition_exact());
        // Rank 0's compute dominates the path via the jump.
        assert!(cp.class_ns[Class::Compute.index()] >= 500);
        assert!(cp.rank_ns[0] >= 500, "rank 0 drives the path: {:?}", cp.rank_ns);
        // The blocked stretch that remains on rank 1 is wait-for-peer or
        // transfer, never compute.
        assert!(cp.cells.keys().all(|&(r, _, c)| r != 1 || c != Class::Compute));
    }

    #[test]
    fn segments_tile_without_gaps_or_overlap() {
        let cp = critical_path_events(&p1_events());
        let mut prev = cp.t_start;
        for seg in &cp.segments {
            assert_eq!(seg.t0, prev, "segments must share endpoints");
            assert!(seg.t1 > seg.t0);
            prev = seg.t1;
        }
        assert_eq!(prev, cp.t_end);
    }

    #[test]
    fn report_json_round_trips_into_explainer() {
        let cp = critical_path_events(&p1_events());
        let j = cp.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        let msg = explain(&parsed, &parsed).unwrap();
        assert!(msg.starts_with("critical path unchanged"), "{msg}");
    }

    #[test]
    fn explainer_names_the_grown_cell() {
        let mk = |makespan: f64, transfer_ns: f64, wire: f64| {
            obj(vec![
                ("makespan_ns", num(makespan)),
                ("onpath_wire_bytes", num(wire)),
                (
                    "cells",
                    arr(vec![
                        obj(vec![
                            ("rank", num(2.0)),
                            ("phase", num(1.0)),
                            ("class", s("transfer")),
                            ("ns", num(transfer_ns)),
                        ]),
                        obj(vec![
                            ("rank", num(0.0)),
                            ("phase", Json::Null),
                            ("class", s("compute")),
                            ("ns", num(makespan - transfer_ns)),
                        ]),
                    ]),
                ),
            ])
        };
        let old = mk(100_000_000.0, 10_000_000.0, 1_000_000.0);
        let new = mk(118_000_000.0, 28_000_000.0, 2_100_000.0);
        let msg = explain(&old, &new).unwrap();
        let first = msg.lines().next().unwrap();
        assert_eq!(
            first,
            "critical path grew 18%: rank 2 phase 1 transfer, wire bytes +2.1x"
        );
    }

    #[test]
    fn explainer_rejects_foreign_documents() {
        let bad = obj(vec![("hello", num(1.0))]);
        assert!(explain(&bad, &bad).is_err());
    }

    #[test]
    fn empty_stream_yields_empty_path() {
        let cp = critical_path_events(&[]);
        assert_eq!(cp.makespan_ns(), 0);
        assert!(cp.partition_exact());
        assert!(cp.segments.is_empty());
    }
}
