//! Cross-rank causal graph over the two-lane trace schema.
//!
//! [`CausalGraph::build`] stitches the per-rank timelines emitted by the
//! engine, the workers, the bench, and the simulator into one DAG whose
//! nodes are *top-level* spans (`Compute`, `Publish`, app-lane `Wait`,
//! `GroupExchangePhase`, `TauSync`, `Fault`) and whose edges encode
//! happens-before:
//!
//! * **program order** — consecutive spans on the same (rank, lane);
//! * **publish → engine** — a rank's `Publish` of version *v* precedes
//!   its engine's first span for *v*;
//! * **wire** — an exchange span's schedule partner (and, for blocked
//!   receives, the causal stamp the comm layer carries on the wire — see
//!   [`crate::comm::Stamp`]) yields an edge from the *producing* side's
//!   span for the same (version, phase) to the consuming span. This is
//!   the cross-rank glue: a receive's wait gains a happens-before edge
//!   to the send that satisfied it;
//! * **engine → result** — the engine's last span for *v* precedes the
//!   app-lane `Wait` that consumed the result;
//! * **membership** — a fault-degraded identity-skip (engine `Fault`
//!   span with a `peer`) gets an edge from the dead rank's crash marker
//!   (its peer-less `Fault` span), so degraded runs still yield a
//!   connected graph: the skip is *caused by* the membership oracle's
//!   decision, not by an absent message.
//!
//! Nested engine sub-spans (`Wait`/`Encode`/`Decode` anchored at their
//! exchange span's start) are folded into their parent node as class
//! durations; sub-spans with no enclosing exchange span (e.g. the
//! simulator's pre-sync barrier waits) stay top-level nodes. The
//! [`crate::trace::critpath`] walk consumes this graph.

use std::collections::BTreeMap;

use super::{Lane, TraceEvent, TraceKind, NO_PEER};

/// Durations of the sub-spans folded into a top-level engine span.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Nested {
    /// Blocked-in-receive ns (engine `Wait` sub-span).
    pub wait_ns: u64,
    /// Codec encode ns.
    pub encode_ns: u64,
    /// Codec decode ns.
    pub decode_ns: u64,
}

/// Why an edge exists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// Same (rank, lane), consecutive spans.
    Program,
    /// Publish of version v → that rank's first engine span for v.
    Publish,
    /// Producer's exchange span → consumer's exchange span (same
    /// version/phase, peer relation carried by the causal wire stamp).
    Wire,
    /// Engine's last span for v → the app wait that consumed v's result.
    Result,
    /// Crash marker on the dead rank → the degraded identity-skip on the
    /// survivor (the membership oracle's decision).
    Membership,
}

/// One happens-before edge (`from` precedes `to`; indices into
/// [`CausalGraph::spans`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    pub kind: EdgeKind,
}

/// The stitched cross-rank DAG.
#[derive(Debug, Clone, Default)]
pub struct CausalGraph {
    /// Top-level spans, sorted by `(t_ns, rank, lane, kind)`.
    pub spans: Vec<TraceEvent>,
    /// Folded sub-span durations, parallel to `spans`.
    pub nested: Vec<Nested>,
    pub edges: Vec<Edge>,
    /// Ranks observed (max rank + 1).
    pub p: usize,
}

fn is_top_level(ev: &TraceEvent) -> bool {
    !matches!(
        (ev.lane, ev.kind),
        (Lane::Engine, TraceKind::Wait | TraceKind::Encode | TraceKind::Decode)
    )
}

impl CausalGraph {
    /// Build the graph from a merged event stream (any rank order; the
    /// builder sorts its own copy).
    pub fn build(events: &[TraceEvent]) -> CausalGraph {
        let mut evs: Vec<TraceEvent> = events.to_vec();
        evs.sort_by_key(|e| (e.t_ns, e.rank, e.lane.index(), e.kind.index()));
        let p = evs.iter().map(|e| e.rank as usize + 1).max().unwrap_or(0);

        // Split top-level spans from nested engine sub-spans.
        let mut spans: Vec<TraceEvent> = Vec::new();
        let mut subs: Vec<TraceEvent> = Vec::new();
        for ev in evs {
            if is_top_level(&ev) {
                spans.push(ev);
            } else {
                subs.push(ev);
            }
        }
        let mut nested = vec![Nested::default(); spans.len()];

        // Anchor index for sub-span folding: engine exchange/sync spans
        // keyed by (rank, version, phase, start) — the engine and the
        // simulator both anchor sub-spans at their parent's start.
        let mut anchor: BTreeMap<(u32, u64, u32, u64), usize> = BTreeMap::new();
        // Fallback: per-rank engine exchange spans for interval matching.
        let mut engine_spans: Vec<Vec<usize>> = vec![Vec::new(); p];
        for (i, ev) in spans.iter().enumerate() {
            if ev.lane == Lane::Engine
                && matches!(ev.kind, TraceKind::GroupExchangePhase | TraceKind::TauSync)
            {
                anchor.insert((ev.rank, ev.version, ev.phase, ev.t_ns), i);
                engine_spans[ev.rank as usize].push(i);
            }
        }
        let mut orphans: Vec<TraceEvent> = Vec::new();
        for sub in subs {
            let parent = anchor
                .get(&(sub.rank, sub.version, sub.phase, sub.t_ns))
                .copied()
                .or_else(|| {
                    // Same version, interval containment (chunked paths
                    // can re-anchor; simulator barrier waits won't match
                    // and stay top-level).
                    engine_spans[sub.rank as usize]
                        .iter()
                        .copied()
                        .find(|&i| {
                            let s = &spans[i];
                            s.version == sub.version
                                && s.t_ns <= sub.t_ns
                                && sub.t_ns < s.end_ns().max(s.t_ns + 1)
                        })
                });
            match parent {
                Some(i) => {
                    let n = &mut nested[i];
                    match sub.kind {
                        TraceKind::Wait => n.wait_ns += sub.dur_ns,
                        TraceKind::Encode => n.encode_ns += sub.dur_ns,
                        TraceKind::Decode => n.decode_ns += sub.dur_ns,
                        _ => unreachable!(),
                    }
                    // A blocked receive's wire stamp names the true cause;
                    // prefer it over the schedule partner on sync spans.
                    if sub.kind == TraceKind::Wait
                        && sub.peer != NO_PEER
                        && spans[i].peer == NO_PEER
                    {
                        spans[i].peer = sub.peer;
                    }
                }
                None => orphans.push(sub),
            }
        }
        if !orphans.is_empty() {
            // Unmatched sub-spans become their own nodes (the covering
            // walk classes them by kind), re-sorted into place.
            spans.extend(orphans);
            let mut order: Vec<usize> = (0..spans.len()).collect();
            order.sort_by_key(|&i| {
                let e = &spans[i];
                (e.t_ns, e.rank, e.lane.index(), e.kind.index())
            });
            let mut reordered = Vec::with_capacity(spans.len());
            let mut reordered_nested = Vec::with_capacity(spans.len());
            for i in order {
                reordered.push(spans[i]);
                reordered_nested.push(nested.get(i).copied().unwrap_or_default());
            }
            spans = reordered;
            nested = reordered_nested;
        }

        let mut g = CausalGraph { spans, nested, edges: Vec::new(), p };
        g.link();
        g
    }

    fn link(&mut self) {
        let spans = &self.spans;
        // Program order per (rank, lane).
        let mut last: BTreeMap<(u32, usize), usize> = BTreeMap::new();
        for (i, ev) in spans.iter().enumerate() {
            let key = (ev.rank, ev.lane.index());
            if let Some(&prev) = last.get(&key) {
                self.edges.push(Edge { from: prev, to: i, kind: EdgeKind::Program });
            }
            last.insert(key, i);
        }
        // Publish / Result: per (rank, version), publish span and the
        // engine's first/last span plus the app wait.
        let mut publish: BTreeMap<(u32, u64), usize> = BTreeMap::new();
        let mut first_engine: BTreeMap<(u32, u64), usize> = BTreeMap::new();
        let mut last_engine: BTreeMap<(u32, u64), usize> = BTreeMap::new();
        let mut crash_marker: BTreeMap<u32, usize> = BTreeMap::new();
        // Producer lookup for wire edges: (rank, version, phase, kind).
        let mut producer: BTreeMap<(u32, u64, u32, usize), usize> = BTreeMap::new();
        for (i, ev) in spans.iter().enumerate() {
            match (ev.lane, ev.kind) {
                (Lane::App, TraceKind::Publish) => {
                    publish.insert((ev.rank, ev.version), i);
                }
                (Lane::Engine, TraceKind::GroupExchangePhase | TraceKind::TauSync) => {
                    first_engine.entry((ev.rank, ev.version)).or_insert(i);
                    last_engine.insert((ev.rank, ev.version), i);
                    producer.insert((ev.rank, ev.version, ev.phase, ev.kind.index()), i);
                }
                (Lane::Engine, TraceKind::Fault) if ev.peer == NO_PEER => {
                    // Peer-less fault span: a crash marker (or deadline
                    // burn with unknown cause). Keep the earliest as the
                    // membership decision anchor for this rank.
                    crash_marker.entry(ev.rank).or_insert(i);
                }
                _ => {}
            }
        }
        for (&(rank, version), &eng) in &first_engine {
            if let Some(&pubi) = publish.get(&(rank, version)) {
                if pubi != eng {
                    self.edges.push(Edge { from: pubi, to: eng, kind: EdgeKind::Publish });
                }
            }
        }
        for (i, ev) in spans.iter().enumerate() {
            match (ev.lane, ev.kind) {
                (Lane::App, TraceKind::Wait) => {
                    if let Some(&eng) = last_engine.get(&(ev.rank, ev.version)) {
                        self.edges.push(Edge { from: eng, to: i, kind: EdgeKind::Result });
                    }
                }
                (Lane::Engine, TraceKind::GroupExchangePhase | TraceKind::TauSync)
                    if ev.peer != NO_PEER && ev.peer != ev.rank =>
                {
                    if let Some(&from) =
                        producer.get(&(ev.peer, ev.version, ev.phase, ev.kind.index()))
                    {
                        self.edges.push(Edge { from, to: i, kind: EdgeKind::Wire });
                    }
                }
                (Lane::Engine, TraceKind::Fault) if ev.peer != NO_PEER => {
                    // Degraded identity-skip: caused by the membership
                    // oracle declaring the peer down.
                    if let Some(&marker) = crash_marker.get(&ev.peer) {
                        self.edges.push(Edge { from: marker, to: i, kind: EdgeKind::Membership });
                    }
                }
                _ => {}
            }
        }
    }

    /// Undirected connectivity from the global sink (the span with the
    /// latest end): fraction of spans reachable. 1.0 means every recorded
    /// span — including a crashed rank's pre-crash history and the
    /// survivors' degraded skips — is causally stitched to the final
    /// state, which is what makes the critical-path walk meaningful on
    /// degraded runs.
    pub fn connected_fraction(&self) -> f64 {
        if self.spans.is_empty() {
            return 1.0;
        }
        let n = self.spans.len();
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for e in &self.edges {
            adj[e.from].push(e.to);
            adj[e.to].push(e.from);
        }
        let sink = (0..n)
            .max_by_key(|&i| (self.spans[i].end_ns(), std::cmp::Reverse(self.spans[i].rank)))
            .unwrap_or(0);
        let mut seen = vec![false; n];
        let mut stack = vec![sink];
        seen[sink] = true;
        let mut count = 0usize;
        while let Some(i) = stack.pop() {
            count += 1;
            for &j in &adj[i] {
                if !seen[j] {
                    seen[j] = true;
                    stack.push(j);
                }
            }
        }
        count as f64 / n as f64
    }

    /// Number of edges of each kind (diagnostics / tests).
    pub fn edge_counts(&self) -> BTreeMap<&'static str, usize> {
        let mut out = BTreeMap::new();
        for e in &self.edges {
            let name = match e.kind {
                EdgeKind::Program => "program",
                EdgeKind::Publish => "publish",
                EdgeKind::Wire => "wire",
                EdgeKind::Result => "result",
                EdgeKind::Membership => "membership",
            };
            *out.entry(name).or_insert(0) += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{NO_PHASE, NO_VERSION};

    fn ev(kind: TraceKind, lane: Lane, rank: u32, t: u64, dur: u64) -> TraceEvent {
        let mut e = TraceEvent::new(kind, lane, t, dur);
        e.rank = rank;
        e
    }

    #[test]
    fn nested_subspans_fold_into_their_exchange_span() {
        let mut phase = ev(TraceKind::GroupExchangePhase, Lane::Engine, 0, 100, 900);
        phase.version = 3;
        phase.phase = 1;
        phase.peer = 1;
        let mut wait = ev(TraceKind::Wait, Lane::Engine, 0, 100, 400);
        wait.version = 3;
        wait.phase = 1;
        let mut enc = ev(TraceKind::Encode, Lane::Engine, 0, 100, 50);
        enc.version = 3;
        enc.phase = 1;
        let g = CausalGraph::build(&[phase, wait, enc]);
        assert_eq!(g.spans.len(), 1);
        assert_eq!(g.nested[0], Nested { wait_ns: 400, encode_ns: 50, decode_ns: 0 });
    }

    #[test]
    fn orphan_subspans_stay_top_level() {
        // A barrier wait with no enclosing exchange span (the simulator's
        // pre-sync wait) becomes its own node.
        let mut w = ev(TraceKind::Wait, Lane::Engine, 0, 100, 400);
        w.version = 9;
        let mut sync = ev(TraceKind::TauSync, Lane::Engine, 0, 500, 300);
        sync.version = 9;
        sync.phase = NO_PHASE;
        let g = CausalGraph::build(&[sync, w]);
        assert_eq!(g.spans.len(), 2);
        assert_eq!(g.spans[0].kind, TraceKind::Wait);
        // Program order still chains them.
        assert_eq!(g.edge_counts().get("program"), Some(&1));
    }

    #[test]
    fn wire_edges_connect_producer_to_consumer() {
        let mk = |rank: u32, peer: u32| {
            let mut e = ev(TraceKind::GroupExchangePhase, Lane::Engine, rank, 100, 500);
            e.version = 0;
            e.phase = 0;
            e.peer = peer;
            e
        };
        let g = CausalGraph::build(&[mk(0, 1), mk(1, 0)]);
        assert_eq!(g.edge_counts().get("wire"), Some(&2));
        assert_eq!(g.connected_fraction(), 1.0);
    }

    #[test]
    fn publish_and_result_edges_tie_lanes_together() {
        let mut p = ev(TraceKind::Publish, Lane::App, 0, 0, 10);
        p.version = 0;
        let mut x = ev(TraceKind::GroupExchangePhase, Lane::Engine, 0, 20, 100);
        x.version = 0;
        x.phase = 0;
        let mut w = ev(TraceKind::Wait, Lane::App, 0, 10, 120);
        w.version = 0;
        let g = CausalGraph::build(&[p, x, w]);
        let counts = g.edge_counts();
        assert_eq!(counts.get("publish"), Some(&1));
        assert_eq!(counts.get("result"), Some(&1));
        assert_eq!(g.connected_fraction(), 1.0);
    }

    #[test]
    fn membership_edges_keep_degraded_runs_connected() {
        // Rank 1 crashes (peer-less marker); rank 0's identity-skip names
        // rank 1 as the down partner. Without the membership edge the two
        // rank timelines would be disconnected.
        let mut marker = ev(TraceKind::Fault, Lane::Engine, 1, 50, 0);
        marker.version = 2;
        let mut skip = ev(TraceKind::Fault, Lane::Engine, 0, 100, 30);
        skip.version = 2;
        skip.phase = 0;
        skip.peer = 1;
        let mut comp = ev(TraceKind::Compute, Lane::App, 0, 0, 90);
        comp.version = 2;
        let g = CausalGraph::build(&[marker, skip, comp]);
        assert_eq!(g.edge_counts().get("membership"), Some(&1));
        assert_eq!(g.connected_fraction(), 1.0);
    }

    #[test]
    fn empty_and_versionless_streams_are_fine() {
        let g = CausalGraph::build(&[]);
        assert_eq!(g.connected_fraction(), 1.0);
        assert_eq!(g.p, 0);
        let lone = ev(TraceKind::Compute, Lane::App, 0, 0, 5);
        let g = CausalGraph::build(&[lone]);
        assert_eq!(g.spans[0].version, NO_VERSION);
        assert_eq!(g.connected_fraction(), 1.0);
    }
}
