//! Chrome trace-event JSON export/import (via `util::json` — no serde).
//!
//! Each [`TraceEvent`] becomes one complete event (`"ph": "X"`) with
//! microsecond `ts`/`dur`, `pid` = process (one per traced run when
//! multiple runs share a file), and `tid` = `rank * 2 + lane` so every
//! rank shows its app and engine lanes as adjacent tracks. Metadata
//! events (`"ph": "M"`) name the processes and threads. The result opens
//! directly in `chrome://tracing` or <https://ui.perfetto.dev>.

use crate::util::json::{arr, num, obj, s, Json};

use super::{Lane, TraceEvent, TraceKind, NO_PEER, NO_PHASE, NO_VERSION};

fn tid(ev: &TraceEvent) -> u32 {
    ev.rank * 2 + ev.lane.index() as u32
}

fn event_json(ev: &TraceEvent, pid: u32, on_path: bool) -> Json {
    let mut args = vec![("bytes", num(ev.bytes as f64)), ("passive", Json::Bool(ev.passive))];
    if ev.version != NO_VERSION {
        args.push(("v", num(ev.version as f64)));
    }
    if ev.phase != NO_PHASE {
        args.push(("phase", num(ev.phase as f64)));
    }
    if ev.peer != NO_PEER {
        args.push(("peer", num(ev.peer as f64)));
    }
    if on_path {
        args.push(("on_path", Json::Bool(true)));
    }
    obj(vec![
        ("name", s(ev.kind.name())),
        ("cat", s(ev.lane.name())),
        ("ph", s("X")),
        ("ts", num(ev.t_ns as f64 / 1000.0)),
        ("dur", num(ev.dur_ns as f64 / 1000.0)),
        ("pid", num(pid as f64)),
        ("tid", num(tid(ev) as f64)),
        ("args", obj(args)),
    ])
}

fn metadata(name: &str, pid: u32, tid: Option<u32>, value: &str) -> Json {
    let mut fields = vec![
        ("name", s(name)),
        ("ph", s("M")),
        ("pid", num(pid as f64)),
        ("args", obj(vec![("name", s(value))])),
    ];
    if let Some(t) = tid {
        fields.push(("tid", num(t as f64)));
    }
    obj(fields)
}

/// Export one event stream as a Chrome trace-event document.
pub fn to_chrome(events: &[TraceEvent], process: &str) -> Json {
    to_chrome_multi(&[(process, events)])
}

/// Export several event streams (one `pid` each) into one document —
/// used by `wagma bench --trace` to put every preset in the same file.
pub fn to_chrome_multi(processes: &[(&str, &[TraceEvent])]) -> Json {
    to_chrome_multi_marked(&processes.iter().map(|&(n, e)| (n, e, None)).collect::<Vec<_>>())
}

/// [`to_chrome`] with a critical-path overlay: events whose index is in
/// `on_path` gain an `"on_path": true` arg, so Perfetto can highlight the
/// spans that determined the makespan (select-by-arg, or just search for
/// `on_path`). Schema-compatible with [`validate_schema`]/[`from_chrome`]
/// (extra args are tolerated / ignored).
pub fn to_chrome_overlay(events: &[TraceEvent], on_path: &[bool], process: &str) -> Json {
    to_chrome_multi_marked(&[(process, events, Some(on_path))])
}

fn to_chrome_multi_marked(processes: &[(&str, &[TraceEvent], Option<&[bool]>)]) -> Json {
    let mut out: Vec<Json> = Vec::new();
    for (pid, (name, events, marks)) in processes.iter().enumerate() {
        let pid = pid as u32;
        out.push(metadata("process_name", pid, None, name));
        let mut tids: Vec<(u32, u32, Lane)> = Vec::new();
        for ev in *events {
            if !tids.iter().any(|&(t, _, _)| t == tid(ev)) {
                tids.push((tid(ev), ev.rank, ev.lane));
            }
        }
        tids.sort_unstable_by_key(|&(t, _, _)| t);
        for (t, rank, lane) in tids {
            out.push(metadata("thread_name", pid, Some(t), &format!("rank {rank} {}", lane.name())));
        }
        out.extend(events.iter().enumerate().map(|(i, ev)| {
            let on = marks.map(|m| m.get(i).copied().unwrap_or(false)).unwrap_or(false);
            event_json(ev, pid, on)
        }));
    }
    obj(vec![("traceEvents", arr(out)), ("displayTimeUnit", s("ms"))])
}

fn field_f64(ev: &Json, key: &str) -> Result<f64, String> {
    ev.get(key).and_then(Json::as_f64).ok_or_else(|| format!("event missing numeric {key:?}"))
}

/// Parse a Chrome trace-event document back into events (metadata events
/// are skipped; `pid` is discarded — callers importing multi-process
/// files should filter beforehand). Inverse of [`to_chrome`] for every
/// event this crate emits.
pub fn from_chrome(doc: &Json) -> Result<Vec<TraceEvent>, String> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    let mut out = Vec::new();
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).ok_or("event missing ph")?;
        if ph == "M" {
            continue;
        }
        if ph != "X" {
            return Err(format!("unsupported event phase {ph:?}"));
        }
        let name = ev.get("name").and_then(Json::as_str).ok_or("event missing name")?;
        let kind = TraceKind::parse(name).ok_or_else(|| format!("unknown span kind {name:?}"))?;
        let cat = ev.get("cat").and_then(Json::as_str).ok_or("event missing cat")?;
        let lane = Lane::parse(cat).ok_or_else(|| format!("unknown lane {cat:?}"))?;
        let tid = field_f64(ev, "tid")? as u64;
        if tid % 2 != lane.index() as u64 {
            return Err(format!("tid {tid} does not encode lane {cat:?}"));
        }
        let args = ev.get("args").ok_or("event missing args")?;
        let mut e = TraceEvent::new(
            kind,
            lane,
            (field_f64(ev, "ts")? * 1000.0).round() as u64,
            (field_f64(ev, "dur")? * 1000.0).round() as u64,
        );
        e.rank = (tid / 2) as u32;
        e.bytes = args.get("bytes").and_then(Json::as_f64).ok_or("args missing bytes")? as u64;
        e.passive = args.get("passive").and_then(Json::as_bool).unwrap_or(false);
        if let Some(v) = args.get("v").and_then(Json::as_f64) {
            e.version = v as u64;
        }
        if let Some(p) = args.get("phase").and_then(Json::as_f64) {
            e.phase = p as u32;
        }
        if let Some(p) = args.get("peer").and_then(Json::as_f64) {
            e.peer = p as u32;
        }
        out.push(e);
    }
    Ok(out)
}

/// Validate that a document conforms to the event schema every producer
/// in this crate (engine, workers, bench, simulator) emits: the property
/// test runs this over both simulator-emitted and measured-emitted
/// traces.
pub fn validate_schema(doc: &Json) -> Result<(), String> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    for (i, ev) in events.iter().enumerate() {
        let fail = |msg: &str| Err(format!("event {i}: {msg}"));
        let Some(ph) = ev.get("ph").and_then(Json::as_str) else {
            return fail("missing ph");
        };
        match ph {
            "M" => {
                let name = ev.get("name").and_then(Json::as_str).unwrap_or("");
                if !matches!(name, "process_name" | "thread_name") {
                    return fail("unknown metadata record");
                }
            }
            "X" => {
                let name = ev.get("name").and_then(Json::as_str).unwrap_or("");
                if TraceKind::parse(name).is_none() {
                    return fail(&format!("unknown span kind {name:?}"));
                }
                let cat = ev.get("cat").and_then(Json::as_str).unwrap_or("");
                if Lane::parse(cat).is_none() {
                    return fail(&format!("unknown lane {cat:?}"));
                }
                for key in ["ts", "dur", "pid", "tid"] {
                    if ev.get(key).and_then(Json::as_f64).is_none() {
                        return fail(&format!("missing numeric {key:?}"));
                    }
                }
                let Some(args) = ev.get("args") else {
                    return fail("missing args");
                };
                if args.get("bytes").and_then(Json::as_f64).is_none() {
                    return fail("args missing numeric \"bytes\"");
                }
                if args.get("passive").and_then(Json::as_bool).is_none() {
                    return fail("args missing boolean \"passive\"");
                }
            }
            other => return fail(&format!("unsupported phase {other:?}")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        let mut a = TraceEvent::new(TraceKind::Compute, Lane::App, 1_000, 2_000_000);
        a.rank = 0;
        a.version = 7;
        let mut b = TraceEvent::new(TraceKind::GroupExchangePhase, Lane::Engine, 2_001_500, 350_000);
        b.rank = 1;
        b.version = 7;
        b.phase = 2;
        b.bytes = 65536;
        b.passive = true;
        b.peer = 3;
        let mut c = TraceEvent::new(TraceKind::Wait, Lane::App, 2_001_000, 400_123);
        c.rank = 1;
        vec![a, b, c]
    }

    #[test]
    fn round_trips_through_json_text() {
        let events = sample_events();
        let doc = to_chrome(&events, "test");
        // Through the actual serializer and parser, not just the tree.
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        validate_schema(&reparsed).unwrap();
        let back = from_chrome(&reparsed).unwrap();
        assert_eq!(back, events);
    }

    #[test]
    fn emits_thread_and_process_metadata() {
        let doc = to_chrome(&sample_events(), "bench fig4");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
            .collect();
        assert!(names.contains(&"bench fig4"));
        assert!(names.contains(&"rank 0 app"));
        assert!(names.contains(&"rank 1 engine"));
    }

    #[test]
    fn multi_process_export_assigns_distinct_pids() {
        let evs = sample_events();
        let doc = to_chrome_multi(&[("fig4", &evs[..]), ("fig7", &evs[..])]);
        validate_schema(&doc).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let pids: std::collections::BTreeSet<i64> = events
            .iter()
            .filter_map(|e| e.get("pid").and_then(Json::as_f64))
            .map(|p| p as i64)
            .collect();
        assert_eq!(pids.into_iter().collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn validate_rejects_foreign_schema() {
        let bad = Json::parse(r#"{"traceEvents":[{"name":"blorp","cat":"app","ph":"X","ts":0,"dur":1,"pid":0,"tid":0,"args":{"bytes":0,"passive":false}}]}"#).unwrap();
        assert!(validate_schema(&bad).is_err());
        let missing_args = Json::parse(
            r#"{"traceEvents":[{"name":"wait","cat":"app","ph":"X","ts":0,"dur":1,"pid":0,"tid":0}]}"#,
        )
        .unwrap();
        assert!(validate_schema(&missing_args).is_err());
        assert!(validate_schema(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn sentinel_fields_are_omitted_not_mangled() {
        let ev = TraceEvent::new(TraceKind::Publish, Lane::App, 5, 10);
        let doc = to_chrome(&[ev], "t");
        let txt = doc.to_string();
        assert!(!txt.contains("18446744073709"), "NO_VERSION must not leak into JSON");
        assert!(!txt.contains("4294967295"), "NO_PEER/NO_PHASE must not leak into JSON");
        let back = from_chrome(&Json::parse(&txt).unwrap()).unwrap();
        assert_eq!(back[0].version, NO_VERSION);
        assert_eq!(back[0].phase, NO_PHASE);
        assert_eq!(back[0].peer, super::super::NO_PEER);
    }

    #[test]
    fn overlay_marks_survive_schema_and_parse() {
        let events = sample_events();
        let marks = vec![false, true, false];
        let doc = to_chrome_overlay(&events, &marks, "overlay");
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        validate_schema(&reparsed).unwrap();
        // on_path is an overlay annotation: parsing ignores it, so the
        // events round-trip unchanged.
        assert_eq!(from_chrome(&reparsed).unwrap(), events);
        let spans: Vec<&Json> = reparsed
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        let marked: Vec<bool> = spans
            .iter()
            .map(|e| {
                e.get("args")
                    .and_then(|a| a.get("on_path"))
                    .and_then(Json::as_bool)
                    .unwrap_or(false)
            })
            .collect();
        assert_eq!(marked, marks);
    }
}
