//! Always-on tracing: per-rank event timelines with typed spans.
//!
//! Every rank records fixed-size [`TraceEvent`]s into a two-lane
//! [`TraceRecorder`] — one ring per lane (application thread, engine
//! thread), lock-split exactly like the engine's mailbox so the two
//! threads never contend on a recording. Rings have fixed capacity and
//! drop the **oldest** event on overflow (a counter reports how many were
//! lost); events are `Copy` and the rings are pre-allocated, so the
//! steady-state recording path performs zero allocations — cheap enough
//! to leave on by default, matching the engine data path's contract.
//!
//! The same event schema is emitted by three producers:
//!
//! * the collective engine (`collectives/engine.rs`) — one
//!   [`TraceKind::GroupExchangePhase`] span per butterfly phase (tagged
//!   with bytes-on-wire and the activation-vs-passive role), one
//!   [`TraceKind::TauSync`] span per every-τ barrier, plus aggregated
//!   `Wait`/`Encode`/`Decode` sub-spans nested inside them;
//! * the optimizer workers and the measured bench — `Compute`, `Publish`
//!   and app-side `Wait` spans (the app `Wait` span *is* the rank's
//!   exposed communication time);
//! * the simulator — the identical schema derived from its analytic
//!   timeline, so one tool ([`attrib`]) can diff simulated vs. measured
//!   overlap component by component.
//!
//! Export is Chrome trace-event JSON ([`chrome`]), viewable in
//! `chrome://tracing` or Perfetto; [`hist`] holds the log-bucketed
//! histogram registry that replaces ad-hoc percentile math in the bench.

pub mod attrib;
pub mod causal;
pub mod chrome;
pub mod critpath;
pub mod hist;

use std::sync::{Mutex, OnceLock};
use std::time::Instant;

pub use attrib::{attribute, diff_json, render_diff, Attribution};
pub use causal::CausalGraph;
pub use chrome::{from_chrome, to_chrome, to_chrome_multi, to_chrome_overlay, validate_schema};
pub use critpath::{critical_path, critical_path_events, explain, Class, CritPath};
pub use hist::{
    bucket_bounds, bucket_of, percentile_sorted, HistogramRegistry, LogHistogram, N_BUCKETS,
};

/// Sentinel: event not associated with a collective version.
pub const NO_VERSION: u64 = u64::MAX;
/// Sentinel: event not associated with a butterfly phase / ring segment.
pub const NO_PHASE: u32 = u32::MAX;
/// Sentinel: event not associated with (or caused by) a peer rank.
pub const NO_PEER: u32 = u32::MAX;

/// Per-lane ring capacity (events). At the bench/train scales in this
/// repo a rank records a handful of events per iteration, so 8 Ki events
/// per lane covers thousands of iterations before drop-oldest kicks in.
pub const TRACE_RING_CAPACITY: usize = 8192;

/// Typed span kinds — the closed event schema shared by the engine, the
/// workers, the bench, and the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TraceKind {
    /// Application forward/backward (or bench busy-loop) work.
    Compute,
    /// Installing a contribution into the engine send slot.
    Publish,
    /// One butterfly phase of a group allreduce (engine lane).
    GroupExchangePhase,
    /// The every-τ global synchronization (engine lane).
    TauSync,
    /// Codec encode time (compression), nested in its exchange span.
    Encode,
    /// Codec decode/decompress-sum time, nested in its exchange span.
    Decode,
    /// Blocked time. App lane: waiting on a collective result (this is
    /// the rank's exposed communication). Engine lane: blocked in a
    /// matched receive waiting for a peer (nested in its exchange span).
    Wait,
    /// An injected-fault / degraded event (engine lane or simulator):
    /// a butterfly phase completed as identity because its peer was
    /// dead or suspect, a rank crash took effect, or the simulator
    /// charged a fault penalty. The span duration is the degraded time
    /// (deadline burned waiting on a missing peer; 0 for instantaneous
    /// markers like plan-declared deaths).
    Fault,
}

/// Number of span kinds (array-indexed registries).
pub const N_KINDS: usize = 8;

impl TraceKind {
    pub const ALL: [TraceKind; N_KINDS] = [
        TraceKind::Compute,
        TraceKind::Publish,
        TraceKind::GroupExchangePhase,
        TraceKind::TauSync,
        TraceKind::Encode,
        TraceKind::Decode,
        TraceKind::Wait,
        TraceKind::Fault,
    ];

    pub fn index(self) -> usize {
        match self {
            TraceKind::Compute => 0,
            TraceKind::Publish => 1,
            TraceKind::GroupExchangePhase => 2,
            TraceKind::TauSync => 3,
            TraceKind::Encode => 4,
            TraceKind::Decode => 5,
            TraceKind::Wait => 6,
            TraceKind::Fault => 7,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Compute => "compute",
            TraceKind::Publish => "publish",
            TraceKind::GroupExchangePhase => "group_exchange_phase",
            TraceKind::TauSync => "tau_sync",
            TraceKind::Encode => "encode",
            TraceKind::Decode => "decode",
            TraceKind::Wait => "wait",
            TraceKind::Fault => "fault",
        }
    }

    pub fn parse(s: &str) -> Option<TraceKind> {
        TraceKind::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// Which thread of the rank recorded the event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Lane {
    /// The application (training/bench) thread.
    App,
    /// The communication engine thread.
    Engine,
}

impl Lane {
    pub const ALL: [Lane; 2] = [Lane::App, Lane::Engine];

    pub fn index(self) -> usize {
        match self {
            Lane::App => 0,
            Lane::Engine => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Lane::App => "app",
            Lane::Engine => "engine",
        }
    }

    pub fn parse(s: &str) -> Option<Lane> {
        Lane::ALL.into_iter().find(|l| l.name() == s)
    }
}

/// One recorded span. `Copy` and fixed-size so the recording ring never
/// allocates; all optional associations use numeric sentinels
/// ([`NO_VERSION`], [`NO_PHASE`]) instead of `Option` to keep the layout
/// flat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub kind: TraceKind,
    pub lane: Lane,
    /// Recording rank (stamped by the recorder).
    pub rank: u32,
    /// Span start, nanoseconds since the process-wide trace epoch.
    pub t_ns: u64,
    /// Span duration in nanoseconds.
    pub dur_ns: u64,
    /// Collective version / training iteration ([`NO_VERSION`] if none).
    pub version: u64,
    /// Butterfly phase or ring-segment index ([`NO_PHASE`] if none).
    pub phase: u32,
    /// Bytes attributed to the span: bytes-on-wire for exchange/sync
    /// spans, payload bytes for publish spans, 0 otherwise.
    pub bytes: u64,
    /// True when the rank joined this collective passively (contributed a
    /// stale buffer after a peer's activation) rather than as activator
    /// or fresh participant.
    pub passive: bool,
    /// Causal peer ([`NO_PEER`] if none): the schedule partner for
    /// exchange-phase spans, the rank whose send satisfied the blocked
    /// receive for engine `Wait` spans (carried on the wire by the comm
    /// layer's causal stamp), and the dead/suspect partner for degraded
    /// `Fault` spans — the edge anchors [`causal::CausalGraph`] stitches
    /// per-rank timelines together with.
    pub peer: u32,
}

impl TraceEvent {
    /// A span with no collective association; set `version`/`phase`/
    /// `bytes`/`passive` on the returned value as needed.
    pub fn new(kind: TraceKind, lane: Lane, t_ns: u64, dur_ns: u64) -> TraceEvent {
        TraceEvent {
            kind,
            lane,
            rank: 0,
            t_ns,
            dur_ns,
            version: NO_VERSION,
            phase: NO_PHASE,
            bytes: 0,
            passive: false,
            peer: NO_PEER,
        }
    }

    /// Span end (ns since epoch).
    pub fn end_ns(&self) -> u64 {
        self.t_ns + self.dur_ns
    }
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide trace epoch: the instant of the first `now_ns` call.
/// All ranks/threads stamp against the same epoch so cross-rank
/// timelines line up in the exported trace.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-wide trace epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Fixed-capacity ring of events: overflow overwrites the **oldest**
/// event and bumps the dropped counter. The backing `Vec` is reserved at
/// construction and never reallocates.
#[derive(Debug)]
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Index of the oldest event once the ring is full.
    head: usize,
    dropped: u64,
}

impl TraceRing {
    pub fn with_capacity(cap: usize) -> TraceRing {
        TraceRing { buf: Vec::with_capacity(cap), cap, head: 0, dropped: 0 }
    }

    pub fn push(&mut self, ev: TraceEvent) {
        if self.cap == 0 {
            self.dropped += 1;
        } else if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Drain surviving events oldest-first, leaving the ring empty (the
    /// dropped counter is preserved).
    pub fn drain(&mut self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        self.buf.clear();
        self.head = 0;
        out
    }
}

struct LaneState {
    ring: TraceRing,
    hist: HistogramRegistry,
}

/// Per-rank recorder: one ring + histogram registry per lane, each behind
/// its own mutex (lock-split — the app and engine threads record into
/// disjoint locks and never contend). Disabled recorders no-op without
/// touching any lock state beyond the initial flag check.
pub struct TraceRecorder {
    rank: u32,
    enabled: bool,
    lanes: [Mutex<LaneState>; 2],
}

impl TraceRecorder {
    pub fn new(rank: u32, enabled: bool, capacity: usize) -> TraceRecorder {
        let mk = || {
            Mutex::new(LaneState {
                ring: TraceRing::with_capacity(if enabled { capacity } else { 0 }),
                hist: HistogramRegistry::default(),
            })
        };
        TraceRecorder { rank, enabled, lanes: [mk(), mk()] }
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one span (the recorder stamps its own rank). No-op when
    /// tracing is disabled.
    pub fn record(&self, mut ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        ev.rank = self.rank;
        let mut lane = self.lanes[ev.lane.index()].lock().unwrap();
        lane.hist.record(ev.kind, ev.dur_ns);
        lane.ring.push(ev);
    }

    /// Total events lost to ring overflow, both lanes.
    pub fn dropped(&self) -> u64 {
        self.lanes.iter().map(|l| l.lock().unwrap().ring.dropped()).sum()
    }

    /// Drain both lanes, merged and sorted by start time.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for l in &self.lanes {
            out.extend(l.lock().unwrap().ring.drain());
        }
        out.sort_by_key(|e| (e.t_ns, e.lane.index(), e.kind.index()));
        out
    }

    /// Merged duration histograms over both lanes (survives `drain`).
    pub fn histograms(&self) -> HistogramRegistry {
        let mut out = HistogramRegistry::default();
        for l in &self.lanes {
            out.merge(&l.lock().unwrap().hist);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64) -> TraceEvent {
        TraceEvent::new(TraceKind::Compute, Lane::App, t, 1)
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let mut r = TraceRing::with_capacity(4);
        for t in 0..10 {
            r.push(ev(t));
        }
        assert_eq!(r.dropped(), 6);
        assert_eq!(r.len(), 4);
        let out = r.drain();
        // Drop-oldest: the survivors are the newest 4, in order.
        let ts: Vec<u64> = out.iter().map(|e| e.t_ns).collect();
        assert_eq!(ts, vec![6, 7, 8, 9]);
        assert!(r.is_empty());
        assert_eq!(r.dropped(), 6, "drain preserves the dropped counter");
    }

    #[test]
    fn ring_order_preserved_below_capacity() {
        let mut r = TraceRing::with_capacity(8);
        for t in [3u64, 1, 4, 1, 5] {
            r.push(ev(t));
        }
        assert_eq!(r.dropped(), 0);
        let ts: Vec<u64> = r.drain().iter().map(|e| e.t_ns).collect();
        assert_eq!(ts, vec![3, 1, 4, 1, 5], "insertion order, not sorted");
    }

    #[test]
    fn zero_capacity_ring_drops_everything() {
        let mut r = TraceRing::with_capacity(0);
        for t in 0..5 {
            r.push(ev(t));
        }
        assert_eq!(r.dropped(), 5);
        assert!(r.drain().is_empty());
    }

    #[test]
    fn recorder_stamps_rank_and_merges_lanes_sorted() {
        let rec = TraceRecorder::new(3, true, 16);
        rec.record(TraceEvent::new(TraceKind::Wait, Lane::Engine, 20, 5));
        rec.record(TraceEvent::new(TraceKind::Compute, Lane::App, 10, 5));
        let out = rec.drain();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|e| e.rank == 3));
        assert_eq!(out[0].kind, TraceKind::Compute);
        assert_eq!(out[1].kind, TraceKind::Wait);
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let rec = TraceRecorder::new(0, false, 16);
        for t in 0..100 {
            rec.record(ev(t));
        }
        assert!(rec.drain().is_empty());
        assert_eq!(rec.histograms().kind(TraceKind::Compute).count(), 0);
    }

    #[test]
    fn histograms_survive_drain() {
        let rec = TraceRecorder::new(0, true, 4);
        for t in 0..10 {
            rec.record(ev(t));
        }
        let _ = rec.drain();
        // All 10 durations were histogrammed even though 6 events dropped.
        assert_eq!(rec.histograms().kind(TraceKind::Compute).count(), 10);
        assert_eq!(rec.dropped(), 6);
    }

    #[test]
    fn kind_and_lane_names_round_trip() {
        for k in TraceKind::ALL {
            assert_eq!(TraceKind::parse(k.name()), Some(k));
        }
        for l in Lane::ALL {
            assert_eq!(Lane::parse(l.name()), Some(l));
        }
        assert_eq!(TraceKind::parse("nope"), None);
    }
}
