//! Experiment presets: one per paper figure/table, mapping the evaluation
//! section's parameters onto simulator and training configurations
//! (DESIGN.md §4 experiment index).

use crate::compress::Compression;
use crate::data::ImbalanceModel;
use crate::optim::Algorithm;
use crate::sched::FusionConfig;
use crate::simulator::{NetworkModel, SimConfig};

/// A named, fully-specified experiment.
#[derive(Debug, Clone)]
pub struct ExperimentPreset {
    pub name: &'static str,
    pub description: &'static str,
    /// Node counts swept (throughput figures).
    pub node_counts: &'static [usize],
    /// Per-rank batch size (samples per iteration) for throughput.
    pub batch: usize,
    /// Flat model parameter count (payload size = 4 bytes each).
    pub model_params: usize,
    pub tau: u64,
    pub imbalance: ImbalanceModel,
    /// Algorithms compared in this figure.
    pub algos: &'static [Algorithm],
    pub steps: usize,
    /// Fusion/overlap knobs (flat by default so the paper figures are
    /// reproduced unchanged; the fusion figure/bench flips `layered` on).
    pub fusion: FusionConfig,
    /// Per-bucket wire compression (off by default for the same reason;
    /// the compression figure/bench turns it on explicitly).
    pub compress: Compression,
}

const FIG4_ALGOS: &[Algorithm] = &[
    Algorithm::Wagma,
    Algorithm::AllreduceSgd,
    Algorithm::LocalSgd,
    Algorithm::DPsgd,
    Algorithm::Sgp,
    Algorithm::EagerSgd,
    Algorithm::AdPsgd,
];

const FIG7_ALGOS: &[Algorithm] = &[
    Algorithm::Wagma,
    Algorithm::AllreduceSgd,
    Algorithm::LocalSgd,
    Algorithm::DPsgd,
    Algorithm::Sgp,
    Algorithm::AdPsgd,
];

const FIG10_ALGOS: &[Algorithm] = &[
    Algorithm::Wagma,
    Algorithm::LocalSgd,
    Algorithm::DPsgd,
    Algorithm::Sgp,
    Algorithm::AdPsgd,
];

/// Look up a preset by figure id.
pub fn preset(name: &str) -> Option<ExperimentPreset> {
    let p = match name {
        // Fig. 4: ResNet-50/ImageNet throughput, b=128, 320 ms on 2 ranks.
        "fig4" => ExperimentPreset {
            name: "fig4",
            description: "ResNet-50 throughput vs P with simulated load imbalance (b=128)",
            node_counts: &[4, 16, 64, 256],
            batch: 128,
            model_params: 25_559_081,
            tau: 10,
            imbalance: ImbalanceModel::fig4(),
            algos: FIG4_ALGOS,
            steps: 200,
            fusion: FusionConfig::default(),
            compress: Compression::None,
        },
        // Fig. 7: Transformer/WMT17 throughput (τ=8, bucketed lengths).
        "fig7" => ExperimentPreset {
            name: "fig7",
            description: "Transformer throughput vs P with bucketed sentence-length imbalance",
            node_counts: &[4, 16, 64],
            batch: 8192, // tokens per local batch
            model_params: 61_362_176,
            tau: 8,
            imbalance: ImbalanceModel::fig7(),
            algos: FIG7_ALGOS,
            steps: 200,
            fusion: FusionConfig::default(),
            compress: Compression::None,
        },
        // Fig. 10: DDPPO/Habitat throughput (heavy-tailed collection).
        "fig10" => ExperimentPreset {
            name: "fig10",
            description: "DDPPO throughput vs P with heavy-tailed experience collection",
            node_counts: &[16, 64, 256, 1024],
            batch: 256, // experience steps per iteration
            model_params: 8_476_421,
            tau: 8,
            imbalance: ImbalanceModel::fig9(),
            algos: FIG10_ALGOS,
            steps: 100,
            fusion: FusionConfig::default(),
            compress: Compression::None,
        },
        _ => return None,
    };
    Some(p)
}

pub fn preset_names() -> &'static [&'static str] {
    &["fig4", "fig7", "fig10"]
}

impl ExperimentPreset {
    /// Simulator configuration for one (algorithm, node count) cell.
    pub fn sim_config(&self, algo: Algorithm, p: usize, seed: u64) -> SimConfig {
        SimConfig {
            algo,
            p,
            steps: self.steps,
            model_bytes: self.model_params * 4,
            tau: self.tau,
            group_size: 0, // √P (paper default)
            dynamic_groups: true,
            local_sgd_h: 1,
            sgp_neighbors: if self.name == "fig10" { 4 } else { 2 },
            imbalance: self.imbalance,
            net: NetworkModel::aries(),
            seed,
            fusion: self.fusion,
            compress: self.compress,
            trace: false,
            faults: crate::fault::FaultPlan::none(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist_and_are_paper_shaped() {
        for name in preset_names() {
            let p = preset(name).unwrap();
            assert!(!p.node_counts.is_empty());
            assert!(p.node_counts.iter().all(|n| n.is_power_of_two()));
            assert!(p.algos.contains(&Algorithm::Wagma));
        }
        assert!(preset("bogus").is_none());
        // Paper parameters spot-checks.
        let f4 = preset("fig4").unwrap();
        assert_eq!(f4.tau, 10);
        assert_eq!(f4.model_params, 25_559_081);
        let f10 = preset("fig10").unwrap();
        assert_eq!(*f10.node_counts.last().unwrap(), 1024);
    }

    #[test]
    fn sim_config_wiring() {
        let p = preset("fig7").unwrap();
        let cfg = p.sim_config(Algorithm::Sgp, 16, 1);
        assert_eq!(cfg.p, 16);
        assert_eq!(cfg.tau, 8);
        assert_eq!(cfg.model_bytes, 61_362_176 * 4);
        assert_eq!(cfg.sgp_neighbors, 2);
        let p10 = preset("fig10").unwrap();
        assert_eq!(p10.sim_config(Algorithm::Sgp, 16, 1).sgp_neighbors, 4);
    }
}
