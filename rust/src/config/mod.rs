//! Configuration system: a TOML-subset parser (offline environment — no
//! `toml` crate) plus experiment presets for every paper figure.
//!
//! Supported TOML subset (everything the presets use): `[section]` tables,
//! `key = value` with strings, integers, floats, booleans, and arrays of
//! scalars; `#` comments.

pub mod preset;
pub mod toml;

pub use preset::{preset, preset_names, ExperimentPreset};
pub use toml::TomlDoc;
