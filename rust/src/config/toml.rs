//! Minimal TOML parser: flat `[section]`s of scalar/array key-values.

use std::collections::BTreeMap;

/// A scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `sections["section"]["key"]`. Top-level keys live in
/// the empty-string section.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = parse_value(value.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), v);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue, String> {
    if let Some(body) = v.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(body.to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = v.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let items = body
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(parse_value)
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Array(items));
    }
    if !v.contains('.') && !v.contains('e') && !v.contains('E') {
        if let Ok(i) = v.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    v.parse::<f64>().map(TomlValue::Float).map_err(|_| format!("cannot parse value {v:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_presets_shape() {
        let doc = TomlDoc::parse(
            r#"
            # experiment config
            name = "fig4"
            [train]
            algo = "wagma"   # the paper's optimizer
            p = 64
            lr = 0.05
            dynamic = true
            sizes = [4, 16, 64]
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("", "name", ""), "fig4");
        assert_eq!(doc.i64_or("train", "p", 0), 64);
        assert_eq!(doc.f64_or("train", "lr", 0.0), 0.05);
        assert!(doc.bool_or("train", "dynamic", false));
        match doc.get("train", "sizes").unwrap() {
            TomlValue::Array(a) => assert_eq!(a.len(), 3),
            other => panic!("{other:?}"),
        }
        // Defaults for missing keys.
        assert_eq!(doc.i64_or("train", "missing", 7), 7);
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = TomlDoc::parse(r##"key = "a#b" # comment"##).unwrap();
        assert_eq!(doc.str_or("", "key", ""), "a#b");
    }

    #[test]
    fn errors_are_reported_with_line_numbers() {
        assert!(TomlDoc::parse("[unclosed").unwrap_err().contains("line 1"));
        assert!(TomlDoc::parse("novalue").unwrap_err().contains("line 1"));
        assert!(TomlDoc::parse("x = @@").is_err());
    }
}
