//! # WAGMA-SGD — Wait-Avoiding Group Model Averaging
//!
//! Production-quality reproduction of *"Breaking (Global) Barriers in
//! Parallel Stochastic Optimization with Wait-Avoiding Group Averaging"*
//! (Li et al., IEEE TPDS 2020).
//!
//! The library is organized in three layers:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: the
//!   wait-avoiding group allreduce ([`collectives::engine`]), the dynamic
//!   grouping strategy ([`topology::grouping`]), WAGMA-SGD and six baseline
//!   distributed optimizers ([`optim`]), the layer-aware gradient fusion
//!   and communication-overlap scheduler ([`sched`]: MG-WFBP-style bucket
//!   planning over per-layer backprop profiles), per-bucket gradient
//!   compression with error feedback ([`compress`]: top-k / 8-bit
//!   quantized wire encodings carried zero-copy through the engine),
//!   deterministic fault injection and elastic membership ([`fault`]:
//!   seeded crash/stall/skew/jitter plans consumed by both the engine
//!   and the simulator), a discrete-event cluster
//!   simulator for at-scale experiments ([`simulator`], with a layered mode
//!   that consumes the bucket timeline instead of one flat payload), a
//!   long-running sweep service that shards simulator grids across a
//!   worker pool behind a caching HTTP API ([`serve`]), and
//!   the PJRT runtime that executes AOT-compiled models ([`runtime`]).
//!   [`coordinator`] gathers the scheduler-facing coordination API behind
//!   one import path.
//! * **L2 (python/compile/model.py)** — JAX model definitions (transformer
//!   LM, MLP classifier, RL policy) lowered once to HLO text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots, verified against a pure-jnp oracle.
//!
//! Python never runs at training time: the Rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API and drives everything.

pub mod bench;
pub mod collectives;
pub mod compress;
pub mod coordinator;
pub mod figures;
pub mod comm;
pub mod config;
pub mod data;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod rl;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod simulator;
pub mod telemetry;
pub mod topology;
pub mod trace;
pub mod util;
