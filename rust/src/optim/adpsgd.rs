//! AD-PSGD (Lian et al. 2018): asynchronous decentralized pairwise
//! averaging. Ranks never synchronize globally: after each local gradient
//! computation a rank picks a uniformly random partner and the pair
//! atomically averages their models. Communication fully overlaps compute,
//! giving the highest raw throughput of all baselines — and, as the paper's
//! Fig. 5/11 show, the worst final accuracy.
//!
//! In-process realization: models live in shared slots
//! (`Arc<Vec<Mutex<...>>>`); pairwise atomic averaging takes both locks in
//! index order (deadlock-free).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metrics::{RankMetrics, StepRecord};
use crate::model::WorkerState;
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;
use crate::optim::sgd_momentum_update;
use crate::util::rng::Xoshiro256;

/// Shared model slots, one per rank.
pub type SharedModels = Arc<Vec<Mutex<Vec<f32>>>>;

pub fn make_shared(p: usize, init: &[f32]) -> SharedModels {
    Arc::new((0..p).map(|_| Mutex::new(init.to_vec())).collect())
}

pub fn run_worker(
    rank: usize,
    shared: SharedModels,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let p = cfg.p;
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ (rank as u64 + 1).wrapping_mul(0x9E37));
    let mut metrics = RankMetrics { rank, ..Default::default() };
    // Momentum stays rank-local (only the model is averaged).
    let mut momentum = vec![0.0f32; cfg.init.len()];
    let run_start = Instant::now();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        // Compute the gradient at the *current* model snapshot (communication
        // from concurrent averaging may change it before the update lands —
        // AD-PSGD's defining staleness).
        let snapshot = shared[rank].lock().unwrap().clone();
        let (g, loss) = engine.grad(&snapshot, t);

        // Atomic pairwise averaging with a random partner.
        if p > 1 {
            let mut partner = rng.usize_below(p - 1);
            if partner >= rank {
                partner += 1;
            }
            let (lo, hi) = (rank.min(partner), rank.max(partner));
            let (first, rest) = shared.split_at(hi);
            let mut a = first[lo].lock().unwrap();
            let mut b = rest[0].lock().unwrap();
            for i in 0..a.len() {
                let avg = 0.5 * (a[i] + b[i]);
                a[i] = avg;
                b[i] = avg;
            }
        }

        // Apply the (possibly stale) local gradient to our own slot.
        {
            let mut w = shared[rank].lock().unwrap();
            sgd_momentum_update(&mut w, &mut momentum, &g, cfg.lr);
        }

        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness: 0 });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            let w = shared[rank].lock().unwrap().clone();
            if let Some(v) = engine.eval(&w) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    // Model bytes moved: one model per step to the partner (accounting
    // parity with the message-passing algorithms).
    metrics.sent_msgs = cfg.steps;
    metrics.sent_bytes = cfg.steps * (cfg.init.len() * 4) as u64;
    let final_params = shared[rank].lock().unwrap().clone();
    let mut state = WorkerState::new(final_params.clone());
    state.momentum = momentum;
    (metrics, final_params)
}
