//! Stochastic Gradient Push (Assran et al. 2019): push-sum gossip over a
//! directed exponential graph.
//!
//! Each rank maintains a biased model `x` and a push-sum weight `w`; the
//! de-biased estimate is `z = x / w`. Per iteration: one SGD step on `z`
//! applied to `x`, then push `1/(k+1)` of `(x, w)` to each of `k`
//! out-neighbors on the time-varying exponential graph
//! `out_i(t) = (i + 2^((t·k + j) mod log2 P)) mod P`, and absorb whatever
//! arrived from in-neighbors. Mass conservation (Σx, Σw invariants) is
//! checked by the property tests.

use std::time::Instant;

use crate::comm::{Endpoint, Tag};
use crate::metrics::{RankMetrics, StepRecord};
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;
use crate::optim::sgd_momentum_update;
use crate::topology::log2_exact;

/// Out-neighbor offsets at step `t` for `k` neighbors.
fn offsets(t: u64, k: usize, log_p: u32) -> Vec<usize> {
    (0..k).map(|j| 1usize << ((t as usize * k + j) % log_p as usize) as u32).collect()
}

pub fn run_worker(
    mut ep: Endpoint,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let rank = ep.rank();
    let p = cfg.p;
    let k = cfg.sgp_neighbors.max(1);
    let log_p = if p > 1 { log2_exact(p) } else { 1 };
    let dim = cfg.init.len();

    // Push-sum state: x (biased model), w (weight). z = x / w.
    let mut x = cfg.init.clone();
    let mut w = 1.0f32;
    let mut momentum = vec![0.0f32; dim];
    let mut z = vec![0.0f32; dim];
    let mut metrics = RankMetrics { rank, ..Default::default() };
    let run_start = Instant::now();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        // De-bias, take the SGD step on z, fold back into x.
        let inv_w = 1.0 / w;
        for i in 0..dim {
            z[i] = x[i] * inv_w;
        }
        let (g, loss) = engine.grad(&z, t);
        sgd_momentum_update(&mut z, &mut momentum, &g, cfg.lr);
        for i in 0..dim {
            x[i] = z[i] * w;
        }

        if p > 1 {
            // Push: split (x, w) into k+1 shares; one share per out-neighbor.
            let share = 1.0 / (k as f32 + 1.0);
            let offs = offsets(t, k, log_p);
            // Message payload = x-share followed by the w-share.
            let mut payload: Vec<f32> = x.iter().map(|v| v * share).collect();
            payload.push(w * share);
            for (j, off) in offs.iter().enumerate() {
                let dst = (rank + off) % p;
                ep.send(dst, Tag::p2p(t, j as u32), payload.clone());
            }
            for v in x.iter_mut() {
                *v *= share;
            }
            w *= share;
            // Absorb from in-neighbors (the graph is regular: in-degree k).
            for (j, off) in offs.iter().enumerate() {
                let src = (rank + p - off % p) % p;
                let msg = ep.recv_data(src, Tag::p2p(t, j as u32), |_, m| {
                    panic!("unexpected ctrl in sgp: {m:?}")
                });
                for i in 0..dim {
                    x[i] += msg[i];
                }
                w += msg[dim];
            }
        }

        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness: 0 });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            let inv_w = 1.0 / w;
            let z_now: Vec<f32> = x.iter().map(|v| v * inv_w).collect();
            if let Some(v) = engine.eval(&z_now) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    metrics.sent_msgs = ep.sent_msgs;
    metrics.sent_bytes = ep.sent_bytes;
    // Report the de-biased model.
    let inv_w = 1.0 / w;
    let z_final: Vec<f32> = x.iter().map(|v| v * inv_w).collect();
    (metrics, z_final)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_offsets_cycle() {
        let offs: Vec<Vec<usize>> = (0..6).map(|t| offsets(t, 1, 3)).collect();
        assert_eq!(offs, vec![vec![1], vec![2], vec![4], vec![1], vec![2], vec![4]]);
        let two: Vec<usize> = offsets(0, 2, 4);
        assert_eq!(two, vec![1, 2]);
    }
}
