//! Distributed optimizers: WAGMA-SGD (Algorithm 2) and the six baselines
//! the paper compares against (Table I, bold entries).
//!
//! | Algorithm       | Coordination        | Staleness | Averages |
//! |-----------------|---------------------|-----------|----------|
//! | Allreduce-SGD   | decentralized, S=P  | none      | gradients|
//! | Local SGD (H)   | decentralized, S=P  | none      | models   |
//! | D-PSGD          | ring, S=3           | none      | models   |
//! | AD-PSGD         | pairwise, S=2       | unbounded | models   |
//! | PairAveraging   | hypercube pair, S=2 | none      | models   |
//! | SGP             | directed exp., S=k+1| none      | models (push-sum) |
//! | eager-SGD       | global partial      | bounded   | gradients|
//! | **WAGMA-SGD**   | **group, S=√P**     | **bounded (τ)** | **models** |
//!
//! Every optimizer runs the same worker skeleton: a [`ComputeEngine`]
//! produces local steps/gradients (backed by PJRT artifacts, an analytic
//! objective, or a no-op + sleep for throughput studies) and the algorithm
//! supplies the communication pattern.
//!
//! **Scheduling subsystem** ([`crate::sched`]): exchanges need not be one
//! flat payload. [`TrainConfig::fusion`] carries the layer-aware fusion
//! knobs (`layered`, `fusion_mode`, `fusion_threshold_bytes`); with
//! `layered = true` the collective engine streams bucketed exchanges at
//! the plan's granularity, and the at-scale simulator consumes the bucket
//! timeline (per-layer backprop ready times → per-bucket collective
//! start/finish) so communication overlaps the backward pass the way
//! MG-WFBP/DaSGD describe. Flat remains the default, reproducing the
//! seed's results bit-for-bit.

pub mod adpsgd;
pub mod allreduce_sgd;
pub mod dpsgd;
pub mod eager_sgd;
pub mod engine;
pub mod local_sgd;
pub mod pair_avg;
pub mod pjrt_engine;
pub mod runner;
pub mod sgp;
pub mod wagma;

pub use engine::{ComputeEngine, EngineFactory, NullEngine, QuadraticEngine, SleepEngine};
pub use runner::{run_training, Algorithm, TrainConfig};

use crate::util;

/// Momentum coefficient used by all Rust-side update rules. Must match
/// `MOMENTUM` in `python/compile/kernels/ref.py` (the fused Pallas
/// optimizer), so the Rust-applied and artifact-applied updates agree.
pub const MOMENTUM: f32 = 0.9;

/// Heavy-ball SGD update applied Rust-side (used by the gradient-averaging
/// algorithms where the update happens *after* communication):
/// `m = MOMENTUM*m + g; p -= lr*m`.
pub fn sgd_momentum_update(params: &mut [f32], momentum: &mut [f32], grad: &[f32], lr: f32) {
    debug_assert_eq!(params.len(), grad.len());
    debug_assert_eq!(momentum.len(), grad.len());
    for ((p, m), g) in params.iter_mut().zip(momentum.iter_mut()).zip(grad.iter()) {
        *m = MOMENTUM * *m + *g;
        *p -= lr * *m;
    }
}

/// Average `src` into `dst` with weight `1/k` each (model averaging step).
pub fn average_into(dst: &mut [f32], others: &[&[f32]]) {
    let k = (others.len() + 1) as f32;
    let inv = 1.0 / k;
    for (i, d) in dst.iter_mut().enumerate() {
        let mut sum = *d;
        for o in others {
            sum += o[i];
        }
        *d = sum * inv;
    }
}

/// Re-export of the shared vector helpers for optimizer implementations.
pub use util::{add_assign, add_scale, axpy_neg, scale};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_update_matches_reference() {
        let mut p = vec![1.0f32, 2.0];
        let mut m = vec![0.5f32, 0.0];
        sgd_momentum_update(&mut p, &mut m, &[1.0, -1.0], 0.1);
        // m = 0.9*0.5 + 1 = 1.45 ; p = 1 - 0.145
        assert!((m[0] - 1.45).abs() < 1e-6);
        assert!((p[0] - 0.855).abs() < 1e-6);
        assert!((m[1] + 1.0).abs() < 1e-6);
        assert!((p[1] - 2.1).abs() < 1e-6);
    }

    #[test]
    fn average_into_means() {
        let mut a = vec![1.0f32, 4.0];
        let b = vec![3.0f32, 0.0];
        let c = vec![5.0f32, 2.0];
        average_into(&mut a, &[&b, &c]);
        assert_eq!(a, vec![3.0, 2.0]);
    }
}
