//! Allreduce-SGD: the standard synchronous data-parallel baseline.
//! Gradients are globally averaged with a blocking allreduce every
//! iteration; every rank applies the identical update, so models stay
//! bit-identical (asserted in tests).

use std::time::Instant;

use crate::collectives::allreduce::{allreduce, AllreduceAlgo};
use crate::comm::Endpoint;
use crate::metrics::{RankMetrics, StepRecord};
use crate::model::WorkerState;
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;
use crate::optim::sgd_momentum_update;

pub fn run_worker(
    mut ep: Endpoint,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let rank = ep.rank();
    let p = cfg.p as f32;
    let mut state = WorkerState::new(cfg.init.clone());
    let mut metrics = RankMetrics { rank, ..Default::default() };
    let run_start = Instant::now();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        let (mut g, loss) = engine.grad(&state.params, t);
        allreduce(&mut ep, &mut g, t, AllreduceAlgo::Auto);
        for gi in g.iter_mut() {
            *gi /= p;
        }
        sgd_momentum_update(&mut state.params, &mut state.momentum, &g, cfg.lr);
        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness: 0 });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            if let Some(v) = engine.eval(&state.params) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    metrics.sent_msgs = ep.sent_msgs;
    metrics.sent_bytes = ep.sent_bytes;
    (metrics, state.params)
}
