//! D-PSGD (Lian et al. 2017): synchronous gossip on a ring. Each iteration
//! every rank takes a local step, then averages its model with its two ring
//! neighbors (quorum size 3). Processes advance with a single global clock
//! (each step blocks on both neighbors).

use std::time::Instant;

use crate::comm::{Endpoint, Tag};
use crate::metrics::{RankMetrics, StepRecord};
use crate::model::WorkerState;
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;

pub fn run_worker(
    mut ep: Endpoint,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let rank = ep.rank();
    let p = cfg.p;
    let left = (rank + p - 1) % p;
    let right = (rank + 1) % p;
    let mut state = WorkerState::new(cfg.init.clone());
    let mut metrics = RankMetrics { rank, ..Default::default() };
    let run_start = Instant::now();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        let loss = engine.step(&mut state, cfg.lr, t);
        if p > 1 {
            // phase 0: clockwise traffic (to right / from left);
            // phase 1: counter-clockwise.
            ep.send(right, Tag::p2p(t, 0), state.params.clone());
            ep.send(left, Tag::p2p(t, 1), state.params.clone());
            let from_left = ep.recv_data(left, Tag::p2p(t, 0), |_, m| {
                panic!("unexpected ctrl in dpsgd: {m:?}")
            });
            let from_right = ep.recv_data(right, Tag::p2p(t, 1), |_, m| {
                panic!("unexpected ctrl in dpsgd: {m:?}")
            });
            for i in 0..state.params.len() {
                state.params[i] = (state.params[i] + from_left[i] + from_right[i]) / 3.0;
            }
        }
        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness: 0 });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            if let Some(v) = engine.eval(&state.params) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    metrics.sent_msgs = ep.sent_msgs;
    metrics.sent_bytes = ep.sent_bytes;
    (metrics, state.params)
}
