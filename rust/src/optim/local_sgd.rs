//! Local SGD: H local heavy-ball steps, then a blocking global model
//! average (Stich 2019; Lin et al. 2018). With H = 1 this is synchronous
//! model-averaging SGD; the paper's ablation ❶ (WAGMA without group
//! collectives) is exactly Local SGD with H = τ.

use std::time::Instant;

use crate::collectives::allreduce::{allreduce, AllreduceAlgo};
use crate::comm::Endpoint;
use crate::metrics::{RankMetrics, StepRecord};
use crate::model::WorkerState;
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;

pub fn run_worker(
    mut ep: Endpoint,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let rank = ep.rank();
    let p = cfg.p as f32;
    let h = cfg.local_sgd_h.max(1);
    let mut state = WorkerState::new(cfg.init.clone());
    let mut metrics = RankMetrics { rank, ..Default::default() };
    let run_start = Instant::now();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        let loss = engine.step(&mut state, cfg.lr, t);
        if (t + 1) % h == 0 {
            allreduce(&mut ep, &mut state.params, t, AllreduceAlgo::Auto);
            for w in state.params.iter_mut() {
                *w /= p;
            }
        }
        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness: 0 });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            if let Some(v) = engine.eval(&state.params) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    metrics.sent_msgs = ep.sent_msgs;
    metrics.sent_bytes = ep.sent_bytes;
    (metrics, state.params)
}
