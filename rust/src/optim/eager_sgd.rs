//! eager-SGD (Li et al. 2020, PPoPP): solo/majority-activated *partial*
//! allreduce on **gradients**. Every iteration runs a global collective,
//! but the collective is externally triggerable — late ranks contribute
//! stale gradients instead of blocking the fast ones.
//!
//! Realized on the wait-avoiding engine with group size S = P (one global
//! group): the activation machinery and passive stale contributions are
//! identical to WAGMA's; only the payload (gradients, not models) and the
//! update rule differ. The τ-periodic synchronous allreduce bounds
//! staleness, as in the paper's bounded-staleness classification.

use std::time::Instant;

use crate::collectives::engine::CollectiveEngine;
use crate::compress::ErrorFeedback;
use crate::metrics::{RankMetrics, StepRecord};
use crate::model::WorkerState;
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;
use crate::optim::sgd_momentum_update;
use crate::trace::{now_ns, Lane, TraceEvent, TraceKind};
use crate::util::add_assign;

pub fn run_worker(
    handle: CollectiveEngine,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let rank = handle.rank();
    let p = cfg.p as f32;
    let mut state = WorkerState::new(cfg.init.clone());
    let mut metrics = RankMetrics { rank, ..Default::default() };
    let run_start = Instant::now();

    // Error-feedback residual for compressed gradient publishes (the
    // deep-gradient-compression recipe: fold the previous iteration's
    // compression loss into this iteration's gradient before encoding).
    let mut ef = ErrorFeedback::new();
    let tracer = handle.tracer();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        let c0 = now_ns();
        let (g, loss) = engine.grad(&state.params, t);
        let mut ev = TraceEvent::new(TraceKind::Compute, Lane::App, c0, now_ns() - c0);
        ev.version = t;
        tracer.record(ev);
        if cfg.compress.is_none() {
            // One counted copy into a pooled buffer; `g` itself is kept
            // for the stale blend below, so a move is not possible.
            handle.publish(&g, t);
        } else {
            let mut gw = g.clone();
            if handle.config().is_sync_iter(t) {
                // Exact/rank-identical sync: deliver the delayed mass,
                // charge no new residual (see wagma.rs).
                ef.drain_into(&mut gw);
            } else {
                let chunk = handle.config().effective_chunk(gw.len());
                ef.fold_chunked(cfg.compress, &mut gw, chunk);
            }
            handle.publish_owned(gw, t);
        }

        let (g_avg, staleness): (Vec<f32>, u64) = if handle.config().is_sync_iter(t) {
            let sum = handle.global_sync(t);
            (sum.into_iter().map(|x| x / p).collect(), 0)
        } else {
            let res = handle.group_allreduce(t);
            let staleness = res.staleness(t);
            if res.is_fresh(t) {
                (res.sum.into_iter().map(|x| x / p).collect(), 0)
            } else {
                // Our fresh gradient missed the collective; blend it in
                // (the stale one we contributed keeps the average unbiased
                // in expectation, as in the paper's partial collectives).
                let mut sum = res.sum;
                add_assign(&mut sum, &g);
                (sum.into_iter().map(|x| x / (p + 1.0)).collect(), staleness)
            }
        };
        sgd_momentum_update(&mut state.params, &mut state.momentum, &g_avg, cfg.lr);

        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            if let Some(v) = engine.eval(&state.params) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    let stats = handle.shutdown();
    metrics.sent_msgs = stats.sent_msgs;
    metrics.sent_bytes = stats.sent_bytes;
    metrics.trace = tracer.drain();
    (metrics, state.params)
}
