//! WAGMA-SGD (paper Algorithm 2): wait-avoiding group model averaging.
//!
//! Per iteration `t` each rank:
//! 1. computes a local heavy-ball SGD update `W'_t` (lines 3–7);
//! 2. publishes `W'_t` into the engine's send buffer;
//! 3. on sync iterations (`(t+1) % τ == 0`): joins the global synchronous
//!    allreduce and sets `W_{t+1} = sync_allreduce(W'_t) / P` (line 16);
//! 4. otherwise joins the wait-avoiding group allreduce:
//!    * if its fresh `W'_t` made the collective: `W_{t+1} = W_sum / S`
//!      (line 11);
//!    * if the collective ran before it arrived (it contributed a stale
//!      model passively): `W_{t+1} = (W_sum + W'_t) / (S+1)` (line 13).

use std::time::Instant;

use crate::collectives::engine::CollectiveEngine;
use crate::compress::ErrorFeedback;
use crate::metrics::{RankMetrics, StepRecord};
use crate::model::WorkerState;
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;
use crate::trace::{now_ns, Lane, TraceEvent, TraceKind};
use crate::util::add_assign;

/// Run one WAGMA-SGD worker to completion. `handle` is this rank's
/// wait-avoiding collective engine; `engine` its compute engine.
pub fn run_worker(
    handle: CollectiveEngine,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let rank = handle.rank();
    let p = cfg.p as f32;
    let s = cfg.resolved_group_size() as f32;
    let mut state = WorkerState::new(cfg.init.clone());
    let mut metrics = RankMetrics { rank, ..Default::default() };
    // With wire compression on, the published contribution carries the
    // error-feedback residual of the previous lossy publish (dropped mass
    // is delayed into the next iteration, never lost). The engine encodes
    // per bucket on the wire; the worker's residual tracks the loss of its
    // own contribution as the group sees it.
    let mut ef = ErrorFeedback::new();
    let tracer = handle.tracer();
    let run_start = Instant::now();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        let c0 = now_ns();
        // Lines 3–7: local update W'_t.
        let loss = engine.step(&mut state, cfg.lr, t);
        let mut ev = TraceEvent::new(TraceKind::Compute, Lane::App, c0, now_ns() - c0);
        ev.version = t;
        tracer.record(ev);
        if cfg.compress.is_none() {
            // One counted copy into a pooled buffer. The app must retain
            // W'_t for the stale blend below, so a move (`publish_owned`)
            // is not possible — but the seed's extra
            // `state.params.clone()` is gone.
            handle.publish(&state.params, t);
        } else {
            // The clone the exact path avoids is the residual-folded
            // payload here: W'_t stays untouched for the stale blend.
            let mut w = state.params.clone();
            if handle.config().is_sync_iter(t) {
                // The every-τ sync carries the contribution in full:
                // deliver the delayed mass, charge no new residual
                // (folding the group-path roundtrip here would re-inject
                // mass the sync never dropped).
                ef.drain_into(&mut w);
            } else {
                let chunk = handle.config().effective_chunk(w.len());
                ef.fold_chunked(cfg.compress, &mut w, chunk);
            }
            handle.publish_owned(w, t);
        }

        let staleness;
        if handle.config().is_sync_iter(t) {
            // Line 16: global model averaging (bounds staleness by τ).
            let sum = handle.global_sync(t);
            state.params = sum.into_iter().map(|x| x / p).collect();
            staleness = 0;
        } else {
            // Lines 9–14: wait-avoiding group model averaging.
            let res = handle.group_allreduce(t);
            staleness = res.staleness(t);
            if res.is_fresh(t) {
                // Fresh contribution: W = W_sum / S.
                state.params = res.sum.into_iter().map(|x| x / s).collect();
            } else {
                // Stale contribution: W = (W_sum + W'_t) / (S+1), where
                // `state.params` still holds W'_t.
                let mut sum = res.sum;
                add_assign(&mut sum, &state.params);
                state.params = sum.into_iter().map(|x| x / (s + 1.0)).collect();
            }
        }

        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            if let Some(v) = engine.eval(&state.params) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    let stats = handle.shutdown();
    metrics.sent_msgs = stats.sent_msgs;
    metrics.sent_bytes = stats.sent_bytes;
    metrics.trace = tracer.drain();
    (metrics, state.params)
}
