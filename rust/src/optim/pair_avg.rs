//! Pair averaging: the simplest decentralized model-averaging baseline.
//! Each iteration every rank takes a local step, then averages its model
//! with exactly ONE partner — the rotating hypercube neighbor
//! `rank ^ (1 << (t mod log2 P))` — so over any window of log2 P steps
//! information from every rank mixes into every other (a deterministic,
//! synchronous cousin of AD-PSGD's random pairwise gossip). Quorum size 2:
//! each step blocks on a single partner, which makes the algorithm cheap
//! but *fault-brittle* — one dead rank stalls its partner every iteration,
//! the property the elastic-membership comparison exercises.

use std::time::Instant;

use crate::comm::{Endpoint, Tag};
use crate::metrics::{RankMetrics, StepRecord};
use crate::model::WorkerState;
use crate::optim::engine::ComputeEngine;
use crate::optim::runner::TrainConfig;
use crate::topology::log2_exact;

/// The deterministic rotating hypercube partner of `rank` at iteration `t`
/// (`p` must be a power of two; with `p == 1` there is no partner).
pub fn partner_of(rank: usize, t: u64, p: usize) -> usize {
    let log_p = log2_exact(p);
    rank ^ (1usize << (t % u64::from(log_p)) as usize)
}

pub fn run_worker(
    mut ep: Endpoint,
    mut engine: Box<dyn ComputeEngine>,
    cfg: &TrainConfig,
) -> (RankMetrics, Vec<f32>) {
    let rank = ep.rank();
    let p = cfg.p;
    let mut state = WorkerState::new(cfg.init.clone());
    let mut metrics = RankMetrics { rank, ..Default::default() };
    let run_start = Instant::now();

    for t in 0..cfg.steps {
        let t0 = Instant::now();
        let loss = engine.step(&mut state, cfg.lr, t);
        if p > 1 {
            let partner = partner_of(rank, t, p);
            ep.send(partner, Tag::p2p(t, 0), state.params.clone());
            let theirs = ep.recv_data(partner, Tag::p2p(t, 0), |_, m| {
                panic!("unexpected ctrl in pair_avg: {m:?}")
            });
            for (mine, other) in state.params.iter_mut().zip(&theirs) {
                *mine = (*mine + *other) * 0.5;
            }
        }
        metrics.steps.push(StepRecord { t, loss, wall: t0.elapsed().as_secs_f64(), staleness: 0 });
        if cfg.eval_every != 0 && (t + 1) % cfg.eval_every == 0 {
            if let Some(v) = engine.eval(&state.params) {
                metrics.evals.push((t, v));
            }
        }
    }

    metrics.total_seconds = run_start.elapsed().as_secs_f64();
    metrics.sent_msgs = ep.sent_msgs;
    metrics.sent_bytes = ep.sent_bytes;
    (metrics, state.params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partner_is_an_involution_and_rotates() {
        let p = 8;
        for t in 0..6u64 {
            for rank in 0..p {
                let q = partner_of(rank, t, p);
                assert_ne!(q, rank);
                assert_eq!(partner_of(q, t, p), rank, "pairing must be symmetric");
            }
        }
        // The partner dimension rotates with period log2 P.
        assert_eq!(partner_of(0, 0, p), 1);
        assert_eq!(partner_of(0, 1, p), 2);
        assert_eq!(partner_of(0, 2, p), 4);
        assert_eq!(partner_of(0, 3, p), 1);
    }
}
