//! Compute engines backed by AOT PJRT artifacts: supervised (LM /
//! classifier) and reinforcement learning (PPO rollouts + policy updates).
//!
//! Constructed inside worker threads via [`EngineFactory`] closures
//! (the PJRT client is thread-local by construction).

use crate::data::{ClassifyDataset, TokenCorpus};
use crate::model::{Batch, DataArg, WorkerState};
use crate::optim::engine::ComputeEngine;
use crate::rl::env::{GridWorld, ACTIONS, OBS_DIM};
use crate::rl::ppo::{collect_rollout, RolloutConfig};
use crate::runtime::ModelRuntime;
use crate::util::rng::Xoshiro256;

/// Supervised engine: LM (token corpus) or classifier (Gaussian clusters),
/// chosen by the artifact's `kind`.
pub struct PjrtEngine {
    rt: ModelRuntime,
    feed: Feed,
    eval_batch: Option<Batch>,
}

enum Feed {
    Lm(TokenCorpus),
    Classify(ClassifyDataset),
}

impl PjrtEngine {
    /// Build for artifact `model` with rank-sharded synthetic data.
    pub fn new(artifacts_dir: &str, model: &str, rank: usize, seed: u64) -> anyhow::Result<PjrtEngine> {
        let rt = ModelRuntime::load(artifacts_dir, model)?;
        let meta = &rt.meta;
        let (feed, eval_batch) = match meta.kind.as_str() {
            "lm" => {
                let mut held_out = TokenCorpus::new(
                    meta.dims["vocab"],
                    meta.dims["seq_len"],
                    meta.batch,
                    seed,
                    usize::MAX, // shard no training rank uses
                );
                let corpus =
                    TokenCorpus::new(meta.dims["vocab"], meta.dims["seq_len"], meta.batch, seed, rank);
                (Feed::Lm(corpus), Some(held_out.next_batch()))
            }
            "classifier" => {
                // Noise scales with the class count so the larger
                // convergence-figure config (mlp_small, 16 classes) does
                // not saturate at 100% for every optimizer — the accuracy
                // separation is what Fig. 5 measures.
                let noise = if meta.dims["classes"] >= 16 { 2.6 } else { 0.35 };
                let ds = ClassifyDataset::new(
                    meta.dims["input_dim"],
                    meta.dims["classes"],
                    meta.batch,
                    noise,
                    seed,
                    rank,
                );
                let eval = ds.eval_batch(meta.batch);
                (Feed::Classify(ds), Some(eval))
            }
            other => anyhow::bail!("PjrtEngine: unsupported kind {other:?} (use RlEngine)"),
        };
        Ok(PjrtEngine { rt, feed, eval_batch })
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    fn next_batch(&mut self) -> Batch {
        match &mut self.feed {
            Feed::Lm(c) => c.next_batch(),
            Feed::Classify(d) => d.next_batch(),
        }
    }
}

impl ComputeEngine for PjrtEngine {
    fn dim(&self) -> usize {
        self.rt.meta.param_count
    }

    fn step(&mut self, state: &mut WorkerState, lr: f32, _t: u64) -> f32 {
        let batch = self.next_batch();
        self.rt
            .step(&mut state.params, &mut state.momentum, &batch, lr)
            .expect("PJRT step failed")
    }

    fn grad(&mut self, params: &[f32], _t: u64) -> (Vec<f32>, f32) {
        let batch = self.next_batch();
        self.rt.grad(params, &batch).expect("PJRT grad failed")
    }

    fn eval(&mut self, params: &[f32]) -> Option<f32> {
        let b = self.eval_batch.as_ref()?;
        Some(self.rt.eval_metric(params, b).expect("PJRT eval failed"))
    }
}

/// PPO optimization epochs per rollout.
const PPO_EPOCHS: usize = 3;

/// RL engine: every `step` is one DD-PPO-style iteration — collect a
/// rollout from vectorized gridworld environments with the *current*
/// policy, then one PPO update through the artifact. Experience-collection
/// time is naturally heavy-tailed (episode lengths vary with procedural
/// difficulty), reproducing the paper's Fig. 9 mechanism organically.
pub struct RlEngine {
    rt: ModelRuntime,
    envs: Vec<GridWorld>,
    ep_returns: Vec<f32>,
    rcfg: RolloutConfig,
    rng: Xoshiro256,
    /// Rolling episode statistics from the most recent rollouts.
    pub last_mean_return: f32,
    pub last_mean_spl: f32,
}

impl RlEngine {
    pub fn new(artifacts_dir: &str, model: &str, rank: usize, seed: u64) -> anyhow::Result<RlEngine> {
        let rt = ModelRuntime::load(artifacts_dir, model)?;
        anyhow::ensure!(rt.meta.kind == "policy", "RlEngine needs a policy artifact");
        let batch = rt.meta.batch;
        // envs * horizon must equal the artifact's train batch. A longer
        // horizon gives GAE more to work with on sparse goals.
        let envs_n = 16.min(batch);
        let horizon = batch / envs_n;
        let rcfg = RolloutConfig { envs: envs_n, horizon, gamma: 0.97, lam: 0.9 };
        let envs = (0..envs_n)
            .map(|i| GridWorld::new(seed ^ ((rank * 1000 + i) as u64).wrapping_mul(0x9E37)))
            .collect();
        Ok(RlEngine {
            rt,
            envs,
            ep_returns: vec![0.0; envs_n],
            rcfg,
            rng: Xoshiro256::seed_from_u64(seed ^ (rank as u64 + 77)),
            last_mean_return: 0.0,
            last_mean_spl: 0.0,
        })
    }

    fn rollout(&mut self, params: &[f32]) -> Batch {
        let rt = &self.rt;
        let artifact_batch = rt.meta.batch;
        let mut policy = |obs: &[f32], rows: usize| -> (Vec<f32>, Vec<f32>) {
            // Pad the observation matrix up to the artifact's fixed batch.
            let mut padded = obs.to_vec();
            padded.resize(artifact_batch * OBS_DIM, 0.0);
            let arg = DataArg::f32(vec![artifact_batch, OBS_DIM], padded);
            let (logp, value) = rt.policy_forward(params, &arg).expect("policy forward");
            (logp[..rows * ACTIONS].to_vec(), value[..rows].to_vec())
        };
        let pb = collect_rollout(
            &mut policy,
            &mut self.envs,
            &mut self.ep_returns,
            &self.rcfg,
            &mut self.rng,
        );
        if pb.episodes_finished > 0 {
            self.last_mean_return = pb.mean_return;
            self.last_mean_spl = pb.mean_spl;
        }
        pb.batch
    }
}

impl ComputeEngine for RlEngine {
    fn dim(&self) -> usize {
        self.rt.meta.param_count
    }

    fn step(&mut self, state: &mut WorkerState, lr: f32, _t: u64) -> f32 {
        let batch = self.rollout(&state.params);
        // Multiple PPO epochs over the same rollout (the clipped surrogate
        // exists precisely to allow this).
        let mut loss = 0.0;
        for _ in 0..PPO_EPOCHS {
            loss = self
                .rt
                .step(&mut state.params, &mut state.momentum, &batch, lr)
                .expect("PJRT PPO step failed");
        }
        loss
    }

    fn grad(&mut self, params: &[f32], _t: u64) -> (Vec<f32>, f32) {
        let batch = self.rollout(params);
        self.rt.grad(params, &batch).expect("PJRT PPO grad failed")
    }

    /// Proper policy evaluation: play `EVAL_EPISODES` fresh episodes to
    /// completion with the current policy and report the mean undiscounted
    /// return. (Rollout-internal episode stats are censoring-biased: early
    /// in training only short, successful episodes finish inside a
    /// horizon.)
    fn eval(&mut self, params: &[f32]) -> Option<f32> {
        const EVAL_EPISODES: usize = 16;
        let rt = &self.rt;
        let artifact_batch = rt.meta.batch;
        let mut envs: Vec<GridWorld> =
            (0..EVAL_EPISODES).map(|i| GridWorld::new(0xE7A1 + i as u64)).collect();
        let mut returns = vec![0.0f32; EVAL_EPISODES];
        let mut spl = vec![0.0f32; EVAL_EPISODES];
        let mut done = vec![false; EVAL_EPISODES];
        let mut rng = Xoshiro256::seed_from_u64(0x5EED);
        for _ in 0..400 {
            if done.iter().all(|&d| d) {
                break;
            }
            let mut obs = vec![0.0f32; artifact_batch * OBS_DIM];
            for (i, env) in envs.iter().enumerate() {
                obs[i * OBS_DIM..(i + 1) * OBS_DIM].copy_from_slice(&env.observe());
            }
            let arg = DataArg::f32(vec![artifact_batch, OBS_DIM], obs);
            let (logp, _) = rt.policy_forward(params, &arg).expect("eval policy forward");
            for (i, env) in envs.iter_mut().enumerate() {
                if done[i] {
                    continue;
                }
                let row = &logp[i * ACTIONS..(i + 1) * ACTIONS];
                // Sample (the trained policy is stochastic).
                let u = rng.next_f32();
                let mut acc = 0.0;
                let mut a = ACTIONS - 1;
                for (j, lp) in row.iter().enumerate() {
                    acc += lp.exp();
                    if u < acc {
                        a = j;
                        break;
                    }
                }
                let o = env.step(a);
                returns[i] += o.reward;
                if o.done {
                    done[i] = true;
                    spl[i] = env.spl(o.success);
                }
            }
        }
        self.last_mean_spl = spl.iter().sum::<f32>() / EVAL_EPISODES as f32;
        Some(returns.iter().sum::<f32>() / EVAL_EPISODES as f32)
    }
}
