//! Training launcher: spawns one worker thread per rank for any algorithm
//! and merges the per-rank metrics into a [`TrainResult`].

use std::str::FromStr;
use std::sync::Arc;
use std::time::Instant;

use crate::collectives::allreduce::AllreduceAlgo;
use crate::collectives::engine::{ActivationMode, CollectiveEngine, EngineConfig};
use crate::comm::world;
use crate::compress::Compression;
use crate::fault::FaultPlan;
use crate::metrics::TrainResult;
use crate::telemetry::TelemetryRegistry;
use crate::optim::engine::EngineFactory;
use crate::optim::{adpsgd, allreduce_sgd, dpsgd, eager_sgd, local_sgd, pair_avg, sgp, wagma};
use crate::sched::FusionConfig;
use crate::topology::Grouping;

/// The distributed SGD variants (Table I, bold set + WAGMA).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Wagma,
    AllreduceSgd,
    LocalSgd,
    DPsgd,
    AdPsgd,
    Sgp,
    EagerSgd,
    /// One-partner model averaging on a rotating hypercube pairing
    /// (robustness baseline: cheapest coordination, most fault-brittle).
    PairAveraging,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Wagma => "wagma",
            Algorithm::AllreduceSgd => "allreduce_sgd",
            Algorithm::LocalSgd => "local_sgd",
            Algorithm::DPsgd => "dpsgd",
            Algorithm::AdPsgd => "adpsgd",
            Algorithm::Sgp => "sgp",
            Algorithm::EagerSgd => "eager_sgd",
            Algorithm::PairAveraging => "pair_avg",
        }
    }

    pub fn all() -> [Algorithm; 8] {
        [
            Algorithm::Wagma,
            Algorithm::AllreduceSgd,
            Algorithm::LocalSgd,
            Algorithm::DPsgd,
            Algorithm::AdPsgd,
            Algorithm::Sgp,
            Algorithm::EagerSgd,
            Algorithm::PairAveraging,
        ]
    }
}

impl FromStr for Algorithm {
    type Err = String;

    fn from_str(s: &str) -> Result<Algorithm, String> {
        match s {
            "wagma" | "wagma_sgd" | "wagma-sgd" => Ok(Algorithm::Wagma),
            "allreduce" | "allreduce_sgd" | "allreduce-sgd" => Ok(Algorithm::AllreduceSgd),
            "local" | "local_sgd" | "local-sgd" => Ok(Algorithm::LocalSgd),
            "dpsgd" | "d-psgd" => Ok(Algorithm::DPsgd),
            "adpsgd" | "ad-psgd" => Ok(Algorithm::AdPsgd),
            "sgp" => Ok(Algorithm::Sgp),
            "eager" | "eager_sgd" | "eager-sgd" => Ok(Algorithm::EagerSgd),
            "pair" | "pair_avg" | "pair-avg" | "pair_averaging" => Ok(Algorithm::PairAveraging),
            other => Err(format!("unknown algorithm {other:?}")),
        }
    }
}

/// Full configuration of a training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub algo: Algorithm,
    pub p: usize,
    pub steps: u64,
    pub lr: f32,
    /// WAGMA / eager-SGD synchronization period τ (0 = never sync).
    pub tau: u64,
    /// WAGMA group size S (0 = the paper default √P).
    pub group_size: usize,
    /// Dynamic (paper) vs fixed (ablation ❷) grouping.
    pub dynamic_groups: bool,
    /// Local SGD averaging period H.
    pub local_sgd_h: u64,
    /// SGP out-degree (paper evaluates 1 and 2).
    pub sgp_neighbors: usize,
    pub seed: u64,
    /// Evaluate the task metric every N steps (0 = never).
    pub eval_every: u64,
    /// Gradient-fusion knobs: with `layered = true` the collective engine
    /// streams exchanges as fused buckets ([`crate::sched`]) instead of
    /// one flat payload.
    pub fusion: FusionConfig,
    /// Per-bucket wire compression for the engine-backed algorithms
    /// (WAGMA, eager-SGD). Workers carry an error-feedback residual so
    /// dropped mass is delayed, not lost; the direct-mode baselines run
    /// uncompressed (their synchronous exchanges are the exact reference
    /// points the paper compares against).
    pub compress: Compression,
    /// Initial model, identical on every rank.
    pub init: Vec<f32>,
    /// Live-telemetry registry: when set, engine-backed algorithms
    /// (WAGMA, eager-SGD) publish steps/wait/staleness/wire/membership
    /// into it at steady state. The direct-mode baselines run
    /// uninstrumented (they bypass the collective engine). `None` is
    /// bit-identical to an uninstrumented run.
    pub telemetry: Option<Arc<TelemetryRegistry>>,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            algo: Algorithm::Wagma,
            p: 4,
            steps: 100,
            lr: 0.05,
            tau: 10,
            group_size: 0,
            dynamic_groups: true,
            local_sgd_h: 1,
            sgp_neighbors: 2,
            seed: 42,
            eval_every: 0,
            fusion: FusionConfig::default(),
            compress: Compression::None,
            init: Vec::new(),
            telemetry: None,
        }
    }
}

impl TrainConfig {
    /// Group size with the paper's √P default applied.
    pub fn resolved_group_size(&self) -> usize {
        if self.group_size == 0 {
            Grouping::sqrt_group_size(self.p)
        } else {
            self.group_size
        }
    }

    fn engine_config(&self, group_size: usize) -> EngineConfig {
        EngineConfig {
            p: self.p,
            group_size,
            tau: self.tau,
            dynamic_groups: self.dynamic_groups,
            sync_algo: AllreduceAlgo::Auto,
            // eager-SGD uses the PPoPP'20 majority collectives; WAGMA the
            // solo (wait-avoiding) activation.
            activation: if self.algo == Algorithm::EagerSgd {
                ActivationMode::Majority
            } else {
                ActivationMode::Solo
            },
            // Layered mode streams fused buckets through the engine as
            // independently-tagged chunks at the plan's granularity.
            chunk_elems: self.fusion.chunk_elems(),
            compression: self.compress,
            trace: true,
            recv_deadline_ns: 0,
            recv_retries: 0,
        }
    }
}

/// Run a full training job: spawn P workers, execute `cfg.steps`
/// iterations of `cfg.algo`, and merge metrics. `factory(rank)` builds each
/// rank's compute engine inside its thread.
pub fn run_training(cfg: &TrainConfig, factory: EngineFactory) -> TrainResult {
    assert!(cfg.p.is_power_of_two(), "P must be a power of two (paper assumption)");
    assert!(!cfg.init.is_empty(), "TrainConfig.init must hold the initial model");
    let start = Instant::now();

    let mut handles = Vec::with_capacity(cfg.p);
    match cfg.algo {
        Algorithm::Wagma | Algorithm::EagerSgd => {
            let group_size = if cfg.algo == Algorithm::EagerSgd {
                cfg.p // eager-SGD: one global partial collective
            } else {
                cfg.resolved_group_size()
            };
            let ecfg = cfg.engine_config(group_size);
            for ep in world(cfg.p) {
                let rank = ep.rank();
                let cfg = cfg.clone();
                let factory = factory.clone();
                // Seed the engine's send buffer with the initial model
                // (WAGMA) or zero gradients (eager-SGD).
                let init_buf = if cfg.algo == Algorithm::Wagma {
                    cfg.init.clone()
                } else {
                    vec![0.0; cfg.init.len()]
                };
                let handle = CollectiveEngine::spawn_instrumented(
                    ep,
                    ecfg,
                    init_buf,
                    Arc::new(FaultPlan::none()),
                    cfg.telemetry.clone(),
                );
                handles.push(std::thread::spawn(move || {
                    let engine = factory(rank);
                    match cfg.algo {
                        Algorithm::Wagma => wagma::run_worker(handle, engine, &cfg),
                        _ => eager_sgd::run_worker(handle, engine, &cfg),
                    }
                }));
            }
        }
        Algorithm::AllreduceSgd
        | Algorithm::LocalSgd
        | Algorithm::DPsgd
        | Algorithm::Sgp
        | Algorithm::PairAveraging => {
            for ep in world(cfg.p) {
                let rank = ep.rank();
                let cfg = cfg.clone();
                let factory = factory.clone();
                handles.push(std::thread::spawn(move || {
                    let engine = factory(rank);
                    match cfg.algo {
                        Algorithm::AllreduceSgd => allreduce_sgd::run_worker(ep, engine, &cfg),
                        Algorithm::LocalSgd => local_sgd::run_worker(ep, engine, &cfg),
                        Algorithm::DPsgd => dpsgd::run_worker(ep, engine, &cfg),
                        Algorithm::PairAveraging => pair_avg::run_worker(ep, engine, &cfg),
                        _ => sgp::run_worker(ep, engine, &cfg),
                    }
                }));
            }
        }
        Algorithm::AdPsgd => {
            let shared = adpsgd::make_shared(cfg.p, &cfg.init);
            for rank in 0..cfg.p {
                let cfg = cfg.clone();
                let factory = factory.clone();
                let shared = shared.clone();
                handles.push(std::thread::spawn(move || {
                    let engine = factory(rank);
                    adpsgd::run_worker(rank, shared, engine, &cfg)
                }));
            }
        }
    }

    let mut per_rank = Vec::with_capacity(cfg.p);
    let mut final_params = Vec::with_capacity(cfg.p);
    for h in handles {
        let (metrics, params) = h.join().expect("worker panicked");
        per_rank.push(metrics);
        final_params.push(params);
    }
    per_rank.sort_by_key(|m| m.rank);

    TrainResult {
        algo: cfg.algo.name().to_string(),
        p: cfg.p,
        per_rank,
        final_params,
        wall_seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::engine::QuadraticEngine;
    use std::sync::Arc;

    fn quad_factory(p: usize, dim: usize, noise: f32, seed: u64) -> EngineFactory {
        Arc::new(move |rank| Box::new(QuadraticEngine::new(dim, rank, p, noise, seed)))
    }

    fn run(algo: Algorithm, p: usize, steps: u64) -> TrainResult {
        let dim = 16;
        let cfg = TrainConfig {
            algo,
            p,
            steps,
            lr: 0.05,
            tau: 10,
            init: vec![0.0; dim],
            ..Default::default()
        };
        run_training(&cfg, quad_factory(p, dim, 0.05, 42))
    }

    #[test]
    fn every_algorithm_reduces_global_loss() {
        // Convergence smoke for all 8 optimizers: distance of the mean
        // final model to the known global optimum must be small.
        let opt = QuadraticEngine::global_optimum(16, 42);
        for algo in Algorithm::all() {
            let r = run(algo, 4, 400);
            let mut mean = vec![0.0f32; 16];
            for fp in &r.final_params {
                for (m, v) in mean.iter_mut().zip(fp) {
                    *m += v / r.final_params.len() as f32;
                }
            }
            let dist: f32 =
                mean.iter().zip(&opt).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            // Initial distance is ~4; with a constant lr and heterogeneous
            // local objectives, model-averaging variants settle into a
            // small lr-proportional neighbourhood of the optimum.
            assert!(dist < 0.8, "{}: final distance {dist}", algo.name());
            assert_eq!(r.per_rank.len(), 4);
            assert_eq!(r.per_rank[0].steps.len(), 400);
        }
    }

    /// End-to-end through the compressed engine path with error feedback:
    /// training still converges into a small neighbourhood of the optimum
    /// and the every-τ sync keeps models consistent (small payloads take
    /// the exact sync path, so post-sync divergence is ~0).
    #[test]
    fn compressed_training_converges_and_syncs_consistently() {
        let dim = 16;
        let opt = QuadraticEngine::global_optimum(dim, 42);
        for comp in [Compression::TopK { ratio: 0.5 }, Compression::QuantizeQ8] {
            let cfg = TrainConfig {
                algo: Algorithm::Wagma,
                p: 4,
                steps: 400,
                lr: 0.05,
                tau: 10,
                compress: comp,
                init: vec![0.0; dim],
                ..Default::default()
            };
            let r = run_training(&cfg, quad_factory(4, dim, 0.05, 42));
            // Last iteration (t=399, tau=10) is a sync point.
            assert!(
                r.model_divergence() < 1e-5,
                "{comp:?}: post-sync divergence {}",
                r.model_divergence()
            );
            let mut mean = vec![0.0f32; dim];
            for fp in &r.final_params {
                for (m, v) in mean.iter_mut().zip(fp) {
                    *m += v / r.final_params.len() as f32;
                }
            }
            let dist: f32 =
                mean.iter().zip(&opt).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
            // Wider neighbourhood than the exact path (lossy averaging
            // oscillates between error-feedback corrections), but far
            // below the ~4.0 initial distance.
            assert!(dist < 2.5, "{comp:?}: final distance {dist}");
        }
    }

    /// eager-SGD's gradient path through compression + error feedback.
    #[test]
    fn compressed_eager_training_converges() {
        let dim = 16;
        let cfg = TrainConfig {
            algo: Algorithm::EagerSgd,
            p: 4,
            steps: 400,
            lr: 0.05,
            tau: 10,
            compress: Compression::TopK { ratio: 0.5 },
            init: vec![0.0; dim],
            ..Default::default()
        };
        let r = run_training(&cfg, quad_factory(4, dim, 0.05, 42));
        let opt = QuadraticEngine::global_optimum(dim, 42);
        let mut mean = vec![0.0f32; dim];
        for fp in &r.final_params {
            for (m, v) in mean.iter_mut().zip(fp) {
                *m += v / r.final_params.len() as f32;
            }
        }
        let dist: f32 =
            mean.iter().zip(&opt).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        assert!(dist < 2.0, "final distance {dist}");
    }

    #[test]
    fn allreduce_keeps_models_identical() {
        let r = run(Algorithm::AllreduceSgd, 4, 50);
        assert!(r.model_divergence() < 1e-6, "divergence {}", r.model_divergence());
    }

    #[test]
    fn wagma_models_consistent_after_sync() {
        // steps = multiple of tau => last iteration (t=49, tau=10) is a
        // sync point, so all models must coincide exactly.
        let r = run(Algorithm::Wagma, 4, 50);
        assert!(r.model_divergence() < 1e-5, "divergence {}", r.model_divergence());
    }

    #[test]
    fn layered_chunked_training_converges() {
        // End-to-end through the chunked engine path: tiny chunks (2 f32
        // elements) so every butterfly phase is streamed as many tagged
        // chunks. Sums are bitwise-identical to the flat path, so training
        // quality and post-sync consistency must match the flat contract.
        let dim = 16;
        let cfg = TrainConfig {
            algo: Algorithm::Wagma,
            p: 4,
            steps: 400,
            lr: 0.05,
            tau: 10,
            fusion: FusionConfig { layered: true, threshold_bytes: 8, ..Default::default() },
            init: vec![0.0; dim],
            ..Default::default()
        };
        let r = run_training(&cfg, quad_factory(4, dim, 0.05, 42));
        let opt = QuadraticEngine::global_optimum(dim, 42);
        let mut mean = vec![0.0f32; dim];
        for fp in &r.final_params {
            for (m, v) in mean.iter_mut().zip(fp) {
                *m += v / r.final_params.len() as f32;
            }
        }
        let dist: f32 =
            mean.iter().zip(&opt).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        assert!(dist < 0.8, "layered/chunked final distance {dist}");
        // steps = multiple of tau => run ends on a global sync.
        assert!(r.model_divergence() < 1e-5, "divergence {}", r.model_divergence());
    }

    #[test]
    fn algorithm_parsing() {
        assert_eq!("wagma".parse::<Algorithm>().unwrap(), Algorithm::Wagma);
        assert_eq!("ad-psgd".parse::<Algorithm>().unwrap(), Algorithm::AdPsgd);
        assert!("bogus".parse::<Algorithm>().is_err());
    }

    #[test]
    fn group_size_default_is_sqrt_p() {
        let cfg = TrainConfig { p: 64, ..Default::default() };
        assert_eq!(cfg.resolved_group_size(), 8);
        let cfg = TrainConfig { p: 64, group_size: 4, ..Default::default() };
        assert_eq!(cfg.resolved_group_size(), 4);
    }
}
