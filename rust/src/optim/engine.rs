//! Compute engines: the per-rank "local work" behind every optimizer.
//!
//! Engines are constructed *inside* worker threads by an [`EngineFactory`]
//! (the PJRT client is not `Send`), so the trait itself needs no `Send`.

use crate::data::StepDelays;
use crate::model::WorkerState;
use crate::optim::sgd_momentum_update;
use crate::util::rng::Xoshiro256;
use std::sync::Arc;

/// Per-rank local computation: a step (in-place update) and a gradient.
pub trait ComputeEngine {
    /// Model dimension (flat parameter count).
    fn dim(&self) -> usize;

    /// Local update (Algorithm 2 lines 3–7): in-place heavy-ball SGD on a
    /// fresh minibatch. Returns the minibatch loss.
    fn step(&mut self, state: &mut WorkerState, lr: f32, t: u64) -> f32;

    /// Gradient + loss at `params` on a fresh minibatch (for the
    /// gradient-averaging algorithms).
    fn grad(&mut self, params: &[f32], t: u64) -> (Vec<f32>, f32);

    /// Optional task metric (accuracy / eval loss / return).
    fn eval(&mut self, _params: &[f32]) -> Option<f32> {
        None
    }
}

/// Thread-safe factory: `rank -> engine`, invoked inside each worker.
pub type EngineFactory = Arc<dyn Fn(usize) -> Box<dyn ComputeEngine> + Send + Sync>;

/// Convex quadratic objective with per-rank data heterogeneity — the
/// convergence-test workhorse. Rank `i` holds
/// `f_i(w) = 0.5 * Σ_j a_j (w_j - c_{i,j})²` with shared curvature `a` and
/// rank-specific centers `c_i`; the global optimum of `F = mean_i f_i` is
/// the mean center, so tests can measure exact suboptimality. Stochastic
/// gradients add N(0, noise²) — satisfying the paper's bounded second
/// moment assumption.
pub struct QuadraticEngine {
    curvature: Vec<f32>,
    center: Vec<f32>,
    noise: f32,
    rng: Xoshiro256,
}

impl QuadraticEngine {
    pub fn new(dim: usize, rank: usize, p: usize, noise: f32, seed: u64) -> QuadraticEngine {
        // Shared curvature in [0.5, 1.5]; centers spread on a lattice so the
        // global optimum (mean center) is analytically known.
        let mut shared = Xoshiro256::seed_from_u64(seed);
        let curvature = (0..dim).map(|_| 0.5 + shared.next_f32()).collect();
        let mut center_rng = Xoshiro256::seed_from_u64(seed ^ 0xA5A5);
        let mut center = vec![0.0f32; dim];
        // Deterministic per-rank offset pattern: rank i shifts dimension j
        // by sin-like lattice values, mean over ranks = base center.
        for (j, c) in center.iter_mut().enumerate() {
            let base = center_rng.normal_f32(0.0, 1.0);
            let offset = ((rank as f32 + 1.0) * (j as f32 + 1.0)).sin();
            let mean_offset: f32 =
                (0..p).map(|r| ((r as f32 + 1.0) * (j as f32 + 1.0)).sin()).sum::<f32>()
                    / p as f32;
            *c = base + offset - mean_offset; // mean over ranks == base
        }
        QuadraticEngine {
            curvature,
            center,
            noise,
            rng: Xoshiro256::seed_from_u64(seed ^ (rank as u64 + 1).wrapping_mul(0x2545F491)),
        }
    }

    /// Exact local loss (no noise).
    pub fn loss(&self, w: &[f32]) -> f32 {
        w.iter()
            .zip(&self.center)
            .zip(&self.curvature)
            .map(|((w, c), a)| 0.5 * a * (w - c) * (w - c))
            .sum()
    }

    /// The global optimum of the mean objective when every rank is built
    /// with the same seed: the shared base center.
    pub fn global_optimum(dim: usize, seed: u64) -> Vec<f32> {
        let mut center_rng = Xoshiro256::seed_from_u64(seed ^ 0xA5A5);
        let _shared = Xoshiro256::seed_from_u64(seed); // keep stream layout documented
        (0..dim).map(|_| center_rng.normal_f32(0.0, 1.0)).collect()
    }
}

impl ComputeEngine for QuadraticEngine {
    fn dim(&self) -> usize {
        self.center.len()
    }

    fn step(&mut self, state: &mut WorkerState, lr: f32, t: u64) -> f32 {
        let (g, loss) = self.grad(&state.params, t);
        sgd_momentum_update(&mut state.params, &mut state.momentum, &g, lr);
        loss
    }

    fn grad(&mut self, params: &[f32], _t: u64) -> (Vec<f32>, f32) {
        let g = params
            .iter()
            .zip(&self.center)
            .zip(&self.curvature)
            .map(|((w, c), a)| a * (w - c) + self.rng.normal_f32(0.0, self.noise))
            .collect();
        (g, self.loss(params))
    }

    fn eval(&mut self, params: &[f32]) -> Option<f32> {
        Some(self.loss(params))
    }
}

/// No compute at all — pure-communication throughput studies. `dim`
/// controls message sizes.
pub struct NullEngine {
    dim: usize,
}

impl NullEngine {
    pub fn new(dim: usize) -> NullEngine {
        NullEngine { dim }
    }
}

impl ComputeEngine for NullEngine {
    fn dim(&self) -> usize {
        self.dim
    }

    fn step(&mut self, _state: &mut WorkerState, _lr: f32, _t: u64) -> f32 {
        0.0
    }

    fn grad(&mut self, _params: &[f32], _t: u64) -> (Vec<f32>, f32) {
        (vec![0.0; self.dim], 0.0)
    }
}

/// Wrap another engine and inject per-(step, rank) compute delays from a
/// pre-sampled imbalance schedule — the Fig. 4 protocol as real sleeps.
/// `time_scale` shrinks the paper's seconds to test-friendly durations.
pub struct SleepEngine<E> {
    inner: E,
    rank: usize,
    schedule: Arc<Vec<Vec<f64>>>,
    time_scale: f64,
}

impl<E: ComputeEngine> SleepEngine<E> {
    pub fn new(
        inner: E,
        rank: usize,
        schedule: Arc<Vec<Vec<f64>>>,
        time_scale: f64,
    ) -> SleepEngine<E> {
        SleepEngine { inner, rank, schedule, time_scale }
    }

    /// Build a shared schedule from an imbalance model.
    pub fn schedule(
        model: crate::data::ImbalanceModel,
        p: usize,
        steps: usize,
        seed: u64,
    ) -> Arc<Vec<Vec<f64>>> {
        Arc::new(StepDelays::new(model, p, seed).sample_many(steps))
    }

    fn sleep_for(&self, t: u64) {
        let row = &self.schedule[(t as usize) % self.schedule.len()];
        let secs = row[self.rank] * self.time_scale;
        if secs > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
    }
}

impl<E: ComputeEngine> ComputeEngine for SleepEngine<E> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn step(&mut self, state: &mut WorkerState, lr: f32, t: u64) -> f32 {
        self.sleep_for(t);
        self.inner.step(state, lr, t)
    }

    fn grad(&mut self, params: &[f32], t: u64) -> (Vec<f32>, f32) {
        self.sleep_for(t);
        self.inner.grad(params, t)
    }

    fn eval(&mut self, params: &[f32]) -> Option<f32> {
        self.inner.eval(params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_centers_average_to_base() {
        let dim = 16;
        let p = 8;
        let engines: Vec<QuadraticEngine> =
            (0..p).map(|r| QuadraticEngine::new(dim, r, p, 0.0, 42)).collect();
        let base = QuadraticEngine::global_optimum(dim, 42);
        for j in 0..dim {
            let mean: f32 = engines.iter().map(|e| e.center[j]).sum::<f32>() / p as f32;
            assert!((mean - base[j]).abs() < 1e-4, "dim {j}: {mean} vs {}", base[j]);
        }
    }

    #[test]
    fn quadratic_sgd_converges_single_rank() {
        let mut e = QuadraticEngine::new(8, 0, 1, 0.01, 7);
        let mut state = WorkerState::new(vec![0.0; 8]);
        let mut first = 0.0;
        let mut last = 0.0;
        for t in 0..300 {
            let loss = e.step(&mut state, 0.05, t);
            if t == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < 0.05 * first, "loss {first} -> {last}");
    }

    #[test]
    fn grad_is_unbiased_at_center() {
        let mut e = QuadraticEngine::new(4, 0, 1, 0.5, 9);
        let at = e.center.clone();
        let n = 2000;
        let mut acc = vec![0.0f64; 4];
        for t in 0..n {
            let (g, _) = e.grad(&at, t);
            for (a, gi) in acc.iter_mut().zip(g) {
                *a += gi as f64;
            }
        }
        for a in acc {
            assert!((a / n as f64).abs() < 0.05, "grad mean {a}");
        }
    }

    #[test]
    fn sleep_engine_sleeps() {
        let sched = Arc::new(vec![vec![0.01, 0.0]]);
        let mut e = SleepEngine::new(NullEngine::new(4), 0, sched, 1.0);
        let mut st = WorkerState::new(vec![0.0; 4]);
        let t0 = std::time::Instant::now();
        e.step(&mut st, 0.1, 0);
        assert!(t0.elapsed().as_secs_f64() >= 0.009);
    }
}
