//! Magnitude top-k sparsification.
//!
//! Keeps the `⌈ratio·n⌉` largest-magnitude entries as `(index, value)`
//! pairs, indices ascending. Values are carried bit-exactly, which gives
//! the two invariants the rest of the system leans on:
//!
//! * **mass conservation** — `decode(encode(g)) + residual == g`
//!   elementwise, where `residual` is `g` outside the kept set and zero
//!   inside it (the [`crate::compress::ErrorFeedback`] contract);
//! * **ratio 1.0 is exact** — all indices are kept in ascending order, so
//!   `decode_add` performs the same per-element additions in the same
//!   order as the uncompressed `sum_into` path: compressed exchanges at
//!   ratio 1.0 are bitwise-identical to uncompressed ones.

use crate::compress::{Compressor, EncodeScratch};

/// Top-k codec at a fixed keep ratio (fraction of entries kept, in
/// `(0, 1]`).
#[derive(Debug, Clone, Copy)]
pub struct TopK {
    ratio: f64,
}

impl TopK {
    pub fn new(ratio: f64) -> TopK {
        assert!(ratio > 0.0 && ratio <= 1.0, "topk ratio {ratio} outside (0, 1]");
        TopK { ratio }
    }

    /// Entries kept for an `n`-element input: `⌈ratio·n⌉`, at least 1.
    pub fn k_of(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        ((self.ratio * n as f64).ceil() as usize).clamp(1, n)
    }
}

/// Header words: element count + kept count.
const HEADER: usize = 2;

impl Compressor for TopK {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn encoded_words(&self, n: usize) -> usize {
        HEADER + 2 * self.k_of(n)
    }

    fn encode(&self, input: &[f32], out: &mut [f32], scratch: &mut EncodeScratch) {
        let n = input.len();
        let k = self.k_of(n);
        assert_eq!(out.len(), HEADER + 2 * k, "encode buffer sized by encoded_words");
        out[0] = f32::from_bits(n as u32);
        out[1] = f32::from_bits(k as u32);
        let (idx_words, val_words) = out[HEADER..].split_at_mut(k);
        if k == n {
            // Degenerate keep-everything case: no selection, exact copy.
            for (i, w) in idx_words.iter_mut().enumerate() {
                *w = f32::from_bits(i as u32);
            }
            val_words.copy_from_slice(input);
            return;
        }
        // Partial selection over a reused index workspace (allocation-free
        // at steady state), then ascending index order so decode visits
        // elements in the same order as a dense pass.
        let idx = &mut scratch.idx;
        idx.clear();
        idx.extend(0..n as u32);
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            input[b as usize].abs().total_cmp(&input[a as usize].abs())
        });
        idx[..k].sort_unstable();
        for j in 0..k {
            idx_words[j] = f32::from_bits(idx[j]);
            val_words[j] = input[idx[j] as usize];
        }
    }

    fn decode_add(&self, encoded: &[f32], dst: &mut [f32]) {
        let (n, k) = decode_header(encoded);
        assert_eq!(dst.len(), n, "decode target length");
        for j in 0..k {
            let i = encoded[HEADER + j].to_bits() as usize;
            dst[i] += encoded[HEADER + k + j];
        }
    }

    fn decode_overwrite(&self, encoded: &[f32], dst: &mut [f32]) {
        let (n, k) = decode_header(encoded);
        assert_eq!(dst.len(), n, "decode target length");
        dst.fill(0.0);
        for j in 0..k {
            let i = encoded[HEADER + j].to_bits() as usize;
            dst[i] = encoded[HEADER + k + j];
        }
    }
}

fn decode_header(encoded: &[f32]) -> (usize, usize) {
    assert!(encoded.len() >= HEADER, "truncated topk payload");
    let n = encoded[0].to_bits() as usize;
    let k = encoded[1].to_bits() as usize;
    assert_eq!(encoded.len(), HEADER + 2 * k, "topk payload length");
    assert!(k <= n, "topk k {k} > n {n}");
    (n, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(c: &TopK, input: &[f32]) -> Vec<f32> {
        let mut enc = vec![0.0f32; c.encoded_words(input.len())];
        c.encode(input, &mut enc, &mut EncodeScratch::default());
        let mut out = vec![f32::NAN; input.len()];
        c.decode_overwrite(&enc, &mut out);
        out
    }

    #[test]
    fn keeps_the_largest_magnitudes_exactly() {
        let c = TopK::new(0.4); // k = 2 of 5
        let input = [0.1f32, -9.0, 0.2, 3.0, -0.3];
        let out = roundtrip(&c, &input);
        assert_eq!(out, vec![0.0, -9.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn ratio_one_is_identity_bitwise() {
        let c = TopK::new(1.0);
        let input: Vec<f32> = (0..97).map(|i| (i as f32 - 48.5) * 0.37).collect();
        let out = roundtrip(&c, &input);
        for (a, b) in input.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn decode_add_accumulates_sparsely() {
        let c = TopK::new(0.5); // k = 2 of 4
        let input = [1.0f32, -4.0, 2.0, 0.5];
        let mut enc = vec![0.0f32; c.encoded_words(4)];
        c.encode(&input, &mut enc, &mut EncodeScratch::default());
        let mut acc = vec![10.0f32; 4];
        c.decode_add(&enc, &mut acc);
        assert_eq!(acc, vec![10.0, 6.0, 12.0, 10.0]);
    }

    #[test]
    fn k_and_encoded_words() {
        let c = TopK::new(0.1);
        assert_eq!(c.k_of(100), 10);
        assert_eq!(c.k_of(5), 1);
        assert_eq!(c.k_of(0), 0);
        assert_eq!(c.encoded_words(100), 2 + 20);
        // ceil: 101 elements keep 11.
        assert_eq!(c.k_of(101), 11);
        assert_eq!(TopK::new(1.0).k_of(7), 7);
    }

    #[test]
    fn indices_survive_as_bit_patterns() {
        // Large counts/indices (> 2^24, where f32 *values* lose integer
        // exactness) must round-trip — they travel as raw bits, not
        // numbers.
        for n in [(1usize << 24) + 3, (1usize << 31) + 5] {
            let w = f32::from_bits(n as u32);
            assert_eq!(w.to_bits() as usize, n);
        }
    }

    #[test]
    fn scratch_is_reused_not_grown() {
        let c = TopK::new(0.25);
        let mut scratch = EncodeScratch::default();
        let input: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut enc = vec![0.0f32; c.encoded_words(64)];
        c.encode(&input, &mut enc, &mut scratch);
        let cap = scratch.idx.capacity();
        for _ in 0..5 {
            c.encode(&input, &mut enc, &mut scratch);
        }
        assert_eq!(scratch.idx.capacity(), cap, "steady-state encode must not grow scratch");
    }
}
