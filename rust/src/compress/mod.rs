//! Gradient compression: per-bucket lossy encodings with error feedback.
//!
//! WAGMA-SGD shrinks the *scope* of each averaging step (group collectives
//! instead of global barriers); this subsystem shrinks the *volume*. The
//! fusion planner's buckets ([`crate::sched`]) are the natural compression
//! units: each bucket (engine chunk) is encoded independently, travels the
//! wire in compressed form, and is decompressed straight into the running
//! reduction (`decode_add` — the compressed counterpart of
//! [`crate::util::sum_into`]).
//!
//! Three codecs behind one [`Compressor`] trait, selected by the
//! [`Compression`] knob that threads preset → TOML → CLI → engine:
//!
//! * [`TopK`] — magnitude top-k sparsification: keep the `ratio·n`
//!   largest-|x| entries as `(index, value)` pairs. Values are preserved
//!   exactly, so `decompress(compress(g)) + residual == g` elementwise
//!   (the error-feedback mass-conservation invariant), and `ratio = 1.0`
//!   degenerates to a bitwise-exact permutation-free copy.
//! * [`QuantizeQ8`] — per-bucket linear quantization: one f32 scale
//!   (`max|x| / 127`) plus an i8 code per element, packed four to a word.
//!   Round-trip error is bounded by `scale / 2` per element.
//! * [`Compression::None`] — passthrough; the engine takes the exact
//!   pre-compression code paths, bit-identical to the uncompressed build.
//!
//! ## Wire format
//!
//! Encoded payloads ride the existing zero-copy [`crate::comm::Chunk`]
//! machinery, so they are `&[f32]` buffers drawn from the endpoint's
//! [`crate::comm::BufferPool`] (no new steady-state allocations). Integer
//! fields (element count, k, sparse indices, packed i8 codes) are stored
//! as raw bit patterns via `f32::from_bits` — these words are only ever
//! copied, never used in arithmetic, so the bit patterns survive the
//! transport untouched.
//!
//! ```text
//! TopK:       [ bits(n) | bits(k) | bits(idx)·k (ascending) | value·k ]
//! QuantizeQ8: [ bits(n) | scale   | packed i8 codes, 4 per word       ]
//! ```
//!
//! The residual of each lossy publish is carried by a per-worker
//! [`ErrorFeedback`] accumulator into the next iteration (the
//! delayed-correction pattern of DaSGD / deep-gradient-compression), so
//! dropped mass is delayed, never lost.

pub mod error_feedback;
pub mod quantize;
pub mod topk;

pub use error_feedback::ErrorFeedback;
pub use quantize::QuantizeQ8;
pub use topk::TopK;

use std::str::FromStr;

use crate::config::TomlDoc;
use crate::util::cli::Args;

/// Reusable scratch state for encoders (index workspace for the top-k
/// selection). Owned by whoever encodes repeatedly — the engine thread,
/// an [`ErrorFeedback`] accumulator — so steady-state encoding allocates
/// nothing once warmed up.
#[derive(Debug, Default)]
pub struct EncodeScratch {
    pub(crate) idx: Vec<u32>,
}

/// A lossy (or identity) gradient codec over f32 slices.
///
/// Implementations must be deterministic: `encode` of equal inputs yields
/// equal outputs on every rank, which is what keeps compressed collectives
/// rank-agreeing (the compressed ring allgather distributes one encoding
/// that every rank — including the segment owner — decodes identically).
pub trait Compressor {
    fn name(&self) -> &'static str;

    /// Encoded length in f32 words for an `n`-element input.
    fn encoded_words(&self, n: usize) -> usize;

    /// Encode `input` into `out` (`out.len() == encoded_words(input.len())`).
    fn encode(&self, input: &[f32], out: &mut [f32], scratch: &mut EncodeScratch);

    /// Decode `encoded` and add elementwise into `dst` (`dst.len()` must be
    /// the original element count) — the fused decompress-sum reduction.
    fn decode_add(&self, encoded: &[f32], dst: &mut [f32]);

    /// Decode `encoded` into `dst`, overwriting it.
    fn decode_overwrite(&self, encoded: &[f32], dst: &mut [f32]);
}

/// The identity codec: encoded form == raw form. Exists so every
/// [`Compression`] kind has a [`Compressor`] behind it; the engine never
/// routes `Compression::None` through it (it branches to the exact
/// pre-compression code paths instead, keeping them bit-identical).
#[derive(Debug, Clone, Copy, Default)]
pub struct Passthrough;

impl Compressor for Passthrough {
    fn name(&self) -> &'static str {
        "none"
    }

    fn encoded_words(&self, n: usize) -> usize {
        n
    }

    fn encode(&self, input: &[f32], out: &mut [f32], _scratch: &mut EncodeScratch) {
        out.copy_from_slice(input);
    }

    fn decode_add(&self, encoded: &[f32], dst: &mut [f32]) {
        crate::util::add_assign(dst, encoded);
    }

    fn decode_overwrite(&self, encoded: &[f32], dst: &mut [f32]) {
        dst.copy_from_slice(encoded);
    }
}

/// Compression selection knob, carried by engine / simulator / train
/// configs (Copy so `EngineConfig` stays Copy).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Compression {
    /// Passthrough: the exact pre-compression code paths run.
    #[default]
    None,
    /// Magnitude top-k sparsification at `ratio` (fraction of entries kept).
    TopK { ratio: f64 },
    /// Per-bucket 8-bit linear quantization.
    QuantizeQ8,
}

/// Default top-k keep ratio when `--compression topk` is selected without
/// an explicit `--topk-ratio` (the deep-gradient-compression sweet spot
/// band; also the acceptance point of the bytes-on-wire criterion).
pub const DEFAULT_TOPK_RATIO: f64 = 0.1;

impl Compression {
    pub fn is_none(&self) -> bool {
        matches!(self, Compression::None)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Compression::None => "none",
            Compression::TopK { .. } => "topk",
            Compression::QuantizeQ8 => "q8",
        }
    }

    /// The configured top-k keep ratio (the default ratio for non-TopK
    /// kinds, so config round-trips are lossless).
    pub fn topk_ratio(&self) -> f64 {
        match self {
            Compression::TopK { ratio } => *ratio,
            _ => DEFAULT_TOPK_RATIO,
        }
    }

    /// Encoded length in f32 words for an `n`-element payload (`n` for
    /// `None`).
    pub fn encoded_words(&self, n: usize) -> usize {
        match *self {
            Compression::None => n,
            Compression::TopK { ratio } => TopK::new(ratio).encoded_words(n),
            Compression::QuantizeQ8 => QuantizeQ8.encoded_words(n),
        }
    }

    /// Bytes on the wire for a `raw_bytes` f32 payload — the cost-model
    /// counterpart of [`Compression::encoded_words`].
    pub fn wire_bytes(&self, raw_bytes: usize) -> usize {
        match self {
            Compression::None => raw_bytes,
            _ => self.encoded_words(raw_bytes / 4) * 4,
        }
    }

    /// Encode `input` into `out`. Allocation-free static dispatch (the
    /// engine's per-phase path); `None` behaves like [`Passthrough`].
    pub fn encode(&self, input: &[f32], out: &mut [f32], scratch: &mut EncodeScratch) {
        match *self {
            Compression::None => Passthrough.encode(input, out, scratch),
            Compression::TopK { ratio } => TopK::new(ratio).encode(input, out, scratch),
            Compression::QuantizeQ8 => QuantizeQ8.encode(input, out, scratch),
        }
    }

    /// Fused decompress-sum: `dst += decode(encoded)`.
    pub fn decode_add(&self, encoded: &[f32], dst: &mut [f32]) {
        match *self {
            Compression::None => Passthrough.decode_add(encoded, dst),
            Compression::TopK { ratio } => TopK::new(ratio).decode_add(encoded, dst),
            Compression::QuantizeQ8 => QuantizeQ8.decode_add(encoded, dst),
        }
    }

    /// `dst = decode(encoded)`.
    pub fn decode_overwrite(&self, encoded: &[f32], dst: &mut [f32]) {
        match *self {
            Compression::None => Passthrough.decode_overwrite(encoded, dst),
            Compression::TopK { ratio } => TopK::new(ratio).decode_overwrite(encoded, dst),
            Compression::QuantizeQ8 => QuantizeQ8.decode_overwrite(encoded, dst),
        }
    }

    // -- config plumbing (mirrors `sched::FusionConfig`) ------------------

    /// Parse from CLI flags (`--compression`, `--topk-ratio`) on top of
    /// `base`.
    pub fn from_args_with(args: &Args, base: Compression) -> Compression {
        let kind = args.str_or("compression", base.name());
        let ratio = args.f64_or("topk-ratio", base.topk_ratio());
        Compression::from_kind_ratio(&kind, ratio)
            .unwrap_or_else(|e| panic!("--compression/--topk-ratio: {e}"))
    }

    pub fn from_args(args: &Args) -> Compression {
        Self::from_args_with(args, Compression::None)
    }

    /// Parse from a TOML document's `[compress]` section (missing keys
    /// fall back to the defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<Compression, String> {
        let kind = doc.str_or("compress", "compression", Compression::None.name());
        let ratio = doc.f64_or("compress", "topk_ratio", DEFAULT_TOPK_RATIO);
        Compression::from_kind_ratio(&kind, ratio)
    }

    /// Emit the `[compress]` TOML section (round-trips through
    /// [`Compression::from_toml`]).
    pub fn to_toml(&self) -> String {
        format!(
            "[compress]\ncompression = \"{}\"\ntopk_ratio = {}\n",
            self.name(),
            self.topk_ratio()
        )
    }

    /// Emit the equivalent CLI flags (round-trips through
    /// [`Compression::from_args`]).
    pub fn to_args(&self) -> Vec<String> {
        vec![
            format!("--compression={}", self.name()),
            format!("--topk-ratio={}", self.topk_ratio()),
        ]
    }

    fn from_kind_ratio(kind: &str, ratio: f64) -> Result<Compression, String> {
        if !(ratio > 0.0 && ratio <= 1.0) {
            return Err(format!("topk_ratio must be in (0, 1], got {ratio}"));
        }
        match kind {
            "none" => Ok(Compression::None),
            "topk" | "top-k" => Ok(Compression::TopK { ratio }),
            "q8" | "quantize" | "int8" => Ok(Compression::QuantizeQ8),
            other => Err(format!("unknown compression {other:?} (none|topk|q8)")),
        }
    }
}

impl FromStr for Compression {
    type Err = String;

    fn from_str(s: &str) -> Result<Compression, String> {
        Compression::from_kind_ratio(s, DEFAULT_TOPK_RATIO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_roundtrips_toml_and_cli() {
        for comp in [
            Compression::None,
            Compression::TopK { ratio: 0.25 },
            Compression::QuantizeQ8,
        ] {
            let doc = TomlDoc::parse(&comp.to_toml()).unwrap();
            assert_eq!(Compression::from_toml(&doc).unwrap(), comp);
            let args = Args::parse(comp.to_args());
            assert_eq!(Compression::from_args(&args), comp);
        }
        // Defaults survive an empty doc / empty args.
        assert_eq!(
            Compression::from_toml(&TomlDoc::parse("").unwrap()).unwrap(),
            Compression::None
        );
        assert_eq!(Compression::from_args(&Args::parse(Vec::new())), Compression::None);
    }

    #[test]
    fn kind_parsing_and_validation() {
        assert_eq!("none".parse::<Compression>().unwrap(), Compression::None);
        assert_eq!(
            "topk".parse::<Compression>().unwrap(),
            Compression::TopK { ratio: DEFAULT_TOPK_RATIO }
        );
        assert_eq!("q8".parse::<Compression>().unwrap(), Compression::QuantizeQ8);
        assert!("bogus".parse::<Compression>().is_err());
        assert!(Compression::from_kind_ratio("topk", 0.0).is_err());
        assert!(Compression::from_kind_ratio("topk", 1.5).is_err());
    }

    #[test]
    fn wire_bytes_reduction_at_the_acceptance_point() {
        // topk_ratio = 0.1 must shrink bytes-on-wire by at least 4x on
        // bucket-sized payloads (the PR acceptance criterion's codec-level
        // precondition: 2 + 2·⌈0.1·n⌉ words vs n words ≈ 5x).
        let comp = Compression::TopK { ratio: 0.1 };
        for n in [4096usize, 100_000, 1 << 20] {
            let raw = n * 4;
            let wire = comp.wire_bytes(raw);
            assert!(
                raw as f64 / wire as f64 >= 4.0,
                "n={n}: raw {raw} wire {wire}"
            );
        }
        // q8 lands just under 4x (1 byte + header per element).
        let q = Compression::QuantizeQ8.wire_bytes(4096 * 4);
        assert!(q < 4096 * 4 / 3 && q > 4096, "q8 wire {q}");
        // None is identity.
        assert_eq!(Compression::None.wire_bytes(1234), 1234);
    }
}
