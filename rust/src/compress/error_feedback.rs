//! Error-feedback accumulator for lossy gradient compression.
//!
//! Compression drops mass; error feedback delays it instead of losing it
//! (Stich et al.; the DaSGD line shows such delayed corrections keep
//! convergence intact — the same role staleness plays in WAGMA itself).
//! Each worker folds the residual of its previous lossy publish into the
//! next payload before it is compressed:
//!
//! ```text
//! w̃_t      = w_t + e_{t-1}
//! publish    compress(w̃_t)          (what the collective averages)
//! e_t      = w̃_t - decompress(compress(w̃_t))
//! ```
//!
//! For [`crate::compress::TopK`] the split is exact:
//! `decompress(compress(w̃)) + e == w̃` elementwise (values ride the wire
//! bit-exactly, the residual is the dropped complement) — the
//! mass-conservation property pinned by the compression property tests.

use crate::compress::{Compression, EncodeScratch};

/// Per-worker residual carrier. Buffers are lazily sized on first use and
/// reused forever after — steady-state folds allocate nothing.
#[derive(Debug, Default)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
    encoded: Vec<f32>,
    decoded: Vec<f32>,
    scratch: EncodeScratch,
    folds: u64,
}

impl ErrorFeedback {
    pub fn new() -> ErrorFeedback {
        ErrorFeedback::default()
    }

    /// Fold the carried residual into `w`, then recompute the residual of
    /// compressing the result: `w += e; e = w - decompress(compress(w))`.
    /// After this call `w` is the payload to publish (the engine performs
    /// the wire encoding itself). No-op for [`Compression::None`].
    pub fn fold(&mut self, comp: Compression, w: &mut [f32]) {
        self.fold_chunked(comp, w, 0);
    }

    /// Like [`fold`](Self::fold), but matching the engine's *chunked*
    /// encoding: the roundtrip runs independently on each `chunk_elems`
    /// range (0 = whole vector), so the residual models exactly the
    /// first-hop loss of a chunked exchange — per-chunk top-k keeps a
    /// different set than whole-vector top-k would. (Losses the engine
    /// applies to *partial sums* on later butterfly hops are inherently
    /// multi-party and are not error-feedback-trackable.)
    pub fn fold_chunked(&mut self, comp: Compression, w: &mut [f32], chunk_elems: usize) {
        if comp.is_none() {
            return;
        }
        let n = w.len();
        self.residual.resize(n, 0.0);
        for (x, e) in w.iter_mut().zip(self.residual.iter()) {
            *x += *e;
        }
        let chunk = if chunk_elems == 0 || chunk_elems >= n { n.max(1) } else { chunk_elems };
        self.decoded.resize(n, 0.0);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + chunk).min(n);
            self.encoded.resize(comp.encoded_words(hi - lo), 0.0);
            comp.encode(&w[lo..hi], &mut self.encoded, &mut self.scratch);
            comp.decode_overwrite(&self.encoded, &mut self.decoded[lo..hi]);
            lo = hi;
        }
        for ((e, &x), &d) in self.residual.iter_mut().zip(w.iter()).zip(self.decoded.iter()) {
            *e = x - d;
        }
        self.folds += 1;
    }

    /// Deliver the carried residual through a lossless transmission:
    /// `w += e; e = 0`, charging no new residual. Used before the every-τ
    /// sync, which carries the contribution in full (exact below the ring
    /// threshold; the compressed ring's own segment loss is engine-side
    /// multi-hop loss, outside the error-feedback contract) — folding the
    /// usual roundtrip there would re-inject mass that was never dropped.
    pub fn drain_into(&mut self, w: &mut [f32]) {
        if self.residual.is_empty() {
            return;
        }
        for (x, e) in w.iter_mut().zip(self.residual.iter_mut()) {
            *x += *e;
            *e = 0.0;
        }
    }

    /// The residual carried into the next iteration.
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// L2 norm of the carried residual (metrics hook).
    pub fn residual_norm(&self) -> f64 {
        crate::util::l2_norm(&self.residual)
    }

    /// Folds performed so far.
    pub fn folds(&self) -> u64 {
        self.folds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_a_no_op() {
        let mut ef = ErrorFeedback::new();
        let mut w = vec![1.0f32, 2.0, 3.0];
        ef.fold(Compression::None, &mut w);
        assert_eq!(w, vec![1.0, 2.0, 3.0]);
        assert!(ef.residual().is_empty());
        assert_eq!(ef.folds(), 0);
    }

    #[test]
    fn topk_mass_conservation_is_exact() {
        // decompress(compress(w)) + residual == w, elementwise bitwise.
        let comp = Compression::TopK { ratio: 0.3 };
        let mut ef = ErrorFeedback::new();
        let w0: Vec<f32> = (0..50).map(|i| ((i * 29) % 17) as f32 * 0.7 - 5.0).collect();
        let mut w = w0.clone();
        ef.fold(comp, &mut w);
        assert_eq!(w, w0, "first fold has zero residual to add");
        // Reconstruct decompress(compress(w)) from the residual identity.
        for (i, (&x, &e)) in w.iter().zip(ef.residual()).enumerate() {
            let decoded = x - e;
            // Kept entries: residual exactly 0, decoded bit-equals x.
            // Dropped entries: decoded exactly 0, residual bit-equals x.
            assert!(
                (e == 0.0 && decoded.to_bits() == x.to_bits()) || decoded == 0.0,
                "element {i}: x={x} e={e}"
            );
            assert_eq!((decoded + e).to_bits(), x.to_bits(), "element {i}");
        }
    }

    #[test]
    fn residual_is_carried_into_the_next_fold() {
        let comp = Compression::TopK { ratio: 0.5 };
        let mut ef = ErrorFeedback::new();
        let mut w = vec![10.0f32, 1.0, -8.0, 2.0];
        ef.fold(comp, &mut w); // keeps 10, -8; residual [0, 1, 0, 2]
        assert_eq!(ef.residual(), &[0.0, 1.0, 0.0, 2.0]);
        let mut w2 = vec![0.0f32, 1.5, 0.0, 0.1];
        ef.fold(comp, &mut w2);
        // The carried residual was folded in before compression.
        assert_eq!(w2, vec![0.0, 2.5, 0.0, 2.1]);
        assert_eq!(ef.residual(), &[0.0, 0.0, 0.0, 0.0]);
        assert_eq!(ef.folds(), 2);
    }

    #[test]
    fn chunked_fold_models_per_chunk_keep_sets() {
        // Whole-vector top-k (50% of 4 = 2) would keep {10, -8}; per-chunk
        // top-k over 2-element chunks keeps one entry per chunk: {10, -8}
        // in chunk 0? No — chunks are [10, 1] and [-8, 2]: keeps 10 and
        // -8, residual [0, 1, 0, 2]. With chunks [1, 10] / [2, -8] the
        // per-chunk winners change with layout; pin the first layout.
        let comp = Compression::TopK { ratio: 0.5 };
        let mut ef = ErrorFeedback::new();
        let mut w = vec![10.0f32, 1.0, -8.0, 2.0];
        ef.fold_chunked(comp, &mut w, 2);
        assert_eq!(ef.residual(), &[0.0, 1.0, 0.0, 2.0]);
        // A layout where the global and per-chunk keep sets differ:
        // chunks [1, 2] and [8, 10] — per-chunk keeps 2 and 10 (one per
        // chunk), while global top-2 would keep 8 and 10.
        let mut ef2 = ErrorFeedback::new();
        let mut w2 = vec![1.0f32, 2.0, 8.0, 10.0];
        ef2.fold_chunked(comp, &mut w2, 2);
        assert_eq!(ef2.residual(), &[1.0, 0.0, 8.0, 0.0]);
        // chunk 0 (or >= n) degenerates to the whole-vector fold.
        let mut ef3 = ErrorFeedback::new();
        let mut w3 = vec![1.0f32, 2.0, 8.0, 10.0];
        ef3.fold_chunked(comp, &mut w3, 0);
        assert_eq!(ef3.residual(), &[1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn drain_delivers_and_clears_the_residual() {
        let comp = Compression::TopK { ratio: 0.5 };
        let mut ef = ErrorFeedback::new();
        let mut w = vec![10.0f32, 1.0, -8.0, 2.0];
        ef.fold(comp, &mut w); // residual [0, 1, 0, 2]
        let mut sync_payload = vec![5.0f32, 5.0, 5.0, 5.0];
        ef.drain_into(&mut sync_payload);
        assert_eq!(sync_payload, vec![5.0, 6.0, 5.0, 7.0]);
        assert_eq!(ef.residual(), &[0.0, 0.0, 0.0, 0.0]);
        // Draining an empty accumulator is a no-op.
        let mut fresh = ErrorFeedback::new();
        let mut v = vec![1.0f32];
        fresh.drain_into(&mut v);
        assert_eq!(v, vec![1.0]);
    }

    #[test]
    fn q8_residual_is_bounded_by_half_scale() {
        let comp = Compression::QuantizeQ8;
        let mut ef = ErrorFeedback::new();
        let mut w: Vec<f32> = (0..33).map(|i| (i as f32 - 16.0) * 0.3).collect();
        ef.fold(comp, &mut w);
        let scale = 16.0 * 0.3 / 127.0;
        for &e in ef.residual() {
            assert!(e.abs() <= scale * 0.51, "residual {e} vs scale {scale}");
        }
        assert!(ef.residual_norm() >= 0.0);
    }
}
