//! Per-bucket 8-bit linear quantization.
//!
//! One f32 scale per bucket (`max|x| / 127`) and one signed 8-bit code per
//! element, packed four to an f32 word (raw bit patterns — never used in
//! arithmetic). Decode is `code · scale`, so the per-element round-trip
//! error is at most `scale / 2`: every in-range `x / scale` lies within
//! `[-127, 127]` and rounding to the nearest integer moves it by ≤ 0.5.

use crate::compress::{Compressor, EncodeScratch};

/// The 8-bit linear quantizer (stateless).
#[derive(Debug, Clone, Copy, Default)]
pub struct QuantizeQ8;

/// Header words: element count + scale.
const HEADER: usize = 2;

/// i8 codes per packed f32 word.
const PACK: usize = 4;

impl Compressor for QuantizeQ8 {
    fn name(&self) -> &'static str {
        "q8"
    }

    fn encoded_words(&self, n: usize) -> usize {
        HEADER + n.div_ceil(PACK)
    }

    fn encode(&self, input: &[f32], out: &mut [f32], _scratch: &mut EncodeScratch) {
        let n = input.len();
        assert_eq!(out.len(), self.encoded_words(n), "encode buffer sized by encoded_words");
        let max_abs = input.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = max_abs / 127.0;
        out[0] = f32::from_bits(n as u32);
        out[1] = scale;
        let inv = if scale > 0.0 { 1.0 / scale as f64 } else { 0.0 };
        for (w, block) in out[HEADER..].iter_mut().zip(input.chunks(PACK)) {
            let mut word = 0u32;
            for (j, &x) in block.iter().enumerate() {
                let code = (x as f64 * inv).round().clamp(-127.0, 127.0) as i32 as i8;
                word |= (code as u8 as u32) << (8 * j);
            }
            *w = f32::from_bits(word);
        }
    }

    fn decode_add(&self, encoded: &[f32], dst: &mut [f32]) {
        let (n, scale) = decode_header(encoded);
        assert_eq!(dst.len(), n, "decode target length");
        for (w, block) in encoded[HEADER..].iter().zip(dst.chunks_mut(PACK)) {
            let word = w.to_bits();
            for (j, d) in block.iter_mut().enumerate() {
                let code = ((word >> (8 * j)) & 0xFF) as u8 as i8;
                *d += code as f32 * scale;
            }
        }
    }

    fn decode_overwrite(&self, encoded: &[f32], dst: &mut [f32]) {
        let (n, scale) = decode_header(encoded);
        assert_eq!(dst.len(), n, "decode target length");
        for (w, block) in encoded[HEADER..].iter().zip(dst.chunks_mut(PACK)) {
            let word = w.to_bits();
            for (j, d) in block.iter_mut().enumerate() {
                let code = ((word >> (8 * j)) & 0xFF) as u8 as i8;
                *d = code as f32 * scale;
            }
        }
    }
}

fn decode_header(encoded: &[f32]) -> (usize, f32) {
    assert!(encoded.len() >= HEADER, "truncated q8 payload");
    let n = encoded[0].to_bits() as usize;
    assert_eq!(encoded.len(), HEADER + n.div_ceil(PACK), "q8 payload length");
    (n, encoded[1])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(input: &[f32]) -> (Vec<f32>, f32) {
        let q = QuantizeQ8;
        let mut enc = vec![0.0f32; q.encoded_words(input.len())];
        q.encode(input, &mut enc, &mut EncodeScratch::default());
        let scale = enc[1];
        let mut out = vec![f32::NAN; input.len()];
        q.decode_overwrite(&enc, &mut out);
        (out, scale)
    }

    #[test]
    fn error_bounded_by_half_scale() {
        let input: Vec<f32> = (0..1001).map(|i| ((i * 37) % 211) as f32 * 0.173 - 18.0).collect();
        let (out, scale) = roundtrip(&input);
        assert!(scale > 0.0);
        // scale/2 plus a whisker of f32 rounding slack in decode's multiply.
        let bound = scale as f64 * 0.5 * (1.0 + 1e-5);
        for (i, (&x, &y)) in input.iter().zip(&out).enumerate() {
            let err = (x as f64 - y as f64).abs();
            assert!(err <= bound, "element {i}: |{x} - {y}| = {err} > {bound}");
        }
    }

    #[test]
    fn extremes_hit_full_code_range() {
        let (out, scale) = roundtrip(&[1.0, -1.0, 0.0]);
        assert_eq!(scale, 1.0 / 127.0);
        assert_eq!(out, vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn all_zero_input_decodes_to_zero() {
        let (out, scale) = roundtrip(&[0.0; 17]);
        assert_eq!(scale, 0.0);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn ragged_tail_packs_and_unpacks() {
        for n in [1usize, 2, 3, 4, 5, 7, 9] {
            let input: Vec<f32> = (0..n).map(|i| i as f32 - 1.5).collect();
            let (out, scale) = roundtrip(&input);
            for (&x, &y) in input.iter().zip(&out) {
                assert!((x - y).abs() <= scale * 0.51, "n={n}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn decode_add_sums_into_accumulator() {
        let q = QuantizeQ8;
        let input = [127.0f32, -127.0, 0.0, 63.5];
        let mut enc = vec![0.0f32; q.encoded_words(4)];
        q.encode(&input, &mut enc, &mut EncodeScratch::default());
        let mut acc = vec![1.0f32; 4];
        q.decode_add(&enc, &mut acc);
        assert_eq!(acc[0], 128.0);
        assert_eq!(acc[1], -126.0);
        assert_eq!(acc[2], 1.0);
        assert!((acc[3] - 65.0).abs() <= 0.51);
    }

    #[test]
    fn encoded_words_counts_header_and_packing() {
        let q = QuantizeQ8;
        assert_eq!(q.encoded_words(0), 2);
        assert_eq!(q.encoded_words(1), 3);
        assert_eq!(q.encoded_words(4), 3);
        assert_eq!(q.encoded_words(5), 4);
        assert_eq!(q.encoded_words(100), 2 + 25);
    }
}
