//! Online straggler detection over sampler windows.
//!
//! Each window the sampler hands the detector one wait-for-peer p99 per
//! rank (nanoseconds peers spent blocked waiting on that rank during the
//! window). A rank is flagged [`Straggler`](super::Health::Straggler)
//! once its p99 exceeds `k ×` the fleet (lower) median for `w`
//! consecutive windows; the flag clears as soon as one window falls back
//! under the threshold. A `min_wait_ns` floor keeps an idle fleet (median
//! ≈ 0) from flagging scheduler noise.
//!
//! The detector only sees wait distributions; `fault::Membership`
//! verdicts (suspect/dead) ride alongside in the snapshot and take
//! precedence when the sampler folds both into a rank's
//! [`Health`](super::Health).

#[derive(Debug, Clone, Copy)]
pub struct StragglerConfig {
    /// Multiple of the fleet median p99 a rank must exceed.
    pub k: f64,
    /// Consecutive offending windows before the flag raises.
    pub w: u32,
    /// Absolute floor (ns): below this, a p99 never flags.
    pub min_wait_ns: u64,
}

impl Default for StragglerConfig {
    fn default() -> StragglerConfig {
        StragglerConfig { k: 2.0, w: 3, min_wait_ns: 100_000 }
    }
}

#[derive(Debug)]
pub struct StragglerDetector {
    cfg: StragglerConfig,
    consecutive: Vec<u32>,
    flagged: Vec<bool>,
}

impl StragglerDetector {
    pub fn new(p: usize, cfg: StragglerConfig) -> StragglerDetector {
        StragglerDetector { cfg, consecutive: vec![0; p], flagged: vec![false; p] }
    }

    pub fn config(&self) -> StragglerConfig {
        self.cfg
    }

    /// Feed one window of per-rank p99s; returns the fleet median used.
    /// Query verdicts through [`StragglerDetector::is_straggler`].
    pub fn observe(&mut self, window_p99_ns: &[u64]) -> u64 {
        assert_eq!(window_p99_ns.len(), self.consecutive.len());
        let median = lower_median(window_p99_ns);
        let thresh = (self.cfg.k * median as f64).max(self.cfg.min_wait_ns as f64);
        for (r, &p99) in window_p99_ns.iter().enumerate() {
            if p99 as f64 > thresh {
                self.consecutive[r] = self.consecutive[r].saturating_add(1);
            } else {
                self.consecutive[r] = 0;
            }
            self.flagged[r] = self.consecutive[r] >= self.cfg.w;
        }
        median
    }

    pub fn is_straggler(&self, rank: usize) -> bool {
        self.flagged[rank]
    }

    /// Offending-window streak for a rank (diagnostics).
    pub fn streak(&self, rank: usize) -> u32 {
        self.consecutive[rank]
    }
}

/// Lower median: robust against the straggler's own sample inflating the
/// fleet baseline in small fleets.
fn lower_median(xs: &[u64]) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_unstable();
    sorted[(sorted.len() - 1) / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(k: f64, w: u32) -> StragglerConfig {
        StragglerConfig { k, w, min_wait_ns: 1_000 }
    }

    #[test]
    fn flags_after_w_consecutive_windows_and_clears() {
        let mut d = StragglerDetector::new(4, cfg(2.0, 3));
        for i in 0..3 {
            d.observe(&[10_000, 11_000, 9_000, 100_000]);
            assert_eq!(d.is_straggler(3), i == 2, "window {i}");
        }
        assert!(!d.is_straggler(0));
        // One quiet window clears the flag and the streak.
        d.observe(&[10_000, 11_000, 9_000, 12_000]);
        assert!(!d.is_straggler(3));
        assert_eq!(d.streak(3), 0);
    }

    #[test]
    fn min_wait_floor_suppresses_idle_noise() {
        let mut d = StragglerDetector::new(2, StragglerConfig { k: 2.0, w: 1, min_wait_ns: 1_000_000 });
        // Median 0, one rank at 500µs: above k×median but below the floor.
        d.observe(&[0, 500_000]);
        assert!(!d.is_straggler(1));
        d.observe(&[0, 2_000_000]);
        assert!(d.is_straggler(1));
    }

    #[test]
    fn lower_median_is_straggler_robust() {
        assert_eq!(lower_median(&[1, 2, 3, 1000]), 2);
        assert_eq!(lower_median(&[5]), 5);
        assert_eq!(lower_median(&[]), 0);
    }
}
