//! # Live telemetry: in-run metrics registry, health, and exposition
//!
//! The tracing layer ([`crate::trace`]) answers *what happened* after a
//! run; this module answers *what is happening now*. It has three parts:
//!
//! * **Registry** ([`registry`]) — one [`RankTelemetry`] slot per rank,
//!   atomics only. The engine, app/worker threads, and the fault paths
//!   publish wait time by attribution class, bytes-on-wire, degraded-mode
//!   counters, membership verdicts, staleness, and steps with zero
//!   steady-state allocations. Rolling wait-for-peer distributions use
//!   [`registry::AtomicHistogram`], which shares the exact log2 buckets
//!   of [`crate::trace::hist`]. Blocked receive time is attributed to the
//!   *waited-on* rank's slot, so a slow rank accumulates the fleet's
//!   wait-for-peer time itself.
//! * **Sampler** ([`sampler`]) — a thread snapshotting the registry at a
//!   configurable interval into a deterministic
//!   [`TelemetrySnapshot`], runs the online straggler detector
//!   ([`straggler`]: window p99 > k× fleet median for w consecutive
//!   windows ⇒ [`Health::Straggler`], with `fault::Membership` verdicts
//!   taking precedence), and fans snapshots out to sinks: JSON lines
//!   (`--telemetry FILE`), the live TTY dashboard ([`top`], `wagma top`
//!   / `--top`), and the latest-snapshot slot.
//! * **Exposition** ([`prometheus`]) — Prometheus text format rendered
//!   from a snapshot and served from a minimal blocking HTTP listener
//!   (`--metrics-addr`; also `/snapshot.json` for `wagma top --addr`).
//!   This listener is the seed of the `wagma serve` ROADMAP direction.
//!
//! The simulator emits analytic snapshots on the same schema via
//! [`snapshot_from_events`], so live and simulated fleets are inspected
//! with the same tools.

pub mod prometheus;
pub mod registry;
pub mod sampler;
pub mod straggler;
pub mod top;

pub use prometheus::{fetch_snapshot, lint_exposition, parse_exposition, render, MetricsServer};
pub use registry::{
    snapshot_from_json, snapshot_json, AtomicHistogram, CritShare, RankSnapshot, RankTelemetry,
    TelemetryRegistry, TelemetrySnapshot,
};
pub use sampler::{
    shared_snapshot, JsonLinesSink, Sampler, SamplerConfig, SamplerReport, SharedSnapshot, Sink,
    TelemetryHub, TopSink,
};
pub use straggler::{StragglerConfig, StragglerDetector};
pub use top::render_top;

use crate::trace::{Lane, TraceEvent, TraceKind};

/// Folded per-rank health shown in every sink.
///
/// Ordering of precedence when folding: `Dead` ≻ `Suspect` (both from
/// `fault::Membership` verdicts published by the engine) ≻ `Straggler`
/// (from the wait-distribution detector) ≻ `Healthy`. A straggler is
/// still *participating* — it answers receives, just slowly — which is
/// exactly the regime where wait-avoiding group averaging absorbs skew;
/// a suspect has already missed a bounded-retry receive window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Healthy,
    Straggler,
    Suspect,
    Dead,
}

impl Health {
    pub fn name(self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Straggler => "straggler",
            Health::Suspect => "suspect",
            Health::Dead => "dead",
        }
    }

    pub fn from_name(s: &str) -> Option<Health> {
        match s {
            "healthy" => Some(Health::Healthy),
            "straggler" => Some(Health::Straggler),
            "suspect" => Some(Health::Suspect),
            "dead" => Some(Health::Dead),
            _ => None,
        }
    }

    /// Stable numeric code for the `wagma_health_state` gauge.
    pub fn code(self) -> u64 {
        match self {
            Health::Healthy => 0,
            Health::Straggler => 1,
            Health::Suspect => 2,
            Health::Dead => 3,
        }
    }
}

/// End-of-run observability-loss warning shared by `wagma
/// train`/`bench`/`trace`. `None` when nothing was lost (silence is only
/// acceptable when the data is complete). The exact wording is pinned by
/// a test — update both together.
pub fn drop_warning(dropped_trace_events: u64, sampler_overruns: u64) -> Option<String> {
    if dropped_trace_events == 0 && sampler_overruns == 0 {
        return None;
    }
    Some(format!(
        "warning: observability data lost: {dropped_trace_events} trace event(s) dropped \
(ring overflow), {sampler_overruns} telemetry sampler overrun(s); timelines and windows \
are incomplete — raise the trace ring capacity or the sampler interval"
    ))
}

/// Build an analytic [`TelemetrySnapshot`] from a trace-event list — the
/// simulator's (and `wagma trace`'s) path onto the live-telemetry
/// schema. Aggregation mirrors the live publishers: engine-lane `Wait`
/// events carry the waited-on partner in `peer` (the causal wire stamp),
/// so wait-for-peer time lands on the *waited-on* rank's slot and the
/// waiter records the blame, exactly as the live engine does.
/// Peer-less waits fall back to self-attribution. The straggler detector
/// runs over this single window with `w` forced to 1, so sustained
/// analytic skew still surfaces as [`Health::Straggler`].
pub fn snapshot_from_events(p: usize, events: &[TraceEvent]) -> TelemetrySnapshot {
    let registry = TelemetryRegistry::new(p);
    for ev in events {
        if (ev.rank as usize) >= p {
            continue;
        }
        let slot = registry.rank(ev.rank as usize);
        match (ev.lane, ev.kind) {
            (Lane::App, TraceKind::Compute) => slot.add_step(),
            (Lane::App, TraceKind::Wait) => slot.add_wait_app_ns(ev.dur_ns),
            (Lane::Engine, TraceKind::Wait) => {
                slot.add_wait_group_ns(ev.dur_ns);
                let cause = ev.peer as usize;
                if ev.peer != crate::trace::NO_PEER && cause < p {
                    registry.rank(cause).record_wait_for_ns(ev.dur_ns);
                    slot.record_blame_ns(cause, ev.dur_ns);
                } else {
                    slot.record_wait_for_ns(ev.dur_ns);
                }
            }
            (Lane::Engine, TraceKind::GroupExchangePhase) => slot.add_wire_bytes(ev.bytes),
            (Lane::Engine, TraceKind::TauSync) => slot.add_wire_bytes(ev.bytes),
            (_, TraceKind::Fault) => {
                if ev.dur_ns > 0 {
                    slot.add_skipped_phases(1);
                }
            }
            _ => {}
        }
    }
    let cfg = StragglerConfig { w: 1, ..StragglerConfig::default() };
    let mut hub = TelemetryHub::new(std::sync::Arc::new(registry), cfg);
    hub.tick()
}

/// Fold a computed critical path into the per-class × per-rank
/// [`CritShare`] rows the sinks expose (`wagma_critpath_share{class,rank}`
/// in the Prometheus exposition, the `critpath` array in JSONL). Phases
/// are summed together; shares are parts-per-million of the makespan, so
/// they stay integer and `Eq`-comparable like every other snapshot field.
pub fn critpath_shares(cp: &crate::trace::CritPath) -> Vec<CritShare> {
    let mk = cp.makespan_ns();
    if mk == 0 {
        return Vec::new();
    }
    let mut per: std::collections::BTreeMap<(u32, &'static str), u64> =
        std::collections::BTreeMap::new();
    for (&(rank, _phase, class), &ns) in &cp.cells {
        *per.entry((rank, class.name())).or_insert(0) += ns;
    }
    per.into_iter()
        .map(|((rank, class), ns)| CritShare {
            class: class.to_string(),
            rank,
            ppm: ns.saturating_mul(1_000_000) / mk,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_warning_silent_only_when_complete() {
        assert_eq!(drop_warning(0, 0), None);
        let w = drop_warning(7, 0).expect("warns");
        assert!(w.contains("7 trace event(s) dropped"), "{w}");
        let w = drop_warning(0, 2).expect("warns");
        assert!(w.contains("2 telemetry sampler overrun(s)"), "{w}");
    }

    #[test]
    fn critpath_shares_fold_phases_into_ppm_rows() {
        use crate::trace::{Class, CritPath, NO_PHASE};
        let mut cp = CritPath { t_start: 0, t_end: 100, ..CritPath::default() };
        cp.cells.insert((0, NO_PHASE, Class::Compute), 60);
        cp.cells.insert((1, 0, Class::Transfer), 15);
        cp.cells.insert((1, 1, Class::Transfer), 25);
        let shares = critpath_shares(&cp);
        assert_eq!(shares.len(), 2, "{shares:?}");
        assert_eq!(shares[0], CritShare { class: "compute".into(), rank: 0, ppm: 600_000 });
        assert_eq!(shares[1], CritShare { class: "transfer".into(), rank: 1, ppm: 400_000 });
        assert!(critpath_shares(&CritPath::default()).is_empty());
    }

    #[test]
    fn health_codes_round_trip() {
        for h in [Health::Healthy, Health::Straggler, Health::Suspect, Health::Dead] {
            assert_eq!(Health::from_name(h.name()), Some(h));
        }
        assert_eq!(Health::from_name("zombie"), None);
    }
}
