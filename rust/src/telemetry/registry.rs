//! Lock-light per-rank metrics registry and the deterministic snapshot
//! the sampler derives from it.
//!
//! Publishers (engine threads, app/worker threads, the fault paths) hold
//! an `Arc<TelemetryRegistry>` and touch only pre-sized atomics: every
//! publish is a handful of relaxed `fetch_add`/`fetch_max` calls into
//! slots allocated once at registry construction, so instrumented runs
//! stay allocation-free at steady state (pinned by the P=1 bit-identity
//! test against `EngineStats::pool_allocs`). Rolling wait-for-peer
//! distributions reuse the exact [`crate::trace::hist`] log2 bucketing
//! through [`AtomicHistogram`], and snapshots rebuild a
//! [`LogHistogram`] via `from_parts` so quantile math lives in one place.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::fault::PeerState;
use crate::trace::{bucket_bounds, bucket_of, LogHistogram, N_BUCKETS};
use crate::util::json::{self, Json};

use super::Health;

/// Concurrent log2-bucketed histogram sharing [`crate::trace::hist`]'s
/// bucket semantics. Cumulative: the sampler computes per-window
/// distributions by differencing consecutive [`AtomicHistogram::counts`]
/// snapshots, so publishers never carry window state.
pub struct AtomicHistogram {
    counts: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> AtomicHistogram {
        AtomicHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

impl AtomicHistogram {
    pub fn record(&self, v: u64) {
        self.counts[bucket_of(v)].fetch_add(1, Relaxed);
        self.sum.fetch_add(v, Relaxed);
        self.min.fetch_min(v, Relaxed);
        self.max.fetch_max(v, Relaxed);
    }

    /// Cumulative per-bucket counts (the sampler's window-delta input).
    pub fn counts(&self) -> [u64; N_BUCKETS] {
        std::array::from_fn(|b| self.counts[b].load(Relaxed))
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Relaxed)
    }

    /// Cumulative view as a [`LogHistogram`] (shared quantile math).
    pub fn load(&self) -> LogHistogram {
        LogHistogram::from_parts(
            self.counts(),
            self.sum.load(Relaxed),
            self.min.load(Relaxed),
            self.max.load(Relaxed),
        )
    }
}

/// Build the histogram of one sampler window from two cumulative count
/// snapshots. Exact min/max are only tracked cumulatively, so the window
/// histogram synthesizes them from its lowest/highest non-empty bucket
/// bounds — the same factor-of-2 resolution quantiles already have.
pub fn window_hist(
    cur: &[u64; N_BUCKETS],
    prev: &[u64; N_BUCKETS],
    sum_delta: u64,
) -> LogHistogram {
    let mut delta = [0u64; N_BUCKETS];
    let mut min = u64::MAX;
    let mut max = 0u64;
    for b in 0..N_BUCKETS {
        let d = cur[b].saturating_sub(prev[b]);
        delta[b] = d;
        if d > 0 {
            let (lo, hi) = bucket_bounds(b);
            min = min.min(lo);
            max = max.max(hi);
        }
    }
    LogHistogram::from_parts(delta, sum_delta, min, max)
}

/// One rank's slot in the registry — atomics only, sized at construction.
///
/// `wait_for` holds nanoseconds *other* ranks spent blocked in a receive
/// waiting on **this** rank (the blocked engine attributes each wait to
/// the partner it waited on). A slow rank therefore accumulates the high
/// wait-for-peer distribution itself, which is what the straggler
/// detector thresholds.
#[derive(Default)]
pub struct RankTelemetry {
    steps: AtomicU64,
    wait_app_ns: AtomicU64,
    wait_group_ns: AtomicU64,
    wait_sync_ns: AtomicU64,
    wire_bytes: AtomicU64,
    skipped_phases: AtomicU64,
    degraded_iters: AtomicU64,
    staleness_sum: AtomicU64,
    staleness_count: AtomicU64,
    /// [`PeerState`] code: 0 healthy, 1 suspect, 2 dead.
    membership: AtomicU64,
    wait_for: AtomicHistogram,
    /// Per-peer blame: `blame[q]` holds nanoseconds **this** rank spent
    /// blocked waiting on peer `q` (the mirror of `wait_for`, resolved to
    /// the waited-on partner). Sized at registry construction; empty under
    /// `Default` so standalone slots stay allocation-free.
    blame: Vec<AtomicHistogram>,
}

impl RankTelemetry {
    /// A slot that can attribute its own blocked time to each of `p`
    /// peers ([`TelemetryRegistry::new`] uses this; `Default` keeps the
    /// blame table empty for contexts without a fixed world size).
    pub fn with_peers(p: usize) -> RankTelemetry {
        RankTelemetry {
            blame: (0..p).map(|_| AtomicHistogram::default()).collect(),
            ..RankTelemetry::default()
        }
    }
    pub fn add_step(&self) {
        self.steps.fetch_add(1, Relaxed);
    }

    pub fn add_wait_app_ns(&self, ns: u64) {
        self.wait_app_ns.fetch_add(ns, Relaxed);
    }

    pub fn add_wait_group_ns(&self, ns: u64) {
        self.wait_group_ns.fetch_add(ns, Relaxed);
    }

    pub fn add_wait_sync_ns(&self, ns: u64) {
        self.wait_sync_ns.fetch_add(ns, Relaxed);
    }

    pub fn add_wire_bytes(&self, b: u64) {
        self.wire_bytes.fetch_add(b, Relaxed);
    }

    pub fn add_skipped_phases(&self, n: u64) {
        self.skipped_phases.fetch_add(n, Relaxed);
    }

    pub fn add_degraded_iter(&self) {
        self.degraded_iters.fetch_add(1, Relaxed);
    }

    pub fn add_staleness(&self, s: u64) {
        self.staleness_sum.fetch_add(s, Relaxed);
        self.staleness_count.fetch_add(1, Relaxed);
    }

    /// Record nanoseconds a peer spent blocked waiting on this rank.
    pub fn record_wait_for_ns(&self, ns: u64) {
        self.wait_for.record(ns);
    }

    pub fn wait_for(&self) -> &AtomicHistogram {
        &self.wait_for
    }

    /// Record nanoseconds **this** rank spent blocked waiting on `peer`
    /// (the waiter-side mirror of [`RankTelemetry::record_wait_for_ns`]).
    /// Out-of-range peers (or an unsized blame table) are dropped, not
    /// panicked on — telemetry must never take the run down.
    pub fn record_blame_ns(&self, peer: usize, ns: u64) {
        if let Some(h) = self.blame.get(peer) {
            h.record(ns);
        }
    }

    /// The peer this rank blames the most: `(peer, p99_ns, total_ns)` of
    /// the per-peer histogram with the largest cumulative blocked time.
    /// `None` when nothing has been blamed yet.
    pub fn blame_top(&self) -> Option<(usize, u64, u64)> {
        self.blame
            .iter()
            .enumerate()
            .map(|(q, h)| (q, h.sum()))
            .filter(|&(_, total)| total > 0)
            .max_by_key(|&(_, total)| total)
            .map(|(q, total)| (q, self.blame[q].load().quantile(0.99) as u64, total))
    }

    /// Dead is sticky; suspect never downgrades it.
    pub fn mark_suspect(&self) {
        let _ = self.membership.compare_exchange(0, 1, Relaxed, Relaxed);
    }

    pub fn mark_dead(&self) {
        self.membership.store(2, Relaxed);
    }

    /// Clears a suspect verdict (leaves dead untouched).
    pub fn heal(&self) {
        let _ = self.membership.compare_exchange(1, 0, Relaxed, Relaxed);
    }

    pub fn set_membership(&self, s: PeerState) {
        match s {
            PeerState::Healthy => self.heal(),
            PeerState::Suspect => self.mark_suspect(),
            PeerState::Dead => self.mark_dead(),
        }
    }

    pub fn membership_code(&self) -> u64 {
        self.membership.load(Relaxed)
    }

    pub fn steps(&self) -> u64 {
        self.steps.load(Relaxed)
    }

    pub fn wait_app_ns(&self) -> u64 {
        self.wait_app_ns.load(Relaxed)
    }

    pub fn wait_group_ns(&self) -> u64 {
        self.wait_group_ns.load(Relaxed)
    }

    pub fn wait_sync_ns(&self) -> u64 {
        self.wait_sync_ns.load(Relaxed)
    }

    pub fn wire_bytes(&self) -> u64 {
        self.wire_bytes.load(Relaxed)
    }

    pub fn skipped_phases(&self) -> u64 {
        self.skipped_phases.load(Relaxed)
    }

    pub fn degraded_iters(&self) -> u64 {
        self.degraded_iters.load(Relaxed)
    }

    pub fn staleness_sum(&self) -> u64 {
        self.staleness_sum.load(Relaxed)
    }

    pub fn staleness_count(&self) -> u64 {
        self.staleness_count.load(Relaxed)
    }
}

/// The per-run registry: one [`RankTelemetry`] per rank plus run-level
/// loss counters. Shared as `Arc<TelemetryRegistry>`; publishing never
/// takes a lock or allocates.
pub struct TelemetryRegistry {
    ranks: Vec<RankTelemetry>,
    dropped_trace_events: AtomicU64,
    sampler_overruns: AtomicU64,
}

impl std::fmt::Debug for TelemetryRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TelemetryRegistry(p={})", self.ranks.len())
    }
}

impl TelemetryRegistry {
    pub fn new(p: usize) -> TelemetryRegistry {
        TelemetryRegistry {
            ranks: (0..p).map(|_| RankTelemetry::with_peers(p)).collect(),
            dropped_trace_events: AtomicU64::new(0),
            sampler_overruns: AtomicU64::new(0),
        }
    }

    pub fn p(&self) -> usize {
        self.ranks.len()
    }

    pub fn rank(&self, r: usize) -> &RankTelemetry {
        &self.ranks[r]
    }

    pub fn add_dropped_trace_events(&self, n: u64) {
        self.dropped_trace_events.fetch_add(n, Relaxed);
    }

    pub fn dropped_trace_events(&self) -> u64 {
        self.dropped_trace_events.load(Relaxed)
    }

    pub fn add_sampler_overrun(&self) {
        self.sampler_overruns.fetch_add(1, Relaxed);
    }

    pub fn sampler_overruns(&self) -> u64 {
        self.sampler_overruns.load(Relaxed)
    }
}

/// One rank's row in a [`TelemetrySnapshot`] — plain values, comparable
/// and JSON-serializable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankSnapshot {
    pub rank: usize,
    pub steps: u64,
    /// Steps completed during this sampler window (step rate × interval).
    pub window_steps: u64,
    pub wait_app_ns: u64,
    pub wait_group_ns: u64,
    pub wait_sync_ns: u64,
    pub wire_bytes: u64,
    pub skipped_phases: u64,
    pub degraded_iters: u64,
    pub staleness_sum: u64,
    pub staleness_count: u64,
    /// 0 healthy / 1 suspect / 2 dead (mirrors [`PeerState`]).
    pub membership: u64,
    /// p99 of the wait-for-peer distribution over this window (ns).
    pub window_wait_for_p99_ns: u64,
    /// Cumulative nanoseconds peers spent blocked waiting on this rank.
    pub total_wait_for_ns: u64,
    /// The peer this rank has spent the most blocked time waiting on
    /// (`-1` when nothing has been blamed yet).
    pub blame_peer: i64,
    /// p99 (ns) of the blocked-time distribution against `blame_peer`.
    pub blame_p99_ns: u64,
    /// Cumulative nanoseconds this rank spent blocked on `blame_peer`.
    pub blame_total_ns: u64,
    pub health: Health,
}

/// Deterministic sampler output: everything the sinks (Prometheus, JSON
/// lines, `wagma top`) render. Counter fields are cumulative and
/// code-structural, which is what the CI baseline gate compares.
/// One critical-path attribution share: the fraction (parts-per-million,
/// integer so snapshots stay `Eq`-comparable) of the run's critical path
/// spent in `class` on `rank`. Produced by
/// [`crate::trace::critical_path_events`] when a traced run ends; empty
/// for live windows where no trace is attached.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CritShare {
    /// Attribution class name (`compute`, `wait_for_peer`, `codec`,
    /// `transfer`, `other`).
    pub class: String,
    pub rank: u32,
    /// Share of the critical path in parts-per-million (1e6 = 100%).
    pub ppm: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetrySnapshot {
    /// Sampler window sequence number (1-based).
    pub window: u64,
    pub p: usize,
    pub ranks: Vec<RankSnapshot>,
    /// Fleet (lower) median of the per-rank window wait-for p99s.
    pub fleet_median_p99_ns: u64,
    pub dropped_trace_events: u64,
    pub sampler_overruns: u64,
    /// Per-class × per-rank critical-path shares (empty until a traced
    /// run attaches them; see [`CritShare`]).
    pub critpath: Vec<CritShare>,
}

impl TelemetrySnapshot {
    pub fn total_steps(&self) -> u64 {
        self.ranks.iter().map(|r| r.steps).sum()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.wire_bytes).sum()
    }
}

fn rank_json(r: &RankSnapshot) -> Json {
    json::obj(vec![
        ("rank", json::num(r.rank as f64)),
        ("steps", json::num(r.steps as f64)),
        ("window_steps", json::num(r.window_steps as f64)),
        ("wait_app_ns", json::num(r.wait_app_ns as f64)),
        ("wait_group_ns", json::num(r.wait_group_ns as f64)),
        ("wait_sync_ns", json::num(r.wait_sync_ns as f64)),
        ("wire_bytes", json::num(r.wire_bytes as f64)),
        ("skipped_phases", json::num(r.skipped_phases as f64)),
        ("degraded_iters", json::num(r.degraded_iters as f64)),
        ("staleness_sum", json::num(r.staleness_sum as f64)),
        ("staleness_count", json::num(r.staleness_count as f64)),
        ("membership", json::num(r.membership as f64)),
        ("window_wait_for_p99_ns", json::num(r.window_wait_for_p99_ns as f64)),
        ("total_wait_for_ns", json::num(r.total_wait_for_ns as f64)),
        ("blame_peer", json::num(r.blame_peer as f64)),
        ("blame_p99_ns", json::num(r.blame_p99_ns as f64)),
        ("blame_total_ns", json::num(r.blame_total_ns as f64)),
        ("health", json::s(r.health.name())),
    ])
}

/// One JSON-lines record (deterministic key order via the `Json` BTreeMap).
pub fn snapshot_json(s: &TelemetrySnapshot) -> Json {
    json::obj(vec![
        ("window", json::num(s.window as f64)),
        ("p", json::num(s.p as f64)),
        ("ranks", json::arr(s.ranks.iter().map(rank_json).collect())),
        ("fleet_median_p99_ns", json::num(s.fleet_median_p99_ns as f64)),
        ("dropped_trace_events", json::num(s.dropped_trace_events as f64)),
        ("sampler_overruns", json::num(s.sampler_overruns as f64)),
        (
            "critpath",
            json::arr(
                s.critpath
                    .iter()
                    .map(|c| {
                        json::obj(vec![
                            ("class", json::s(&c.class)),
                            ("rank", json::num(c.rank as f64)),
                            ("ppm", json::num(c.ppm as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn get_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .map(|v| v as u64)
        .ok_or_else(|| format!("snapshot json: missing numeric field `{key}`"))
}

/// Tolerant numeric read for fields added after the first JSONL schema
/// shipped (`blame_*`, `critpath`): old telemetry files must keep
/// parsing, so absence falls back to `default` instead of erroring.
fn opt_f64(j: &Json, key: &str, default: f64) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(default)
}

fn rank_from_json(j: &Json) -> Result<RankSnapshot, String> {
    let health = j
        .get("health")
        .and_then(Json::as_str)
        .ok_or("snapshot json: missing `health`")?;
    Ok(RankSnapshot {
        rank: get_u64(j, "rank")? as usize,
        steps: get_u64(j, "steps")?,
        window_steps: get_u64(j, "window_steps")?,
        wait_app_ns: get_u64(j, "wait_app_ns")?,
        wait_group_ns: get_u64(j, "wait_group_ns")?,
        wait_sync_ns: get_u64(j, "wait_sync_ns")?,
        wire_bytes: get_u64(j, "wire_bytes")?,
        skipped_phases: get_u64(j, "skipped_phases")?,
        degraded_iters: get_u64(j, "degraded_iters")?,
        staleness_sum: get_u64(j, "staleness_sum")?,
        staleness_count: get_u64(j, "staleness_count")?,
        membership: get_u64(j, "membership")?,
        window_wait_for_p99_ns: get_u64(j, "window_wait_for_p99_ns")?,
        total_wait_for_ns: get_u64(j, "total_wait_for_ns")?,
        blame_peer: opt_f64(j, "blame_peer", -1.0) as i64,
        blame_p99_ns: opt_f64(j, "blame_p99_ns", 0.0) as u64,
        blame_total_ns: opt_f64(j, "blame_total_ns", 0.0) as u64,
        health: Health::from_name(health)
            .ok_or_else(|| format!("snapshot json: unknown health `{health}`"))?,
    })
}

/// Parse one JSON-lines record back into a snapshot (round-trip of
/// [`snapshot_json`]; used by `wagma top --file` and the tests).
pub fn snapshot_from_json(j: &Json) -> Result<TelemetrySnapshot, String> {
    let ranks = j
        .get("ranks")
        .and_then(Json::as_arr)
        .ok_or("snapshot json: missing `ranks` array")?
        .iter()
        .map(rank_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let critpath = j
        .get("critpath")
        .and_then(Json::as_arr)
        .map(|items| {
            items
                .iter()
                .filter_map(|c| {
                    Some(CritShare {
                        class: c.get("class")?.as_str()?.to_string(),
                        rank: c.get("rank")?.as_f64()? as u32,
                        ppm: c.get("ppm")?.as_f64()? as u64,
                    })
                })
                .collect()
        })
        .unwrap_or_default();
    Ok(TelemetrySnapshot {
        window: get_u64(j, "window")?,
        p: get_u64(j, "p")? as usize,
        ranks,
        fleet_median_p99_ns: get_u64(j, "fleet_median_p99_ns")?,
        dropped_trace_events: get_u64(j, "dropped_trace_events")?,
        sampler_overruns: get_u64(j, "sampler_overruns")?,
        critpath,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_histogram_matches_loghistogram() {
        let a = AtomicHistogram::default();
        let mut h = LogHistogram::default();
        for v in [0u64, 1, 3, 17, 1023, 1024, 999_999] {
            a.record(v);
            h.record(v);
        }
        let loaded = a.load();
        assert_eq!(loaded.count(), h.count());
        assert_eq!(loaded.sum(), h.sum());
        assert_eq!(loaded.min(), h.min());
        assert_eq!(loaded.max(), h.max());
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(loaded.quantile(q), h.quantile(q));
        }
    }

    #[test]
    fn window_hist_is_count_delta() {
        let a = AtomicHistogram::default();
        a.record(10);
        a.record(20);
        let prev = a.counts();
        let prev_sum = a.sum();
        a.record(1_000_000);
        a.record(1_000_001);
        let w = window_hist(&a.counts(), &prev, a.sum() - prev_sum);
        assert_eq!(w.count(), 2);
        assert_eq!(w.sum(), 2_000_001);
        // Window min/max are bucket bounds of the only non-empty bucket.
        let b = bucket_of(1_000_000);
        let (lo, hi) = bucket_bounds(b);
        assert_eq!(w.min(), lo);
        assert_eq!(w.max(), hi);
    }

    #[test]
    fn membership_dead_is_sticky() {
        let r = RankTelemetry::default();
        assert_eq!(r.membership_code(), 0);
        r.mark_suspect();
        assert_eq!(r.membership_code(), 1);
        r.heal();
        assert_eq!(r.membership_code(), 0);
        r.mark_dead();
        r.mark_suspect();
        r.heal();
        assert_eq!(r.membership_code(), 2);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let snap = TelemetrySnapshot {
            window: 3,
            p: 2,
            ranks: (0..2)
                .map(|r| RankSnapshot {
                    rank: r,
                    steps: 10 + r as u64,
                    window_steps: 2,
                    wait_app_ns: 100,
                    wait_group_ns: 200,
                    wait_sync_ns: 50,
                    wire_bytes: 4096,
                    skipped_phases: 0,
                    degraded_iters: 0,
                    staleness_sum: 5,
                    staleness_count: 9,
                    membership: 0,
                    window_wait_for_p99_ns: 777,
                    total_wait_for_ns: 1234,
                    blame_peer: if r == 0 { 1 } else { -1 },
                    blame_p99_ns: if r == 0 { 512 } else { 0 },
                    blame_total_ns: if r == 0 { 2048 } else { 0 },
                    health: if r == 1 { Health::Straggler } else { Health::Healthy },
                })
                .collect(),
            fleet_median_p99_ns: 777,
            dropped_trace_events: 0,
            sampler_overruns: 0,
            critpath: vec![
                CritShare { class: "compute".into(), rank: 0, ppm: 900_000 },
                CritShare { class: "wait_for_peer".into(), rank: 1, ppm: 100_000 },
            ],
        };
        let text = snapshot_json(&snap).to_string();
        let back = snapshot_from_json(&Json::parse(&text).expect("parse")).expect("decode");
        assert_eq!(back, snap);
    }

    #[test]
    fn old_schema_without_blame_or_critpath_still_parses() {
        // A pre-blame JSONL record (no blame_* fields, no critpath array)
        // must decode with the tolerant defaults, not error.
        let text = r#"{"window":1,"p":1,"ranks":[{"rank":0,"steps":5,"window_steps":5,
            "wait_app_ns":1,"wait_group_ns":2,"wait_sync_ns":3,"wire_bytes":4,
            "skipped_phases":0,"degraded_iters":0,"staleness_sum":0,"staleness_count":0,
            "membership":0,"window_wait_for_p99_ns":0,"total_wait_for_ns":0,
            "health":"healthy"}],"fleet_median_p99_ns":0,"dropped_trace_events":0,
            "sampler_overruns":0}"#;
        let snap = snapshot_from_json(&Json::parse(text).expect("parse")).expect("decode");
        assert_eq!(snap.ranks[0].blame_peer, -1);
        assert_eq!(snap.ranks[0].blame_total_ns, 0);
        assert!(snap.critpath.is_empty());
    }

    #[test]
    fn blame_top_names_the_worst_peer() {
        let r = RankTelemetry::with_peers(4);
        assert_eq!(r.blame_top(), None);
        r.record_blame_ns(1, 10_000);
        r.record_blame_ns(3, 40_000);
        r.record_blame_ns(3, 50_000);
        r.record_blame_ns(7, 1_000_000); // out of range: dropped, not a panic
        let (peer, p99, total) = r.blame_top().expect("some blame recorded");
        assert_eq!(peer, 3);
        assert_eq!(total, 90_000);
        assert!(p99 >= 50_000);
        // Default-constructed slots have no blame table at all.
        let bare = RankTelemetry::default();
        bare.record_blame_ns(0, 5);
        assert_eq!(bare.blame_top(), None);
    }
}
