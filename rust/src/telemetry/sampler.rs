//! The sampler: periodically snapshots the registry into a deterministic
//! [`TelemetrySnapshot`] and fans it out to sinks.
//!
//! [`TelemetryHub`] holds the sampler-side state (previous cumulative
//! histogram counts for window deltas, the straggler detector) and
//! exposes a synchronous [`TelemetryHub::tick`] so tests and the
//! simulator can drive windows deterministically without a thread.
//! [`Sampler`] wraps a hub in a background thread at a configurable
//! interval; a tick that takes longer than the interval counts as an
//! overrun (surfaced in the end-of-run warning alongside dropped trace
//! events). The latest snapshot is also published into a shared slot the
//! metrics HTTP server and `wagma top` read from.

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::trace::N_BUCKETS;

use super::registry::{
    snapshot_json, window_hist, RankSnapshot, TelemetryRegistry, TelemetrySnapshot,
};
use super::straggler::{StragglerConfig, StragglerDetector};
use super::top::render_top;
use super::Health;

/// Shared slot holding the most recent snapshot (server/`top` read side).
pub type SharedSnapshot = Arc<Mutex<Option<TelemetrySnapshot>>>;

pub fn shared_snapshot() -> SharedSnapshot {
    Arc::new(Mutex::new(None))
}

/// Sampler-side window state over one [`TelemetryRegistry`].
pub struct TelemetryHub {
    registry: Arc<TelemetryRegistry>,
    detector: StragglerDetector,
    prev_counts: Vec<[u64; N_BUCKETS]>,
    prev_sums: Vec<u64>,
    prev_steps: Vec<u64>,
    window: u64,
}

impl TelemetryHub {
    pub fn new(registry: Arc<TelemetryRegistry>, cfg: StragglerConfig) -> TelemetryHub {
        let p = registry.p();
        TelemetryHub {
            registry,
            detector: StragglerDetector::new(p, cfg),
            prev_counts: vec![[0u64; N_BUCKETS]; p],
            prev_sums: vec![0; p],
            prev_steps: vec![0; p],
            window: 0,
        }
    }

    pub fn registry(&self) -> &Arc<TelemetryRegistry> {
        &self.registry
    }

    /// Close the current window: snapshot every rank, difference the
    /// wait-for histograms against the previous window, run the straggler
    /// detector, and fold membership + straggler verdicts into one
    /// [`Health`] per rank (dead ≻ suspect ≻ straggler ≻ healthy).
    pub fn tick(&mut self) -> TelemetrySnapshot {
        self.window += 1;
        let p = self.registry.p();
        let mut p99s = vec![0u64; p];
        let mut rows = Vec::with_capacity(p);
        for r in 0..p {
            let slot = self.registry.rank(r);
            let counts = slot.wait_for().counts();
            let sum = slot.wait_for().sum();
            let win = window_hist(&counts, &self.prev_counts[r], sum - self.prev_sums[r]);
            p99s[r] = win.quantile(0.99) as u64;
            self.prev_counts[r] = counts;
            self.prev_sums[r] = sum;
            rows.push((slot, sum));
        }
        let median = self.detector.observe(&p99s);
        let ranks = rows
            .into_iter()
            .enumerate()
            .map(|(r, (slot, wait_for_sum))| {
                let steps = slot.steps();
                let window_steps = steps - self.prev_steps[r];
                self.prev_steps[r] = steps;
                let membership = slot.membership_code();
                let health = match membership {
                    2 => Health::Dead,
                    1 => Health::Suspect,
                    _ if self.detector.is_straggler(r) => Health::Straggler,
                    _ => Health::Healthy,
                };
                let (blame_peer, blame_p99_ns, blame_total_ns) = match slot.blame_top() {
                    Some((q, p99, total)) => (q as i64, p99, total),
                    None => (-1, 0, 0),
                };
                RankSnapshot {
                    rank: r,
                    steps,
                    window_steps,
                    wait_app_ns: slot.wait_app_ns(),
                    wait_group_ns: slot.wait_group_ns(),
                    wait_sync_ns: slot.wait_sync_ns(),
                    wire_bytes: slot.wire_bytes(),
                    skipped_phases: slot.skipped_phases(),
                    degraded_iters: slot.degraded_iters(),
                    staleness_sum: slot.staleness_sum(),
                    staleness_count: slot.staleness_count(),
                    membership,
                    window_wait_for_p99_ns: p99s[r],
                    total_wait_for_ns: wait_for_sum,
                    blame_peer,
                    blame_p99_ns,
                    blame_total_ns,
                    health,
                }
            })
            .collect();
        TelemetrySnapshot {
            window: self.window,
            p,
            ranks,
            fleet_median_p99_ns: median,
            dropped_trace_events: self.registry.dropped_trace_events(),
            sampler_overruns: self.registry.sampler_overruns(),
            // Critical-path shares are a whole-run property: the CLI
            // attaches them post-run (see `wagma critpath`), live windows
            // publish none.
            critpath: Vec::new(),
        }
    }
}

/// A snapshot consumer. Sinks run on the sampler thread; errors are
/// counted, not fatal (telemetry must never take the run down).
pub trait Sink: Send {
    fn publish(&mut self, snap: &TelemetrySnapshot) -> std::io::Result<()>;
}

/// Appends one JSON object per snapshot to a file (`--telemetry FILE`).
/// Clonable around an `Arc<Mutex<File>>` so several samplers (one per
/// bench preset) can share one output file.
#[derive(Clone)]
pub struct JsonLinesSink {
    file: Arc<Mutex<std::fs::File>>,
}

impl JsonLinesSink {
    pub fn create(path: &str) -> std::io::Result<JsonLinesSink> {
        Ok(JsonLinesSink { file: Arc::new(Mutex::new(std::fs::File::create(path)?)) })
    }
}

impl Sink for JsonLinesSink {
    fn publish(&mut self, snap: &TelemetrySnapshot) -> std::io::Result<()> {
        let line = snapshot_json(snap).to_string();
        let mut f = self.file.lock().map_err(|_| {
            std::io::Error::new(std::io::ErrorKind::Other, "telemetry file lock poisoned")
        })?;
        writeln!(f, "{line}")?;
        // Flush per snapshot so a follower (`wagma top --file`) and a run
        // killed mid-window both see every published line — the end-of-run
        // snapshot must never sit in a userspace buffer.
        f.flush()
    }
}

/// Redraws the `wagma top` dashboard on stderr every window (`--top` on
/// `train`/`bench`).
#[derive(Default)]
pub struct TopSink {
    frames: u64,
}

impl Sink for TopSink {
    fn publish(&mut self, snap: &TelemetrySnapshot) -> std::io::Result<()> {
        let frame = render_top(snap, 80);
        // Home + clear-to-end keeps the dashboard in place on a TTY while
        // staying harmless (plain frames) when stderr is a file.
        if self.frames > 0 {
            eprint!("\x1b[H\x1b[J");
        }
        eprint!("{frame}");
        self.frames += 1;
        Ok(())
    }
}

#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    pub interval: Duration,
    pub straggler: StragglerConfig,
}

impl Default for SamplerConfig {
    fn default() -> SamplerConfig {
        SamplerConfig { interval: Duration::from_millis(250), straggler: StragglerConfig::default() }
    }
}

/// What the sampler thread hands back at shutdown.
#[derive(Debug)]
pub struct SamplerReport {
    pub windows: u64,
    pub overruns: u64,
    pub sink_errors: u64,
    pub last: Option<TelemetrySnapshot>,
}

/// Background sampler thread. [`Sampler::stop`] requests one final tick
/// (so the run's closing counters always reach the sinks) and joins.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<SamplerReport>,
}

impl Sampler {
    pub fn spawn(
        registry: Arc<TelemetryRegistry>,
        cfg: SamplerConfig,
        mut sinks: Vec<Box<dyn Sink>>,
        latest: SharedSnapshot,
    ) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("telemetry-sampler".into())
            .spawn(move || {
                let mut hub = TelemetryHub::new(registry, cfg.straggler);
                let mut sink_errors = 0u64;
                loop {
                    let t0 = Instant::now();
                    let stopping = stop_t.load(Ordering::Acquire);
                    let snap = hub.tick();
                    for s in &mut sinks {
                        if s.publish(&snap).is_err() {
                            sink_errors += 1;
                        }
                    }
                    let windows = snap.window;
                    if let Ok(mut slot) = latest.lock() {
                        *slot = Some(snap);
                    }
                    if stopping {
                        return SamplerReport {
                            windows,
                            overruns: hub.registry().sampler_overruns(),
                            sink_errors,
                            last: latest.lock().ok().and_then(|s| s.clone()),
                        };
                    }
                    let spent = t0.elapsed();
                    if spent >= cfg.interval {
                        hub.registry().add_sampler_overrun();
                    } else {
                        let mut left = cfg.interval - spent;
                        // Sleep in short slices so stop() latency stays low.
                        while !left.is_zero() && !stop_t.load(Ordering::Acquire) {
                            let slice = left.min(Duration::from_millis(10));
                            std::thread::sleep(slice);
                            left = left.saturating_sub(slice);
                        }
                    }
                }
            })
            .expect("spawn telemetry sampler thread");
        Sampler { stop, handle }
    }

    /// Request the final window and join the thread.
    pub fn stop(self) -> SamplerReport {
        self.stop.store(true, Ordering::Release);
        self.handle.join().unwrap_or(SamplerReport {
            windows: 0,
            overruns: 0,
            sink_errors: 0,
            last: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hub_windows_are_deltas_and_detector_folds_in() {
        let reg = Arc::new(TelemetryRegistry::new(2));
        let scfg = StragglerConfig { k: 2.0, w: 2, min_wait_ns: 1_000 };
        let mut hub = TelemetryHub::new(Arc::clone(&reg), scfg);
        for w in 0..3 {
            for _ in 0..50 {
                reg.rank(0).record_wait_for_ns(10_000);
                reg.rank(1).record_wait_for_ns(900_000);
            }
            reg.rank(0).add_step();
            let snap = hub.tick();
            assert_eq!(snap.window, w + 1);
            assert_eq!(snap.ranks[0].window_steps, 1);
            assert!(snap.ranks[1].window_wait_for_p99_ns > snap.ranks[0].window_wait_for_p99_ns);
            if w >= 1 {
                assert_eq!(snap.ranks[1].health, Health::Straggler, "window {w}");
            } else {
                assert_eq!(snap.ranks[1].health, Health::Healthy);
            }
        }
        // Quiet window: the delta histogram is empty, the flag clears.
        let snap = hub.tick();
        assert_eq!(snap.ranks[1].window_wait_for_p99_ns, 0);
        assert_eq!(snap.ranks[1].health, Health::Healthy);
        assert_eq!(snap.ranks[0].window_steps, 0);
    }

    #[test]
    fn membership_outranks_straggler() {
        let reg = Arc::new(TelemetryRegistry::new(2));
        let scfg = StragglerConfig { k: 2.0, w: 1, min_wait_ns: 1_000 };
        let mut hub = TelemetryHub::new(Arc::clone(&reg), scfg);
        reg.rank(1).record_wait_for_ns(5_000_000);
        reg.rank(1).mark_suspect();
        let snap = hub.tick();
        assert_eq!(snap.ranks[1].health, Health::Suspect);
        reg.rank(1).mark_dead();
        let snap = hub.tick();
        assert_eq!(snap.ranks[1].health, Health::Dead);
    }

    #[test]
    fn jsonl_sink_gets_final_snapshot_even_inside_first_window() {
        // A run that finishes well inside the first sampler window must
        // still leave a non-empty JSONL file: stop() forces a final tick
        // and the sink flushes per line.
        let dir = std::env::temp_dir();
        let path = dir.join(format!("wagma_jsonl_flush_{}.jsonl", std::process::id()));
        let path_s = path.to_str().expect("utf8 temp path").to_string();
        let reg = Arc::new(TelemetryRegistry::new(2));
        reg.rank(0).add_step();
        reg.rank(0).record_blame_ns(1, 8_000);
        let sink = JsonLinesSink::create(&path_s).expect("create sink");
        let sampler = Sampler::spawn(
            Arc::clone(&reg),
            // An hour-long window: only the forced final tick can publish.
            SamplerConfig { interval: Duration::from_secs(3600), ..Default::default() },
            vec![Box::new(sink)],
            shared_snapshot(),
        );
        let report = sampler.stop();
        assert!(report.windows >= 1);
        let text = std::fs::read_to_string(&path).expect("read jsonl");
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert!(!lines.is_empty(), "final end-of-run snapshot missing from JSONL");
        let j = crate::util::json::Json::parse(lines[lines.len() - 1]).expect("parse line");
        let snap = super::super::snapshot_from_json(&j).expect("decode");
        assert_eq!(snap.ranks[0].steps, 1);
        assert_eq!(snap.ranks[0].blame_peer, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sampler_thread_final_tick_reaches_latest() {
        let reg = Arc::new(TelemetryRegistry::new(1));
        let latest = shared_snapshot();
        let sampler = Sampler::spawn(
            Arc::clone(&reg),
            SamplerConfig { interval: Duration::from_millis(5), ..Default::default() },
            vec![],
            Arc::clone(&latest),
        );
        reg.rank(0).add_step();
        reg.rank(0).add_wire_bytes(4096);
        std::thread::sleep(Duration::from_millis(20));
        let report = sampler.stop();
        assert!(report.windows >= 1);
        let last = report.last.expect("final snapshot");
        assert_eq!(last.ranks[0].steps, 1);
        assert_eq!(last.ranks[0].wire_bytes, 4096);
        assert_eq!(latest.lock().expect("lock").as_ref().map(|s| s.window), Some(last.window));
    }
}
