//! The `wagma top` dashboard: one plain-text frame per snapshot.
//!
//! Pure function of the snapshot so the CLI (live loop), the `--top`
//! sink, and the tests all render identically. ASCII bars show each
//! rank's window wait-for-peer p99 normalized to the fleet maximum; the
//! health column carries the straggler/membership verdicts.

use super::registry::TelemetrySnapshot;

pub fn fmt_bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{b} B")
    }
}

pub fn fmt_ns(ns: u64) -> String {
    let ns = ns as f64;
    if ns >= 1e9 {
        format!("{:.2}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.1}µs", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

/// Render one dashboard frame. `width` bounds the bar column.
pub fn render_top(snap: &TelemetrySnapshot, width: usize) -> String {
    let bar_w = width.clamp(40, 160) / 4;
    let max_p99 = snap
        .ranks
        .iter()
        .map(|r| r.window_wait_for_p99_ns)
        .max()
        .unwrap_or(0);
    let mut out = String::with_capacity(256 + 96 * snap.ranks.len());
    out.push_str(&format!(
        "wagma top — window {} · {} ranks · fleet median wait p99 {}\n",
        snap.window,
        snap.p,
        fmt_ns(snap.fleet_median_p99_ns)
    ));
    out.push_str(&format!(
        "{:<5} {:<10} {:>8} {:>8} {:>12}  {:<bar$}  {:>10} {:>10} {:>9} {:>14}\n",
        "rank",
        "health",
        "steps",
        "win-st",
        "win-p99-wait",
        "wait bar",
        "wire",
        "skip/degr",
        "staleness",
        "blames",
        bar = bar_w + 2,
    ));
    for r in &snap.ranks {
        let filled = if max_p99 == 0 {
            0
        } else {
            ((r.window_wait_for_p99_ns as f64 / max_p99 as f64) * bar_w as f64).round() as usize
        };
        let bar: String = "#".repeat(filled.min(bar_w))
            + &".".repeat(bar_w - filled.min(bar_w));
        let stale = if r.staleness_count == 0 {
            0.0
        } else {
            r.staleness_sum as f64 / r.staleness_count as f64
        };
        // Who this rank blames: the peer it has spent the most blocked
        // time waiting on, with the p99 of that per-peer distribution.
        let blames = if r.blame_peer < 0 {
            "-".to_string()
        } else {
            format!("r{} p99 {}", r.blame_peer, fmt_ns(r.blame_p99_ns))
        };
        out.push_str(&format!(
            "r{:<4} {:<10} {:>8} {:>8} {:>12}  |{bar}|  {:>10} {:>6}/{:<3} {:>9.2} {:>14}\n",
            r.rank,
            r.health.name().to_uppercase(),
            r.steps,
            r.window_steps,
            fmt_ns(r.window_wait_for_p99_ns),
            fmt_bytes(r.wire_bytes),
            r.skipped_phases,
            r.degraded_iters,
            stale,
            blames,
        ));
    }
    out.push_str(&format!(
        "dropped trace events: {} · sampler overruns: {}\n",
        snap.dropped_trace_events, snap.sampler_overruns
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::super::registry::RankSnapshot;
    use super::super::Health;
    use super::*;

    #[test]
    fn frame_shows_straggler_and_scales_bars() {
        let snap = TelemetrySnapshot {
            window: 5,
            p: 2,
            ranks: vec![
                RankSnapshot {
                    rank: 0,
                    steps: 40,
                    window_steps: 8,
                    wait_app_ns: 0,
                    wait_group_ns: 0,
                    wait_sync_ns: 0,
                    wire_bytes: 1 << 20,
                    skipped_phases: 0,
                    degraded_iters: 0,
                    staleness_sum: 10,
                    staleness_count: 20,
                    membership: 0,
                    window_wait_for_p99_ns: 50_000,
                    total_wait_for_ns: 100_000,
                    blame_peer: -1,
                    blame_p99_ns: 0,
                    blame_total_ns: 0,
                    health: Health::Healthy,
                },
                RankSnapshot {
                    rank: 1,
                    steps: 22,
                    window_steps: 3,
                    wait_app_ns: 0,
                    wait_group_ns: 0,
                    wait_sync_ns: 0,
                    wire_bytes: 1 << 19,
                    skipped_phases: 2,
                    degraded_iters: 1,
                    staleness_sum: 0,
                    staleness_count: 0,
                    membership: 0,
                    window_wait_for_p99_ns: 9_000_000,
                    total_wait_for_ns: 90_000_000,
                    blame_peer: 0,
                    blame_p99_ns: 2_500_000,
                    blame_total_ns: 80_000_000,
                    health: Health::Straggler,
                },
            ],
            fleet_median_p99_ns: 50_000,
            dropped_trace_events: 3,
            sampler_overruns: 0,
            critpath: Vec::new(),
        };
        let frame = render_top(&snap, 80);
        assert!(frame.contains("STRAGGLER"), "{frame}");
        assert!(frame.contains("HEALTHY"), "{frame}");
        assert!(frame.contains("dropped trace events: 3"), "{frame}");
        // The blames column names the top blamed peer with its p99; a
        // rank with no blame yet shows a dash.
        assert!(frame.contains("blames"), "{frame}");
        assert!(frame.contains("r0 p99 2.50ms"), "{frame}");
        let lines: Vec<&str> = frame.lines().collect();
        assert!(lines[2].trim_end().ends_with('-'), "{frame}");
        // The straggler's bar is full, the healthy rank's nearly empty.
        let full = lines[3].matches('#').count();
        let sparse = lines[2].matches('#').count();
        assert!(full > sparse, "{frame}");
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_ns(1_500), "1.5µs");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(999), "999ns");
    }
}
