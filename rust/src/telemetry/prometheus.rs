//! Prometheus text exposition (format 0.0.4) and the minimal blocking
//! HTTP listener behind `--metrics-addr`.
//!
//! Everything is hand-rolled over `std::net` — no HTTP or metrics crate.
//! [`render`] turns a [`TelemetrySnapshot`] into the text format,
//! [`parse_exposition`]/[`lint_exposition`] parse it back and check
//! naming/label/HELP/TYPE rules (used by the round-trip tests so the
//! endpoint stays scrapeable by a real Prometheus), and
//! [`MetricsServer`] serves `/metrics` (text exposition),
//! `/snapshot.json` (the JSON-lines record, which `wagma top --addr`
//! polls), and `/healthz` from the sampler's latest-snapshot slot. The
//! listener itself now runs on the [`crate::serve::http`] mini-router
//! (which was factored out of this file's original hand-rolled accept
//! loop); the metrics routes are mounted through
//! [`crate::serve::add_metrics_routes`], shared with the `wagma serve`
//! daemon.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use crate::util::json::Json;

use super::registry::{snapshot_from_json, TelemetrySnapshot};
use super::sampler::SharedSnapshot;

const NS_PER_SEC: f64 = 1e9;

fn family(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

fn sample(out: &mut String, name: &str, labels: &[(&str, String)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            out.push_str(v);
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&format!("{value}"));
    out.push('\n');
}

/// Render one snapshot as Prometheus text exposition.
pub fn render(snap: &TelemetrySnapshot) -> String {
    let mut o = String::with_capacity(4096);
    let rank = |r: usize| vec![("rank", r.to_string())];

    family(&mut o, "wagma_steps_total", "Training steps completed per rank.", "counter");
    for r in &snap.ranks {
        sample(&mut o, "wagma_steps_total", &rank(r.rank), r.steps as f64);
    }
    family(
        &mut o,
        "wagma_wait_app_seconds_total",
        "App-thread exposed communication wait per rank.",
        "counter",
    );
    for r in &snap.ranks {
        sample(
            &mut o,
            "wagma_wait_app_seconds_total",
            &rank(r.rank),
            r.wait_app_ns as f64 / NS_PER_SEC,
        );
    }
    family(
        &mut o,
        "wagma_wait_engine_seconds_total",
        "Engine-thread blocked-receive wait per rank by attribution class.",
        "counter",
    );
    for r in &snap.ranks {
        sample(
            &mut o,
            "wagma_wait_engine_seconds_total",
            &[("class", "group".into()), ("rank", r.rank.to_string())],
            r.wait_group_ns as f64 / NS_PER_SEC,
        );
        sample(
            &mut o,
            "wagma_wait_engine_seconds_total",
            &[("class", "sync".into()), ("rank", r.rank.to_string())],
            r.wait_sync_ns as f64 / NS_PER_SEC,
        );
    }
    family(&mut o, "wagma_wire_bytes_total", "Bytes put on the wire per rank.", "counter");
    for r in &snap.ranks {
        sample(&mut o, "wagma_wire_bytes_total", &rank(r.rank), r.wire_bytes as f64);
    }
    family(
        &mut o,
        "wagma_skipped_phases_total",
        "Group-exchange phases completed as identity after a peer timed out.",
        "counter",
    );
    for r in &snap.ranks {
        sample(&mut o, "wagma_skipped_phases_total", &rank(r.rank), r.skipped_phases as f64);
    }
    family(
        &mut o,
        "wagma_degraded_iters_total",
        "Iterations that took at least one degraded path.",
        "counter",
    );
    for r in &snap.ranks {
        sample(&mut o, "wagma_degraded_iters_total", &rank(r.rank), r.degraded_iters as f64);
    }
    family(
        &mut o,
        "wagma_staleness_iters_total",
        "Sum of contribution staleness (iterations) folded into collectives.",
        "counter",
    );
    for r in &snap.ranks {
        sample(&mut o, "wagma_staleness_iters_total", &rank(r.rank), r.staleness_sum as f64);
    }
    family(
        &mut o,
        "wagma_staleness_samples_total",
        "Number of staleness samples behind wagma_staleness_iters_total.",
        "counter",
    );
    for r in &snap.ranks {
        sample(&mut o, "wagma_staleness_samples_total", &rank(r.rank), r.staleness_count as f64);
    }
    family(
        &mut o,
        "wagma_membership_state",
        "fault::Membership verdict: 0 healthy, 1 suspect, 2 dead.",
        "gauge",
    );
    for r in &snap.ranks {
        sample(&mut o, "wagma_membership_state", &rank(r.rank), r.membership as f64);
    }
    family(
        &mut o,
        "wagma_health_state",
        "Folded health: 0 healthy, 1 straggler, 2 suspect, 3 dead.",
        "gauge",
    );
    for r in &snap.ranks {
        sample(&mut o, "wagma_health_state", &rank(r.rank), r.health.code() as f64);
    }
    family(
        &mut o,
        "wagma_straggler",
        "1 while the straggler detector flags this rank.",
        "gauge",
    );
    for r in &snap.ranks {
        sample(
            &mut o,
            "wagma_straggler",
            &rank(r.rank),
            if r.health == super::Health::Straggler { 1.0 } else { 0.0 },
        );
    }
    family(
        &mut o,
        "wagma_wait_for_peer_p99_seconds",
        "Window p99 of time peers spent blocked waiting on this rank.",
        "gauge",
    );
    for r in &snap.ranks {
        sample(
            &mut o,
            "wagma_wait_for_peer_p99_seconds",
            &rank(r.rank),
            r.window_wait_for_p99_ns as f64 / NS_PER_SEC,
        );
    }
    family(
        &mut o,
        "wagma_window_steps",
        "Steps completed during the last sampler window (step rate proxy).",
        "gauge",
    );
    for r in &snap.ranks {
        sample(&mut o, "wagma_window_steps", &rank(r.rank), r.window_steps as f64);
    }
    family(
        &mut o,
        "wagma_fleet_median_wait_p99_seconds",
        "Fleet lower-median of the per-rank window wait-for p99s.",
        "gauge",
    );
    sample(
        &mut o,
        "wagma_fleet_median_wait_p99_seconds",
        &[],
        snap.fleet_median_p99_ns as f64 / NS_PER_SEC,
    );
    family(&mut o, "wagma_telemetry_window", "Sampler window sequence number.", "gauge");
    sample(&mut o, "wagma_telemetry_window", &[], snap.window as f64);
    family(&mut o, "wagma_ranks", "World size of the instrumented run.", "gauge");
    sample(&mut o, "wagma_ranks", &[], snap.p as f64);
    family(
        &mut o,
        "wagma_dropped_trace_events_total",
        "Trace ring overflows across all ranks.",
        "counter",
    );
    sample(&mut o, "wagma_dropped_trace_events_total", &[], snap.dropped_trace_events as f64);
    family(
        &mut o,
        "wagma_sampler_overruns_total",
        "Sampler ticks that exceeded the sampling interval.",
        "counter",
    );
    sample(&mut o, "wagma_sampler_overruns_total", &[], snap.sampler_overruns as f64);
    family(
        &mut o,
        "wagma_critpath_share",
        "Fraction of the run's critical path per attribution class and rank.",
        "gauge",
    );
    for c in &snap.critpath {
        sample(
            &mut o,
            "wagma_critpath_share",
            &[("class", c.class.clone()), ("rank", c.rank.to_string())],
            c.ppm as f64 / 1e6,
        );
    }
    o
}

/// One parsed exposition sample.
#[derive(Debug, Clone, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_labels(body: &str) -> Result<Vec<(String, String)>, String> {
    let mut out = Vec::new();
    let body = body.trim();
    if body.is_empty() {
        return Ok(out);
    }
    for pair in body.split(',') {
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| format!("label pair `{pair}` has no `=`"))?;
        let v = v.trim();
        if !(v.starts_with('"') && v.ends_with('"') && v.len() >= 2) {
            return Err(format!("label value `{v}` not quoted"));
        }
        out.push((k.trim().to_string(), v[1..v.len() - 1].to_string()));
    }
    Ok(out)
}

/// Parse the text exposition into samples (comments skipped).
pub fn parse_exposition(text: &str) -> Result<Vec<PromSample>, String> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("sample line `{line}` has no value"))?;
        let value: f64 = value
            .parse()
            .map_err(|_| format!("sample line `{line}`: bad float `{value}`"))?;
        let (name, labels) = match head.find('{') {
            Some(i) => {
                if !head.ends_with('}') {
                    return Err(format!("sample line `{line}`: unterminated label set"));
                }
                (&head[..i], parse_labels(&head[i + 1..head.len() - 1])?)
            }
            None => (head, Vec::new()),
        };
        out.push(PromSample { name: name.to_string(), labels, value });
    }
    Ok(out)
}

/// Format lint: metric/label naming, HELP+TYPE present before samples,
/// known TYPE values, counters suffixed `_total` (our convention so the
/// exposition follows Prometheus best practice).
pub fn lint_exposition(text: &str) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut helps: BTreeMap<String, bool> = BTreeMap::new();
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            if !valid_metric_name(name) {
                return Err(format!("HELP for invalid metric name `{name}`"));
            }
            helps.insert(name.to_string(), true);
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("metric `{name}` has unknown TYPE `{kind}`"));
            }
            if kind == "counter" && !name.ends_with("_total") {
                return Err(format!("counter `{name}` does not end in _total"));
            }
            types.insert(name.to_string(), kind.to_string());
        } else if line.starts_with('#') {
            continue;
        } else {
            let sample = parse_exposition(line)?
                .pop()
                .ok_or_else(|| format!("unparseable sample `{line}`"))?;
            if !valid_metric_name(&sample.name) {
                return Err(format!("invalid metric name `{}`", sample.name));
            }
            if !helps.contains_key(&sample.name) {
                return Err(format!("sample `{}` has no preceding HELP", sample.name));
            }
            if !types.contains_key(&sample.name) {
                return Err(format!("sample `{}` has no preceding TYPE", sample.name));
            }
            for (k, _) in &sample.labels {
                if !valid_label_name(k) {
                    return Err(format!("metric `{}`: invalid label name `{k}`", sample.name));
                }
            }
        }
    }
    if helps.is_empty() {
        return Err("exposition contains no metric families".into());
    }
    Ok(())
}

/// Minimal HTTP listener serving the latest snapshot, built on the
/// shared [`crate::serve::http`] router (this listener was the seed
/// that router was factored out of). `/metrics` + `/snapshot.json`
/// come from [`crate::serve::add_metrics_routes`] — the same builder
/// the `wagma serve` daemon mounts, so `wagma top --addr` and a
/// Prometheus scraper work identically against either endpoint.
pub struct MetricsServer {
    server: crate::serve::http::Server,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9464`; port 0 picks an ephemeral
    /// port, see [`MetricsServer::local_addr`]) and serve until dropped.
    pub fn serve(addr: &str, latest: SharedSnapshot) -> std::io::Result<MetricsServer> {
        let hz = Arc::clone(&latest);
        let router = crate::serve::add_metrics_routes(
            crate::serve::http::Router::new().get("/", |_req, resp| {
                resp.full(
                    "200 OK",
                    "text/plain",
                    "wagma telemetry: /metrics /snapshot.json /healthz\n",
                )
            }),
            latest,
        )
        .get("/healthz", move |_req, resp| {
            // Health body carries the observability-loss counters so a
            // probe can alert on silent data loss without parsing the
            // full exposition.
            let (dropped, overruns) = hz
                .lock()
                .ok()
                .and_then(|s| s.clone())
                .map(|s| (s.dropped_trace_events, s.sampler_overruns))
                .unwrap_or((0, 0));
            resp.full(
                "200 OK",
                "text/plain",
                &format!("ok dropped_trace_events={dropped} sampler_overruns={overruns}\n"),
            )
        });
        let server =
            crate::serve::http::Server::serve(addr, "wagma-metrics", Arc::new(router))?;
        Ok(MetricsServer { server })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.server.local_addr()
    }

    /// Successfully answered requests (any route).
    pub fn requests_served(&self) -> u64 {
        self.server.requests_served()
    }

    /// The underlying router (the lint-every-served-route test sweeps
    /// [`crate::serve::http::Router::served_routes`] through this).
    pub fn router(&self) -> &Arc<crate::serve::http::Router> {
        self.server.router()
    }
}

/// Blocking GET of `/snapshot.json` from a running [`MetricsServer`]
/// (`wagma top --addr`). `addr` is `host:port`.
pub fn fetch_snapshot(addr: &str) -> Result<TelemetrySnapshot, String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_read_timeout(Some(Duration::from_secs(2)))
        .map_err(|e| e.to_string())?;
    let req = format!("GET /snapshot.json HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp).map_err(|e| e.to_string())?;
    let (head, body) = resp
        .split_once("\r\n\r\n")
        .ok_or("malformed HTTP response")?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains("200") {
        return Err(format!("{addr}: {status}"));
    }
    let j = Json::parse(body).map_err(|e| format!("snapshot body: {e}"))?;
    snapshot_from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::super::registry::RankSnapshot;
    use super::super::Health;
    use super::*;

    fn snap() -> TelemetrySnapshot {
        TelemetrySnapshot {
            window: 2,
            p: 2,
            ranks: (0..2)
                .map(|r| RankSnapshot {
                    rank: r,
                    steps: 7,
                    window_steps: 3,
                    wait_app_ns: 1_500_000,
                    wait_group_ns: 2_000_000,
                    wait_sync_ns: 500_000,
                    wire_bytes: 65536,
                    skipped_phases: 1,
                    degraded_iters: 1,
                    staleness_sum: 4,
                    staleness_count: 7,
                    membership: 0,
                    window_wait_for_p99_ns: 900_000,
                    total_wait_for_ns: 3_000_000,
                    blame_peer: if r == 0 { 1 } else { -1 },
                    blame_p99_ns: if r == 0 { 900_000 } else { 0 },
                    blame_total_ns: if r == 0 { 3_000_000 } else { 0 },
                    health: if r == 1 { Health::Straggler } else { Health::Healthy },
                })
                .collect(),
            fleet_median_p99_ns: 450_000,
            dropped_trace_events: 2,
            sampler_overruns: 1,
            critpath: vec![
                super::super::registry::CritShare {
                    class: "compute".into(),
                    rank: 0,
                    ppm: 750_000,
                },
                super::super::registry::CritShare {
                    class: "wait_for_peer".into(),
                    rank: 1,
                    ppm: 250_000,
                },
            ],
        }
    }

    #[test]
    fn render_lints_and_parses_back() {
        let text = render(&snap());
        lint_exposition(&text).expect("lint");
        let samples = parse_exposition(&text).expect("parse");
        let find = |name: &str, rank: &str| {
            samples
                .iter()
                .find(|s| {
                    s.name == name
                        && s.labels.iter().any(|(k, v)| k == "rank" && v == rank)
                })
                .unwrap_or_else(|| panic!("missing {name}{{rank={rank}}}"))
                .value
        };
        assert_eq!(find("wagma_steps_total", "0"), 7.0);
        assert_eq!(find("wagma_wire_bytes_total", "1"), 65536.0);
        assert_eq!(find("wagma_straggler", "1"), 1.0);
        assert_eq!(find("wagma_straggler", "0"), 0.0);
        assert_eq!(find("wagma_health_state", "1"), Health::Straggler.code() as f64);
        let windows: Vec<_> =
            samples.iter().filter(|s| s.name == "wagma_telemetry_window").collect();
        assert_eq!(windows.len(), 1);
        assert_eq!(windows[0].value, 2.0);
        // Critical-path share gauges carry class+rank labels, value in
        // [0,1] (ppm / 1e6).
        let share = samples
            .iter()
            .find(|s| {
                s.name == "wagma_critpath_share"
                    && s.labels.iter().any(|(k, v)| k == "class" && v == "compute")
            })
            .expect("critpath share gauge");
        assert_eq!(share.value, 0.75);
        assert!(share.labels.iter().any(|(k, v)| k == "rank" && v == "0"));
    }

    #[test]
    fn lint_rejects_malformed_expositions() {
        assert!(lint_exposition("wagma_x 1\n").is_err(), "sample without HELP/TYPE");
        assert!(
            lint_exposition("# HELP bad-name x\n# TYPE bad-name gauge\nbad-name 1\n").is_err(),
            "invalid name"
        );
        assert!(
            lint_exposition("# HELP wagma_c c\n# TYPE wagma_c counter\nwagma_c 1\n").is_err(),
            "counter without _total"
        );
        assert!(lint_exposition("").is_err(), "empty exposition");
    }

    #[test]
    fn server_serves_metrics_and_snapshot() {
        let latest: SharedSnapshot = Arc::new(std::sync::Mutex::new(None));
        let server = MetricsServer::serve("127.0.0.1:0", Arc::clone(&latest)).expect("bind");
        let addr = server.local_addr().to_string();
        // No snapshot yet: snapshot fetch reports the 503.
        assert!(fetch_snapshot(&addr).is_err());
        *latest.lock().expect("lock") = Some(snap());
        let got = fetch_snapshot(&addr).expect("fetch");
        assert_eq!(got, snap());
        // Raw /metrics scrape lints.
        let mut stream = TcpStream::connect(&addr).expect("connect");
        stream
            .write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
            .expect("write");
        let mut resp = String::new();
        stream.read_to_string(&mut resp).expect("read");
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        let body = resp.split_once("\r\n\r\n").expect("body").1;
        lint_exposition(body).expect("scrape lints");
        // /healthz surfaces the observability-loss counters.
        let mut hz = TcpStream::connect(&addr).expect("connect healthz");
        hz.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
            .expect("write healthz");
        let mut hz_resp = String::new();
        hz.read_to_string(&mut hz_resp).expect("read healthz");
        let hz_body = hz_resp.split_once("\r\n\r\n").expect("healthz body").1;
        assert_eq!(hz_body, "ok dropped_trace_events=2 sampler_overruns=1\n", "{hz_resp}");
        assert!(server.requests_served() >= 3);
    }
}
