//! Figure harnesses: one entry point per paper figure/ablation
//! (DESIGN.md §4). Each regenerates the figure's series — printed as a
//! table and written as CSV under the output directory.

use std::path::Path;
use std::sync::Arc;

use crate::config::preset;
use crate::data::{ImbalanceModel, StepDelays};
use crate::metrics::{CsvWriter, TrainResult};
use crate::optim::engine::EngineFactory;
use crate::optim::pjrt_engine::{PjrtEngine, RlEngine};
use crate::optim::{run_training, Algorithm, SleepEngine, TrainConfig};
use crate::runtime::ModelRuntime;
use crate::util::stats::{ascii_histogram, Summary};

/// Scale factor applied to paper-seconds in the real-thread convergence
/// figures (sleeps shrink 20×; ratios between algorithms are preserved).
pub const TIME_SCALE: f64 = 0.05;

/// Open a figure CSV under `out_dir`, refusing to clobber an existing
/// output unless `force` — figure series are expensive to regenerate and
/// silently overwriting them loses the previous sweep. Shared by every
/// figure harness so the `--force` contract is uniform.
pub fn create_csv(
    out_dir: &str,
    name: &str,
    header: &[&str],
    force: bool,
) -> anyhow::Result<CsvWriter> {
    let path = Path::new(out_dir).join(name);
    if path.exists() && !force {
        anyhow::bail!(
            "refusing to overwrite existing output {} (pass --force to regenerate)",
            path.display()
        );
    }
    Ok(CsvWriter::create(path, header)?)
}

/// Rolling telemetry for a simulator figure sweep: counts solved cells,
/// the harness's own wall-clock, and the modelled bytes-on-wire each cell
/// moved (`wire_bytes_per_iter × P × steps`). Every sweep ends with the
/// same `[telemetry]` summary line the instrumented `train`/`bench` paths
/// emit, so figure regeneration cost shows up in the same vocabulary as
/// live runs.
struct SweepTelemetry {
    started: std::time::Instant,
    cells: usize,
    wire_bytes: f64,
}

impl SweepTelemetry {
    fn start() -> Self {
        Self { started: std::time::Instant::now(), cells: 0, wire_bytes: 0.0 }
    }

    /// Record one solved sweep cell (one printed/CSV row).
    fn record(&mut self, r: &crate::simulator::SimResult) {
        self.cells += 1;
        self.wire_bytes += r.wire_bytes_per_iter * r.p as f64 * r.steps as f64;
    }

    /// The sweep's final summary line.
    fn finish(self, figure: &str) {
        let wall = self.started.elapsed().as_secs_f64().max(1e-9);
        println!(
            "[telemetry] {figure}: {} cells in {:.2}s ({:.1} cells/s), \
             total modelled wire {:.3e} B",
            self.cells,
            wall,
            self.cells as f64 / wall,
            self.wire_bytes,
        );
    }
}

/// Throughput figures (Fig. 4 / 7 / 10): simulator sweep over
/// (algorithm × node count). Cells run through `client` — in-process by
/// default, a `wagma serve` daemon under `--addr` (identical output
/// either way: the canonical result codec is exact).
pub fn fig_throughput(
    name: &str,
    out_dir: &str,
    quick: bool,
    force: bool,
    client: &crate::serve::Client,
) -> anyhow::Result<()> {
    let p = preset(name).ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    println!("== {} — {} ==", p.name, p.description);
    println!(
        "{:<14} {:>6} {:>16} {:>16} {:>10} {:>10}",
        "algorithm", "P", "throughput/s", "ideal/s", "eff", "skew(s)"
    );
    let mut csv = create_csv(
        out_dir,
        &format!("{name}.csv"),
        &["algo", "p", "throughput", "ideal_throughput", "efficiency", "mean_skew_s"],
        force,
    )?;
    let counts: Vec<usize> =
        if quick { p.node_counts.iter().copied().take(2).collect() } else { p.node_counts.to_vec() };
    let mut tele = SweepTelemetry::start();
    for &n in &counts {
        for &algo in p.algos {
            let mut cfg = p.sim_config(algo, n, 42);
            if quick {
                cfg.steps = 50;
            }
            let r = client.simulate(&cfg)?;
            tele.record(&r);
            let thr = r.throughput(p.batch);
            let ideal = r.ideal_throughput(p.batch);
            println!(
                "{:<14} {:>6} {:>16.0} {:>16.0} {:>9.1}% {:>10.3}",
                algo.name(),
                n,
                thr,
                ideal,
                100.0 * thr / ideal,
                r.mean_skew
            );
            csv.row(&[
                algo.name().to_string(),
                n.to_string(),
                format!("{thr:.1}"),
                format!("{ideal:.1}"),
                format!("{:.4}", thr / ideal),
                format!("{:.4}", r.mean_skew),
            ])?;
        }
        println!();
    }
    tele.finish(name);
    Ok(())
}

/// Fig. 6 / Fig. 9: per-step runtime distributions of the two imbalanced
/// workloads (bucketed sentence lengths; heavy-tailed experience
/// collection).
pub fn fig_distribution(name: &str, out_dir: &str, force: bool) -> anyhow::Result<()> {
    let (model, label) = match name {
        "fig6" => (ImbalanceModel::fig7(), "Transformer per-step runtime (bucketed lengths)"),
        "fig9" => (ImbalanceModel::fig9(), "RL experience-collection runtime (heavy tail)"),
        _ => anyhow::bail!("unknown distribution figure {name}"),
    };
    let mut d = StepDelays::new(model, 1, 42);
    let samples: Vec<f64> = (0..5000).map(|_| d.sample_step()[0]).collect();
    let s = Summary::of(&samples);
    println!("== {name} — {label} ==");
    println!(
        "n={} mean={:.3}s p50={:.3}s p95={:.3}s p99={:.3}s max={:.3}s",
        s.n, s.mean, s.p50, s.p95, s.p99, s.max
    );
    println!("{}", ascii_histogram(&samples, 16, 50));
    let mut csv = create_csv(out_dir, &format!("{name}.csv"), &["seconds"], force)?;
    for x in &samples {
        csv.rowf(&[*x])?;
    }
    Ok(())
}

/// Shared driver for the convergence figures: run each algorithm on the
/// same model with the same injected imbalance, and report the task metric
/// over (scaled) wall-clock time.
#[allow(clippy::too_many_arguments)]
pub fn convergence_sweep(
    figure: &str,
    model: &'static str,
    artifacts_dir: &'static str,
    algos: &[Algorithm],
    p: usize,
    steps: u64,
    tau: u64,
    lr: f32,
    imbalance: ImbalanceModel,
    out_dir: &str,
    force: bool,
) -> anyhow::Result<Vec<TrainResult>> {
    let init = ModelRuntime::load(artifacts_dir, model)?.init_params()?;
    let is_rl = model.starts_with("policy");
    let mut results = Vec::new();
    let mut csv = create_csv(
        out_dir,
        &format!("{figure}.csv"),
        &["algo", "step", "metric", "wall_s", "train_loss"],
        force,
    )?;

    for &algo in algos {
        let schedule = SleepEngine::<PjrtEngine>::schedule(imbalance, p, steps as usize, 42);
        let factory: EngineFactory = {
            let schedule = schedule.clone();
            Arc::new(move |rank| {
                if is_rl {
                    let eng = RlEngine::new(artifacts_dir, model, rank, 42)
                        .expect("load RL engine");
                    Box::new(SleepEngine::new(eng, rank, schedule.clone(), TIME_SCALE))
                } else {
                    let eng = PjrtEngine::new(artifacts_dir, model, rank, 42)
                        .expect("load PJRT engine");
                    Box::new(SleepEngine::new(eng, rank, schedule.clone(), TIME_SCALE))
                }
            })
        };
        let cfg = TrainConfig {
            algo,
            p,
            steps,
            lr,
            tau,
            eval_every: (steps / 20).max(1),
            init: init.clone(),
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let r = run_training(&cfg, factory);
        let wall = t0.elapsed().as_secs_f64();
        let curve = r.eval_curve();
        let last = curve.last().map(|(_, v)| *v).unwrap_or(f32::NAN);
        println!(
            "{figure}: {:<14} wall={:>7.1}s final_metric={:>8.4} mean_staleness={:.2} divergence={:.2e}",
            algo.name(),
            wall,
            last,
            r.mean_staleness(),
            r.model_divergence()
        );
        let losses = r.loss_curve();
        for (i, (step, metric)) in curve.iter().enumerate() {
            // Approximate wall time at this eval point: proportional share.
            let w = wall * (i + 1) as f64 / curve.len() as f64;
            let train_loss = losses
                .get(*step as usize)
                .map(|(_, l)| *l)
                .unwrap_or(f32::NAN);
            csv.row(&[
                algo.name().to_string(),
                step.to_string(),
                format!("{metric}"),
                format!("{w:.3}"),
                format!("{train_loss}"),
            ])?;
        }
        results.push(r);
    }
    Ok(results)
}

/// Fig. 5 analogue: classifier accuracy under the Fig. 4 imbalance.
pub fn fig5(out_dir: &str, quick: bool, force: bool) -> anyhow::Result<()> {
    let steps = if quick { 60 } else { 400 };
    let algos = [
        Algorithm::Wagma,
        Algorithm::AllreduceSgd,
        Algorithm::LocalSgd,
        Algorithm::DPsgd,
        Algorithm::Sgp,
        Algorithm::AdPsgd,
        Algorithm::EagerSgd,
    ];
    println!("== fig5 — classifier accuracy vs time (imbalanced, P=8) ==");
    convergence_sweep(
        "fig5",
        "mlp_small",
        "artifacts",
        &algos,
        8,
        steps,
        10,
        0.05,
        ImbalanceModel::fig4(),
        out_dir,
        force,
    )?;
    Ok(())
}

/// Fig. 8 analogue: LM eval loss under bucketed-length imbalance.
pub fn fig8(out_dir: &str, quick: bool, force: bool) -> anyhow::Result<()> {
    let steps = if quick { 40 } else { 200 };
    let algos = [
        Algorithm::Wagma,
        Algorithm::AllreduceSgd,
        Algorithm::LocalSgd,
        Algorithm::DPsgd,
        Algorithm::Sgp,
        Algorithm::AdPsgd,
    ];
    println!("== fig8 — LM eval loss vs time (bucketed imbalance, P=4) ==");
    convergence_sweep(
        "fig8",
        "lm_tiny",
        "artifacts",
        &algos,
        4,
        steps,
        8,
        0.1,
        ImbalanceModel::fig7(),
        out_dir,
        force,
    )?;
    Ok(())
}

/// Fig. 11 analogue: RL mean return vs time (heavy-tailed collection).
pub fn fig11(out_dir: &str, quick: bool, force: bool) -> anyhow::Result<()> {
    let steps = if quick { 40 } else { 300 };
    let algos = [
        Algorithm::Wagma,
        Algorithm::LocalSgd,
        Algorithm::DPsgd,
        Algorithm::Sgp,
        Algorithm::AdPsgd,
    ];
    println!("== fig11 — RL mean return vs time (P=4) ==");
    convergence_sweep(
        "fig11",
        "policy_tiny",
        "artifacts",
        &algos,
        4,
        steps,
        8,
        0.003,
        ImbalanceModel::fig9(),
        out_dir,
        force,
    )?;
    Ok(())
}

/// Ablations ❶–❹ (paper §V-B): WAGMA variants on the classifier.
pub fn ablation(out_dir: &str, quick: bool, force: bool) -> anyhow::Result<()> {
    let steps = if quick { 60 } else { 400 };
    let p = 16;
    let init = ModelRuntime::load("artifacts", "mlp_small")?.init_params()?;
    println!("== ablation — WAGMA design choices (P={p}, mlp_small) ==");
    let mut csv = create_csv(
        out_dir,
        "ablation.csv",
        &["variant", "final_metric", "mean_staleness"],
        force,
    )?;

    struct Variant {
        name: &'static str,
        algo: Algorithm,
        group_size: usize,
        dynamic: bool,
        tau: u64,
        local_h: u64,
    }
    let variants = [
        Variant { name: "wagma_sqrtP", algo: Algorithm::Wagma, group_size: 0, dynamic: true, tau: 10, local_h: 1 },
        // ❶ no group collectives: local SGD with H = τ.
        Variant { name: "no_group_collectives", algo: Algorithm::LocalSgd, group_size: 0, dynamic: true, tau: 10, local_h: 10 },
        // ❷ fixed groups.
        Variant { name: "fixed_groups", algo: Algorithm::Wagma, group_size: 0, dynamic: false, tau: 10, local_h: 1 },
        // ❸ S = P (global collective).
        Variant { name: "group_size_P", algo: Algorithm::Wagma, group_size: p, dynamic: true, tau: 10, local_h: 1 },
        // ❹ S = 2 (gossip-sized groups).
        Variant { name: "group_size_2", algo: Algorithm::Wagma, group_size: 2, dynamic: true, tau: 10, local_h: 1 },
    ];

    for v in &variants {
        let schedule =
            SleepEngine::<PjrtEngine>::schedule(ImbalanceModel::fig4(), p, steps as usize, 42);
        let factory: EngineFactory = {
            let schedule = schedule.clone();
            Arc::new(move |rank| {
                let eng =
                    PjrtEngine::new("artifacts", "mlp_small", rank, 42).expect("load engine");
                Box::new(SleepEngine::new(eng, rank, schedule.clone(), TIME_SCALE))
            })
        };
        let cfg = TrainConfig {
            algo: v.algo,
            p,
            steps,
            lr: 0.05,
            tau: v.tau,
            group_size: v.group_size,
            dynamic_groups: v.dynamic,
            local_sgd_h: v.local_h,
            eval_every: (steps / 10).max(1),
            init: init.clone(),
            ..Default::default()
        };
        let r = run_training(&cfg, factory);
        let last = r.eval_curve().last().map(|(_, v)| *v).unwrap_or(f32::NAN);
        println!(
            "{:<24} final_metric={:>8.4} staleness={:.2}",
            v.name,
            last,
            r.mean_staleness()
        );
        csv.row(&[
            v.name.to_string(),
            format!("{last}"),
            format!("{:.4}", r.mean_staleness()),
        ])?;
    }
    Ok(())
}

/// Fusion/overlap study (the scheduling subsystem's figure): simulated
/// makespan of flat vs layered exchanges on the fig4 preset, across fusion
/// modes and bucket thresholds. Quantifies how much communication the
/// bucket timeline hides under backprop.
pub fn fig_fusion(
    out_dir: &str,
    quick: bool,
    force: bool,
    client: &crate::serve::Client,
) -> anyhow::Result<()> {
    use crate::sched::{FusionConfig, FusionMode, FusionPlan, LayerProfile};

    let pre = preset("fig4").ok_or_else(|| anyhow::anyhow!("fig4 preset missing"))?;
    let p = 64usize;
    println!("== fusion — layered gradient fusion & overlap vs flat payloads (fig4, P={p}) ==");
    let mut csv = create_csv(
        out_dir,
        "fusion.csv",
        &["algo", "mode", "threshold_bytes", "buckets", "makespan_s", "flat_makespan_s", "speedup"],
        force,
    )?;
    let profile = LayerProfile::for_model_bytes(pre.model_params * 4);
    let thresholds: &[usize] =
        if quick { &[8 << 20] } else { &[1 << 20, 4 << 20, 8 << 20, 32 << 20] };
    println!(
        "{:<14} {:<10} {:>14} {:>8} {:>12} {:>12} {:>8}",
        "algorithm", "mode", "threshold", "buckets", "makespan", "flat", "speedup"
    );
    let mut tele = SweepTelemetry::start();
    for &algo in &[Algorithm::Wagma, Algorithm::AllreduceSgd] {
        let mut flat_cfg = pre.sim_config(algo, p, 42);
        if quick {
            flat_cfg.steps = 50;
        }
        let flat = client.simulate(&flat_cfg)?.makespan;
        for mode in [FusionMode::Threshold, FusionMode::MgWfbp] {
            for &threshold in thresholds {
                let fusion = FusionConfig { layered: true, mode, threshold_bytes: threshold };
                let mut cfg = flat_cfg.clone();
                cfg.fusion = fusion;
                let buckets = FusionPlan::build(
                    &profile,
                    &fusion,
                    &cfg.net,
                    cfg.fusion_participants(),
                    cfg.imbalance.mean(),
                )
                .num_buckets();
                let r = client.simulate(&cfg)?;
                tele.record(&r);
                let makespan = r.makespan;
                let speedup = flat / makespan;
                println!(
                    "{:<14} {:<10} {:>14} {:>8} {:>11.3}s {:>11.3}s {:>7.2}x",
                    algo.name(),
                    mode.name(),
                    threshold,
                    buckets,
                    makespan,
                    flat,
                    speedup
                );
                csv.row(&[
                    algo.name().to_string(),
                    mode.name().to_string(),
                    threshold.to_string(),
                    buckets.to_string(),
                    format!("{makespan:.6}"),
                    format!("{flat:.6}"),
                    format!("{speedup:.4}"),
                ])?;
            }
        }
    }
    tele.finish("fusion");
    Ok(())
}

/// Compression sweep (the `compress` subsystem's figure): simulated
/// makespan and bytes-on-wire across compression ratio × τ × group size
/// on the fig4/fig7/fig10 presets. Quantifies the volume lever next to
/// WAGMA's scope lever: how much wire traffic the per-bucket codecs
/// remove, at what makespan effect, as the sync period and group size
/// vary.
pub fn fig_compression(
    out_dir: &str,
    quick: bool,
    force: bool,
    client: &crate::serve::Client,
) -> anyhow::Result<()> {
    use crate::compress::Compression;

    let p = if quick { 16usize } else { 64 };
    println!("== compress — per-bucket compression sweep (ratio × τ × group size, P={p}) ==");
    let mut csv = create_csv(
        out_dir,
        "compress.csv",
        &[
            "preset",
            "compression",
            "topk_ratio",
            "tau",
            "group_size",
            "makespan_s",
            "wire_bytes_per_iter",
            "wire_reduction_x",
            "throughput",
        ],
        force,
    )?;
    let codecs: Vec<Compression> = if quick {
        vec![
            Compression::None,
            Compression::TopK { ratio: 0.1 },
            Compression::QuantizeQ8,
        ]
    } else {
        vec![
            Compression::None,
            Compression::TopK { ratio: 0.25 },
            Compression::TopK { ratio: 0.1 },
            Compression::TopK { ratio: 0.05 },
            Compression::TopK { ratio: 0.01 },
            Compression::QuantizeQ8,
        ]
    };
    println!(
        "{:<6} {:<6} {:>6} {:>4} {:>6} {:>12} {:>16} {:>10} {:>14}",
        "preset", "codec", "ratio", "tau", "S", "makespan", "wire B/iter", "reduce", "throughput"
    );
    let mut tele = SweepTelemetry::start();
    for name in ["fig4", "fig7", "fig10"] {
        let pre = preset(name).ok_or_else(|| anyhow::anyhow!("missing preset {name}"))?;
        let taus: Vec<u64> = if quick { vec![pre.tau] } else { vec![4, pre.tau, 25] };
        let groups: Vec<usize> = if quick { vec![8] } else { vec![4, 8, 16] };
        for &tau in &taus {
            for &s in &groups {
                let cell = |comp: Compression| -> anyhow::Result<crate::simulator::SimResult> {
                    let mut cfg = pre.sim_config(Algorithm::Wagma, p, 42);
                    cfg.tau = tau;
                    cfg.group_size = s.min(p);
                    cfg.compress = comp;
                    if quick {
                        cfg.steps = 50;
                    }
                    client.simulate(&cfg)
                };
                let baseline = cell(Compression::None)?;
                for &comp in &codecs {
                    // The None row IS the baseline — don't simulate it twice.
                    let r = if comp.is_none() { baseline.clone() } else { cell(comp)? };
                    tele.record(&r);
                    let reduction = baseline.wire_bytes_per_iter / r.wire_bytes_per_iter;
                    // Only top-k rows have a keep ratio; fabricating one
                    // for none/q8 would corrupt ratio-faceted plots.
                    let ratio = match comp {
                        Compression::TopK { ratio } => format!("{ratio}"),
                        _ => "-".to_string(),
                    };
                    println!(
                        "{:<6} {:<6} {:>6} {:>4} {:>6} {:>11.3}s {:>16.0} {:>9.2}x {:>13.0}/s",
                        name,
                        comp.name(),
                        ratio,
                        tau,
                        s.min(p),
                        r.makespan,
                        r.wire_bytes_per_iter,
                        reduction,
                        r.throughput(pre.batch),
                    );
                    csv.row(&[
                        name.to_string(),
                        comp.name().to_string(),
                        ratio.clone(),
                        tau.to_string(),
                        s.min(p).to_string(),
                        format!("{:.6}", r.makespan),
                        format!("{:.0}", r.wire_bytes_per_iter),
                        format!("{reduction:.4}"),
                        format!("{:.1}", r.throughput(pre.batch)),
                    ])?;
                }
            }
        }
    }
    tele.finish("compress");
    Ok(())
}

/// Elastic-membership study (the fault subsystem's figure): simulated
/// makespan under crash-time × compute-skew × link-jitter scenarios on
/// the fig4/fig7/fig10 presets, comparing wait-avoiding WAGMA against
/// synchronous Allreduce-SGD and the fault-brittle PairAveraging
/// baseline. The headline contrast: after a mid-run fail-stop, the
/// synchronous baseline stalls at least one full detection deadline per
/// remaining iteration, while WAGMA's deterministic membership re-forms
/// groups without a detection stall.
pub fn fig_elastic(
    out_dir: &str,
    quick: bool,
    force: bool,
    client: &crate::serve::Client,
) -> anyhow::Result<()> {
    use crate::fault::{Crash, FaultPlan, LinkFaults, DEFAULT_DEADLINE_S};

    let p = 16usize;
    let steps: usize = if quick { 50 } else { 200 };
    let deadline = DEFAULT_DEADLINE_S;
    let algos = [Algorithm::Wagma, Algorithm::AllreduceSgd, Algorithm::PairAveraging];
    println!(
        "== elastic — membership churn sweep (crash × skew × jitter, P={p}, deadline={deadline}s) =="
    );
    let mut csv = create_csv(
        out_dir,
        "elastic.csv",
        &[
            "preset",
            "algo",
            "scenario",
            "crash_at",
            "skew",
            "jitter_s",
            "deadline_s",
            "makespan_s",
            "clean_makespan_s",
            "loss_s",
            "loss_per_post_crash_iter_s",
            "throughput",
        ],
        force,
    )?;

    // Scenario grid. `crash` fail-stops the last rank mid-run; `skew`
    // slows rank 0 by the multiplier; `jitter` puts uniform extra latency
    // on every link. Quick keeps the axes but trims the cross-product.
    let crashes: &[Option<u64>] = &[None, Some(steps as u64 / 2)];
    let skews: &[f64] = if quick { &[1.0] } else { &[1.0, 2.0] };
    let jitters: &[f64] = if quick { &[0.0] } else { &[0.0, 0.001] };

    println!(
        "{:<6} {:<14} {:<22} {:>11} {:>11} {:>9} {:>14}",
        "preset", "algorithm", "scenario", "makespan", "clean", "loss", "loss/iter(post)"
    );
    let mut tele = SweepTelemetry::start();
    for name in ["fig4", "fig7", "fig10"] {
        let pre = preset(name).ok_or_else(|| anyhow::anyhow!("missing preset {name}"))?;
        for &algo in &algos {
            let run = |plan: FaultPlan| {
                let mut cfg = pre.sim_config(algo, p, 42);
                cfg.steps = steps;
                cfg.faults = plan;
                client.simulate(&cfg)
            };
            let clean = run(FaultPlan::none())?;
            for &crash in crashes {
                for &skew in skews {
                    for &jitter in jitters {
                        let mut plan = FaultPlan { seed: 42, deadline_s: deadline, ..FaultPlan::none() };
                        let mut labels: Vec<String> = Vec::new();
                        if let Some(at) = crash {
                            plan.crashes.push(Crash { rank: p - 1, at_iter: at });
                            labels.push(format!("crash@{at}"));
                        }
                        if skew != 1.0 {
                            let mut s = vec![1.0; p];
                            s[0] = skew;
                            plan.skew = s;
                            labels.push(format!("skew{skew}x"));
                        }
                        if jitter > 0.0 {
                            plan.link = LinkFaults { jitter_s: jitter, drop_prob: 0.0 };
                            labels.push(format!("jitter{}ms", jitter * 1e3));
                        }
                        let scenario =
                            if labels.is_empty() { "clean".to_string() } else { labels.join("+") };
                        let r = if plan.is_empty() { clean.clone() } else { run(plan)? };
                        tele.record(&r);
                        let loss = r.makespan - clean.makespan;
                        let post_iters = crash.map(|at| steps as f64 - at as f64);
                        let loss_per_iter = post_iters.map(|n| loss / n);
                        println!(
                            "{:<6} {:<14} {:<22} {:>10.3}s {:>10.3}s {:>8.3}s {:>14}",
                            name,
                            algo.name(),
                            scenario,
                            r.makespan,
                            clean.makespan,
                            loss,
                            loss_per_iter
                                .map(|l| format!("{l:.4}s"))
                                .unwrap_or_else(|| "-".to_string()),
                        );
                        csv.row(&[
                            name.to_string(),
                            algo.name().to_string(),
                            scenario,
                            crash.map(|a| a.to_string()).unwrap_or_else(|| "-".to_string()),
                            format!("{skew}"),
                            format!("{jitter}"),
                            format!("{deadline}"),
                            format!("{:.6}", r.makespan),
                            format!("{:.6}", clean.makespan),
                            format!("{loss:.6}"),
                            loss_per_iter
                                .map(|l| format!("{l:.6}"))
                                .unwrap_or_else(|| "-".to_string()),
                            format!("{:.1}", r.throughput(pre.batch)),
                        ])?;
                        // The acceptance contrast, printed where it holds:
                        // a crashed peer costs the synchronous baseline at
                        // least the full detection deadline every iteration.
                        if algo == Algorithm::AllreduceSgd
                            && crash.is_some()
                            && skew == 1.0
                            && jitter == 0.0
                        {
                            let lpi = loss_per_iter.unwrap_or(0.0);
                            println!(
                                "       -> allreduce loses {lpi:.4}s/iter post-crash \
                                 (>= deadline {deadline}s: {})",
                                lpi >= deadline - 1e-9
                            );
                        }
                    }
                }
            }
        }
    }
    tele.finish("elastic");
    Ok(())
}

/// Figs. 1–3: protocol demonstration traces (activation tree, dynamic
/// grouping, straggler snapshot) — printed, not measured.
pub fn fig_protocol_demos() {
    use crate::topology::{BinomialTree, Grouping};
    println!("== Fig. 1 — activation tree (P=4, activator P1) ==");
    let t = BinomialTree::new(4);
    for rank in 0..4 {
        println!("  P{rank} forwards to {:?}", t.children(1, rank));
    }
    println!("\n== Fig. 2 — dynamic grouping (P=8, S=4) ==");
    let g = Grouping::new(8, 4);
    for it in 0..4u64 {
        println!("  iteration {it}: groups {:?}", g.groups(it));
    }
    println!(
        "\n  update propagation: log_S P = {} iterations",
        g.propagation_iters()
    );
    println!("\n== Fig. 3 — see `cargo test -p wagma straggler` for the executable snapshot ==");
}
