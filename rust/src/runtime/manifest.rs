//! Artifact manifest parsing (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`). Describes every AOT artifact's ABI so the
//! coordinator can construct correctly-shaped inputs without touching
//! Python.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one data argument.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<ArgMeta> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .context("arg missing shape")?
            .iter()
            .map(|v| v.as_usize().context("non-numeric dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.get("dtype").and_then(|d| d.as_str()).context("arg missing dtype")?;
        Ok(ArgMeta { shape, dtype: dtype.to_string() })
    }
}

/// File names of one model's artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelFiles {
    pub step: String,
    pub grad: String,
    pub eval: String,
    pub params: String,
}

/// Metadata for one model artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    pub kind: String,
    pub batch: usize,
    pub dims: BTreeMap<String, usize>,
    pub param_count: usize,
    pub data_args: Vec<ArgMeta>,
    pub eval_args: Vec<ArgMeta>,
    pub files: ModelFiles,
}

/// Metadata for a standalone kernel artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelMeta {
    pub name: String,
    pub s: usize,
    pub n: usize,
    pub file: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub fingerprint: String,
    pub models: BTreeMap<String, ModelMeta>,
    pub kernels: BTreeMap<String, KernelMeta>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path:?}; run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest JSON: {e}"))?;
        let mut out = Manifest {
            fingerprint: j
                .get("fingerprint")
                .and_then(|f| f.as_str())
                .unwrap_or_default()
                .to_string(),
            ..Default::default()
        };
        if let Some(Json::Obj(models)) = j.get("models") {
            for (name, m) in models {
                out.models.insert(name.clone(), Self::parse_model(name, m)?);
            }
        }
        if let Some(Json::Obj(kernels)) = j.get("kernels") {
            for (name, k) in kernels {
                let files = k.get("files").context("kernel missing files")?;
                out.kernels.insert(
                    name.clone(),
                    KernelMeta {
                        name: name.clone(),
                        s: k.get("s").and_then(|v| v.as_usize()).unwrap_or(0),
                        n: k.get("n").and_then(|v| v.as_usize()).unwrap_or(0),
                        file: files
                            .get("hlo")
                            .and_then(|f| f.as_str())
                            .context("kernel missing hlo file")?
                            .to_string(),
                    },
                );
            }
        }
        Ok(out)
    }

    fn parse_model(name: &str, m: &Json) -> Result<ModelMeta> {
        let files = m.get("files").context("model missing files")?;
        let file = |k: &str| -> Result<String> {
            Ok(files
                .get(k)
                .and_then(|f| f.as_str())
                .with_context(|| format!("model {name} missing file {k}"))?
                .to_string())
        };
        let args = |k: &str| -> Result<Vec<ArgMeta>> {
            m.get(k)
                .and_then(|a| a.as_arr())
                .with_context(|| format!("model {name} missing {k}"))?
                .iter()
                .map(ArgMeta::from_json)
                .collect()
        };
        let mut dims = BTreeMap::new();
        if let Some(Json::Obj(d)) = m.get("dims") {
            for (k, v) in d {
                dims.insert(k.clone(), v.as_usize().context("non-numeric dim")?);
            }
        }
        Ok(ModelMeta {
            name: name.to_string(),
            kind: m.get("kind").and_then(|k| k.as_str()).context("missing kind")?.to_string(),
            batch: m.get("batch").and_then(|b| b.as_usize()).context("missing batch")?,
            dims,
            param_count: m
                .get("param_count")
                .and_then(|p| p.as_usize())
                .context("missing param_count")?,
            data_args: args("data_args")?,
            eval_args: args("eval_args")?,
            files: ModelFiles {
                step: file("step")?,
                grad: file("grad")?,
                eval: file("eval")?,
                params: file("params")?,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "fingerprint": "abc123",
      "built": ["mlp_tiny"],
      "models": {
        "mlp_tiny": {
          "name": "mlp_tiny", "kind": "classifier", "batch": 32,
          "dims": {"input_dim": 64, "hidden": 128, "classes": 10},
          "param_count": 26634, "use_pallas_ffn": true,
          "data_args": [
            {"shape": [32, 64], "dtype": "float32"},
            {"shape": [32], "dtype": "int32"}
          ],
          "eval_args": [
            {"shape": [32, 64], "dtype": "float32"},
            {"shape": [32], "dtype": "int32"}
          ],
          "step_outputs": 3, "grad_outputs": 2,
          "files": {
            "step": "mlp_tiny.step.hlo.txt", "grad": "mlp_tiny.grad.hlo.txt",
            "eval": "mlp_tiny.eval.hlo.txt", "params": "mlp_tiny.params.bin"
          }
        }
      },
      "kernels": {
        "group_average": {
          "name": "group_average", "kind": "kernel", "s": 4, "n": 65536,
          "files": {"hlo": "group_average.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.fingerprint, "abc123");
        let model = &m.models["mlp_tiny"];
        assert_eq!(model.kind, "classifier");
        assert_eq!(model.batch, 32);
        assert_eq!(model.param_count, 26634);
        assert_eq!(model.dims["hidden"], 128);
        assert_eq!(model.data_args.len(), 2);
        assert_eq!(model.data_args[0].shape, vec![32, 64]);
        assert_eq!(model.data_args[0].elements(), 2048);
        assert_eq!(model.data_args[1].dtype, "int32");
        assert_eq!(model.files.step, "mlp_tiny.step.hlo.txt");
        let k = &m.kernels["group_average"];
        assert_eq!((k.s, k.n), (4, 65536));
    }

    #[test]
    fn missing_fields_are_errors() {
        assert!(Manifest::parse(r#"{"models": {"x": {"kind": "lm"}}}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
