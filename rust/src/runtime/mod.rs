//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The Rust hot path never touches Python. `make artifacts` (build time)
//! lowers the L2 JAX models to `artifacts/*.hlo.txt`; at run time each
//! worker thread owns a [`ModelRuntime`] — a PJRT CPU client plus the
//! compiled step/grad/eval executables for one model — and drives training
//! entirely through it.
//!
//! Note on threading: the `xla` crate's `PjRtClient` is `Rc`-based and not
//! `Send`, so every worker constructs its own client and compiles its own
//! executables at startup (a few hundred ms per model; amortized over the
//! whole run).

pub mod manifest;

pub use manifest::{ArgMeta, Manifest, ModelMeta};

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::Batch;

/// A PJRT CPU client plus compiled executables for one model artifact.
pub struct ModelRuntime {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    step_exe: xla::PjRtLoadedExecutable,
    grad_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    artifacts_dir: PathBuf,
}

impl ModelRuntime {
    /// Load model `name` from `artifacts_dir` (compiling its HLO on a fresh
    /// CPU PJRT client).
    pub fn load(artifacts_dir: impl AsRef<Path>, name: &str) -> Result<ModelRuntime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let meta = manifest
            .models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest; run `make artifacts`"))?
            .clone();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compile {path:?}"))
        };
        Ok(ModelRuntime {
            step_exe: compile(&meta.files.step)?,
            grad_exe: compile(&meta.files.grad)?,
            eval_exe: compile(&meta.files.eval)?,
            client,
            artifacts_dir: dir,
            meta,
        })
    }

    /// Read the deterministic initial parameter vector written by aot.py.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self.artifacts_dir.join(&self.meta.files.params);
        let bytes = std::fs::read(&path).with_context(|| format!("read {path:?}"))?;
        anyhow::ensure!(
            bytes.len() == self.meta.param_count * 4,
            "params.bin size {} != 4 * param_count {}",
            bytes.len(),
            self.meta.param_count
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// One local training step (Algorithm 2 lines 3–7): heavy-ball SGD via
    /// the fused Pallas kernel inside the artifact. Updates `params` and
    /// `mom` in place and returns the minibatch loss.
    pub fn step(
        &self,
        params: &mut Vec<f32>,
        mom: &mut Vec<f32>,
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 + batch.args.len());
        inputs.push(xla::Literal::vec1(params));
        inputs.push(xla::Literal::vec1(mom));
        for a in &batch.args {
            inputs.push(a.to_literal()?);
        }
        inputs.push(xla::Literal::from(lr));
        let out = self.execute(&self.step_exe, &inputs)?;
        let (p2, m2, loss) = out.to_tuple3().context("step output arity")?;
        *params = p2.to_vec::<f32>()?;
        *mom = m2.to_vec::<f32>()?;
        Ok(loss.to_vec::<f32>()?[0])
    }

    /// Gradient + loss for the gradient-averaging baselines
    /// (Allreduce-SGD, eager-SGD).
    pub fn grad(&self, params: &[f32], batch: &Batch) -> Result<(Vec<f32>, f32)> {
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(1 + batch.args.len());
        inputs.push(xla::Literal::vec1(params));
        for a in &batch.args {
            inputs.push(a.to_literal()?);
        }
        let out = self.execute(&self.grad_exe, &inputs)?;
        let (g, loss) = out.to_tuple2().context("grad output arity")?;
        Ok((g.to_vec::<f32>()?, loss.to_vec::<f32>()?[0]))
    }

    /// Task metric: classifier accuracy or LM loss on a held-out batch.
    pub fn eval_metric(&self, params: &[f32], batch: &Batch) -> Result<f32> {
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(1 + batch.args.len());
        inputs.push(xla::Literal::vec1(params));
        for a in &batch.args {
            inputs.push(a.to_literal()?);
        }
        let out = self.execute(&self.eval_exe, &inputs)?;
        let m = out.to_tuple1().context("eval output arity")?;
        Ok(m.to_vec::<f32>()?[0])
    }

    /// Policy forward: per-sample action log-probs and values
    /// (`obs [B, O] -> (logp [B, A], value [B])`).
    pub fn policy_forward(
        &self,
        params: &[f32],
        obs: &crate::model::DataArg,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let inputs = vec![xla::Literal::vec1(params), obs.to_literal()?];
        let out = self.execute(&self.eval_exe, &inputs)?;
        let (logp, value) = out.to_tuple2().context("policy eval output arity")?;
        Ok((logp.to_vec::<f32>()?, value.to_vec::<f32>()?))
    }

    fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let bufs = exe.execute::<xla::Literal>(inputs).context("PJRT execute")?;
        Ok(bufs[0][0].to_literal_sync()?)
    }

    /// Raw client access (tests / diagnostics).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Standalone kernel artifact: the Pallas group-average as an executable
/// (optional accelerator-offloaded blend; the coordinator's default blend is
/// native Rust — see benches/collectives.rs for the comparison).
pub struct AverageKernel {
    exe: xla::PjRtLoadedExecutable,
    _client: xla::PjRtClient,
    pub s: usize,
    pub n: usize,
}

impl AverageKernel {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<AverageKernel> {
        let dir = artifacts_dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let k = manifest
            .kernels
            .get("group_average")
            .context("group_average not in manifest")?;
        let client = xla::PjRtClient::cpu()?;
        let path = dir.join(&k.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        Ok(AverageKernel { exe, _client: client, s: k.s, n: k.n })
    }

    /// Average `s` stacked models of length `n` (row-major [S, N]).
    pub fn average(&self, stacked: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(stacked.len() == self.s * self.n, "bad stacked size");
        let lit = xla::Literal::vec1(stacked).reshape(&[self.s as i64, self.n as i64])?;
        let out = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }
}
