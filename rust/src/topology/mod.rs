//! Process topology machinery: hypercube/butterfly phase schedules, the
//! dynamic grouping strategy of WAGMA-SGD (Algorithm 1 in the paper), and
//! binomial activation trees for wait-avoiding collectives.

pub mod grouping;
pub mod tree;

pub use grouping::Grouping;
pub use tree::BinomialTree;

/// log2 of a power-of-two, with a hard assertion (the paper assumes both
/// `P` and `S` are powers of two; so do we).
pub fn log2_exact(x: usize) -> u32 {
    assert!(x.is_power_of_two(), "{x} is not a power of two");
    x.trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_values() {
        assert_eq!(log2_exact(1), 0);
        assert_eq!(log2_exact(2), 1);
        assert_eq!(log2_exact(1024), 10);
    }

    #[test]
    #[should_panic]
    fn log2_rejects_non_pow2() {
        log2_exact(12);
    }
}
