//! Dynamic grouping strategy (paper §III-B, Algorithm 1).
//!
//! At training iteration `t`, the `P` processes are partitioned into `P/S`
//! non-overlapping groups of size `S`. Within a group, the allreduce runs
//! `log2(S)` butterfly phases; the hypercube *bit positions* used by those
//! phases rotate with `t`:
//!
//! ```text
//! bit(t, r) = (t · log2(S) + r) mod log2(P),   r = 0 .. log2(S)-1
//! partner(p, t, r) = p XOR (1 << bit(t, r))
//! ```
//!
//! The paper's pseudocode expresses this with a left-shifting mask and a
//! rotating `shift`; the closed form above is the fixed point of its worked
//! example (P=8, S=4: iteration 0 groups {0,1,2,3},{4,5,6,7}; iteration 1
//! groups {0,1,4,5},{2,3,6,7}) and is what the butterfly implementation in
//! §III-B ("we use the variable t to change the phases that should be
//! executed in the current iteration") describes. Because the start offset
//! advances by `log2(S)` every iteration, all `log2(P)` hypercube
//! dimensions are covered every `ceil(log2 P / log2 S) = log_S(P)`
//! iterations, which is the paper's propagation guarantee.

use super::log2_exact;

/// The dynamic (or optionally static) grouping schedule for `P` processes
/// with group size `S`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grouping {
    p: usize,
    s: usize,
    log_p: u32,
    log_s: u32,
    /// If false, the group composition is frozen to iteration 0 —
    /// the "fixed groups" ablation (paper §V-B experiment ❷).
    dynamic: bool,
}

impl Grouping {
    /// Dynamic grouping (the paper's default).
    pub fn new(p: usize, s: usize) -> Grouping {
        Self::with_mode(p, s, true)
    }

    /// Static grouping ablation: groups never change across iterations.
    pub fn fixed(p: usize, s: usize) -> Grouping {
        Self::with_mode(p, s, false)
    }

    fn with_mode(p: usize, s: usize, dynamic: bool) -> Grouping {
        let log_p = log2_exact(p);
        let log_s = log2_exact(s);
        assert!(s <= p, "group size {s} exceeds process count {p}");
        assert!(p >= 1);
        Grouping { p, s, log_p, log_s, dynamic }
    }

    /// The paper's recommended group size: S = sqrt(P), rounded to the
    /// nearest power of two (exact when log2(P) is even).
    pub fn sqrt_group_size(p: usize) -> usize {
        let log_p = log2_exact(p);
        1usize << log_p.div_ceil(2)
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn group_size(&self) -> usize {
        self.s
    }

    /// Number of butterfly phases per group collective.
    pub fn phases(&self) -> u32 {
        self.log_s
    }

    pub fn is_dynamic(&self) -> bool {
        self.dynamic
    }

    /// Hypercube bit position used at iteration `t`, phase `r`.
    pub fn phase_bit(&self, t: u64, r: u32) -> u32 {
        debug_assert!(r < self.log_s);
        if self.log_p == 0 {
            return 0;
        }
        let t = if self.dynamic { t } else { 0 };
        ((t * self.log_s as u64 + r as u64) % self.log_p as u64) as u32
    }

    /// XOR mask for iteration `t`, phase `r`.
    pub fn phase_mask(&self, t: u64, r: u32) -> usize {
        1usize << self.phase_bit(t, r)
    }

    /// The butterfly partner of `rank` at iteration `t`, phase `r`.
    pub fn partner(&self, rank: usize, t: u64, r: u32) -> usize {
        debug_assert!(rank < self.p);
        rank ^ self.phase_mask(t, r)
    }

    /// OR of all phase masks at iteration `t` — the set of "free" bits
    /// that vary within a group.
    pub fn free_mask(&self, t: u64) -> usize {
        (0..self.log_s).fold(0usize, |m, r| m | self.phase_mask(t, r))
    }

    /// Canonical group identifier of `rank` at iteration `t` (its rank with
    /// the free bits cleared). Two ranks are in the same group iff their
    /// group ids are equal.
    pub fn group_id(&self, rank: usize, t: u64) -> usize {
        rank & !self.free_mask(t)
    }

    /// All members of `rank`'s group at iteration `t`, ascending.
    pub fn group_of(&self, rank: usize, t: u64) -> Vec<usize> {
        let free = self.free_mask(t);
        let base = rank & !free;
        // Enumerate subsets of the free mask.
        let mut members = Vec::with_capacity(self.s);
        let mut sub = 0usize;
        loop {
            members.push(base | sub);
            if sub == free {
                break;
            }
            sub = (sub.wrapping_sub(free)) & free; // next subset trick
        }
        members.sort_unstable();
        members
    }

    /// The full partition at iteration `t`: `P/S` groups of size `S`.
    pub fn groups(&self, t: u64) -> Vec<Vec<usize>> {
        let free = self.free_mask(t);
        let mut out = Vec::with_capacity(self.p / self.s);
        for base in 0..self.p {
            if base & free == 0 {
                out.push(self.group_of(base, t));
            }
        }
        out
    }

    /// Number of iterations for a local update to propagate to all ranks:
    /// `log_S(P)` (paper §V-B: "globally propagate only using log_S P
    /// iterations").
    pub fn propagation_iters(&self) -> u32 {
        if self.log_s == 0 {
            return u32::MAX; // S = 1 never propagates
        }
        self.log_p.div_ceil(self.log_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's worked example (§III-B): P=8, S=4.
    #[test]
    fn grouping_paper_example() {
        let g = Grouping::new(8, 4);
        assert_eq!(g.groups(0), vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        assert_eq!(g.groups(1), vec![vec![0, 1, 4, 5], vec![2, 3, 6, 7]]);
    }

    /// Fig. 2's schedule: the same two partitions alternate for P=8, S=4.
    #[test]
    fn grouping_alternates() {
        let g = Grouping::new(8, 4);
        for t in 0..12u64 {
            let gr = g.groups(t);
            assert_eq!(gr.len(), 2);
            assert!(gr.iter().all(|grp| grp.len() == 4));
        }
        // Iterations 0 and 3 use bit offsets 0 and 6 mod 3 = 0: same groups.
        assert_eq!(g.groups(0), g.groups(3));
    }

    #[test]
    fn partition_invariants() {
        for &(p, s) in &[(2, 2), (4, 2), (8, 2), (8, 4), (16, 4), (64, 8), (256, 16)] {
            let g = Grouping::new(p, s);
            for t in 0..10u64 {
                let groups = g.groups(t);
                assert_eq!(groups.len(), p / s);
                let mut seen = vec![false; p];
                for grp in &groups {
                    assert_eq!(grp.len(), s);
                    for &r in grp {
                        assert!(!seen[r], "rank {r} in two groups");
                        seen[r] = true;
                    }
                }
                assert!(seen.iter().all(|&b| b), "partition must cover all ranks");
            }
        }
    }

    #[test]
    fn partner_is_involution_and_same_group() {
        let g = Grouping::new(32, 4);
        for t in 0..8u64 {
            for rank in 0..32 {
                for r in 0..g.phases() {
                    let q = g.partner(rank, t, r);
                    assert_eq!(g.partner(q, t, r), rank, "partner must be an involution");
                    assert_eq!(g.group_id(rank, t), g.group_id(q, t));
                }
            }
        }
    }

    #[test]
    fn fixed_grouping_never_changes() {
        let g = Grouping::fixed(16, 4);
        let g0 = g.groups(0);
        for t in 1..20u64 {
            assert_eq!(g.groups(t), g0);
        }
    }

    #[test]
    fn dynamic_grouping_covers_all_bits() {
        // Within propagation_iters() consecutive iterations, every hypercube
        // dimension must appear in some phase (this is what guarantees
        // global propagation in log_S P iterations).
        for &(p, s) in &[(16, 4), (64, 8), (256, 16), (1024, 32)] {
            let g = Grouping::new(p, s);
            let window = g.propagation_iters() as u64;
            for t0 in 0..6u64 {
                let mut bits = 0usize;
                for t in t0..t0 + window {
                    bits |= g.free_mask(t);
                }
                assert_eq!(bits, p - 1, "P={p} S={s} window={window} bits={bits:b}");
            }
        }
    }

    #[test]
    fn sqrt_group_size_values() {
        assert_eq!(Grouping::sqrt_group_size(64), 8);
        assert_eq!(Grouping::sqrt_group_size(256), 16);
        assert_eq!(Grouping::sqrt_group_size(1024), 32);
        // Odd log2: round up.
        assert_eq!(Grouping::sqrt_group_size(8), 4);
        assert_eq!(Grouping::sqrt_group_size(128), 16);
    }

    #[test]
    fn global_group_is_allreduce() {
        let g = Grouping::new(16, 16);
        assert_eq!(g.groups(0).len(), 1);
        assert_eq!(g.groups(5)[0], (0..16).collect::<Vec<_>>());
        assert_eq!(g.propagation_iters(), 1);
    }
}
