//! Binomial broadcast trees over a hypercube, used for collective
//! *activation* (paper §III-A1, Fig. 1).
//!
//! The wait-avoiding group allreduce is built from overlapping binomial
//! trees, one rooted at each process: the *activator* (first process to
//! reach the collective) broadcasts activation messages along the tree
//! rooted at itself; every receiver forwards to its own children in that
//! tree before joining the collective.
//!
//! Trees are expressed in *relative* coordinates `rel = rank XOR root`:
//! in relative space the root is 0, the parent of node `r != 0` clears the
//! highest set bit of `r`, and the children of `r` set each bit above its
//! highest set bit. Depth is `log2(P)` and every node is reached exactly
//! once — the classic binomial broadcast.

use super::log2_exact;

/// Binomial broadcast tree over `P` (power-of-two) ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinomialTree {
    p: usize,
    log_p: u32,
}

impl BinomialTree {
    pub fn new(p: usize) -> BinomialTree {
        BinomialTree { p, log_p: log2_exact(p) }
    }

    pub fn p(&self) -> usize {
        self.p
    }

    /// Children of `rank` in the tree rooted at `root`, in send order.
    pub fn children(&self, root: usize, rank: usize) -> Vec<usize> {
        debug_assert!(root < self.p && rank < self.p);
        let rel = rank ^ root;
        let start = if rel == 0 {
            0
        } else {
            // Bits above the highest set bit of rel.
            (usize::BITS - rel.leading_zeros()) as u32
        };
        (start..self.log_p).map(|k| (rel | (1usize << k)) ^ root).collect()
    }

    /// Parent of `rank` in the tree rooted at `root` (None for the root).
    pub fn parent(&self, root: usize, rank: usize) -> Option<usize> {
        let rel = rank ^ root;
        if rel == 0 {
            return None;
        }
        let high = 1usize << (usize::BITS - 1 - rel.leading_zeros() as u32) as u32;
        Some((rel & !high) ^ root)
    }

    /// Depth of `rank` in the tree rooted at `root` = popcount of the
    /// relative id. Maximum depth is log2(P).
    pub fn depth(&self, root: usize, rank: usize) -> u32 {
        (rank ^ root).count_ones()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_example() {
        // Paper Fig. 1: P=4, activator P1. P1's tree: P1 -> {P0, P3},
        // P0 forwards to P2.
        let t = BinomialTree::new(4);
        assert_eq!(t.children(1, 1), vec![0, 3]);
        assert_eq!(t.children(1, 0), vec![2]);
        assert_eq!(t.children(1, 3), Vec::<usize>::new());
        assert_eq!(t.children(1, 2), Vec::<usize>::new());
    }

    #[test]
    fn every_rank_reached_exactly_once() {
        for &p in &[1usize, 2, 4, 8, 16, 64, 256] {
            let t = BinomialTree::new(p);
            for root in [0, p / 3, p - 1] {
                let root = root.min(p - 1);
                let mut reached = vec![0usize; p];
                // BFS from root.
                let mut frontier = vec![root];
                reached[root] += 1;
                while let Some(r) = frontier.pop() {
                    for c in t.children(root, r) {
                        reached[c] += 1;
                        frontier.push(c);
                    }
                }
                assert!(
                    reached.iter().all(|&n| n == 1),
                    "P={p} root={root}: {reached:?}"
                );
            }
        }
    }

    #[test]
    fn parent_child_consistency() {
        let t = BinomialTree::new(32);
        for root in 0..32 {
            for rank in 0..32 {
                for c in t.children(root, rank) {
                    assert_eq!(t.parent(root, c), Some(rank));
                }
                if let Some(par) = t.parent(root, rank) {
                    assert!(t.children(root, par).contains(&rank));
                }
            }
            assert_eq!(t.parent(root, root), None);
        }
    }

    #[test]
    fn depth_bounded_by_log_p() {
        let t = BinomialTree::new(64);
        for root in [0usize, 17, 63] {
            for rank in 0..64 {
                assert!(t.depth(root, rank) <= 6);
            }
        }
    }
}
