//! Benchmark harness (offline environment: no `criterion`). Provides
//! warmup + timed iterations, robust statistics, throughput units, and a
//! JSON report — used by every target in `rust/benches/`.
//!
//! [`measured_overlap`] is the wall-clock engine harness behind the
//! `wagma bench` subcommand and `BENCH_engine.json`; [`calibrate`] fits
//! `NetworkModel` α/β from the same harness (`wagma bench --calibrate`).

pub mod calibrate;
pub mod measured_overlap;

use std::time::Instant;

use crate::util::json::{num, obj, s, Json};
use crate::util::stats::Summary;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wall times (seconds).
    pub times: Vec<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.times)
    }

    pub fn to_json(&self) -> Json {
        let su = self.summary();
        obj(vec![
            ("name", s(&self.name)),
            ("iters", num(self.iters as f64)),
            ("mean_s", num(su.mean)),
            ("median_s", num(su.p50)),
            ("p99_s", num(su.p99)),
            ("std_s", num(su.std)),
            ("min_s", num(su.min)),
            ("max_s", num(su.max)),
        ])
    }

    pub fn report(&self) -> String {
        let su = self.summary();
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            self.iters,
            fmt_time(su.p50),
            fmt_time(su.mean),
            fmt_time(su.std),
        )
    }
}

/// Human-readable duration.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner: `target_time` bounds total measurement wall-clock.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_seconds: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Bencher {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            target_seconds: 3.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher { warmup_iters: 1, min_iters: 3, max_iters: 30, target_seconds: 1.0, ..Default::default() }
    }

    /// Time `f` (called with the iteration index). Returns the result and
    /// records it for the final report.
    pub fn bench<F: FnMut(usize)>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for i in 0..self.warmup_iters {
            f(i);
        }
        let mut times = Vec::new();
        let start = Instant::now();
        let mut i = 0;
        while (i < self.min_iters
            || (start.elapsed().as_secs_f64() < self.target_seconds && i < self.max_iters))
            && i < self.max_iters
        {
            let t0 = Instant::now();
            f(i);
            times.push(t0.elapsed().as_secs_f64());
            i += 1;
        }
        self.results.push(BenchResult { name: name.to_string(), iters: times.len(), times });
        self.results.last().unwrap()
    }

    /// Record an externally-measured sample set (figure harnesses that
    /// compute model time rather than wall time).
    pub fn record(&mut self, name: &str, times: Vec<f64>) -> &BenchResult {
        self.results
            .push(BenchResult { name: name.to_string(), iters: times.len(), times });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print the standard report table and optionally write JSON results.
    pub fn finish(self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            "benchmark", "iters", "median", "mean", "std"
        );
        for r in &self.results {
            println!("{}", r.report());
        }
        if let Ok(dir) = std::env::var("WAGMA_BENCH_OUT") {
            let path = std::path::Path::new(&dir)
                .join(format!("{}.json", title.replace([' ', '/'], "_")));
            let _ = std::fs::create_dir_all(&dir);
            let j = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
            if std::fs::write(&path, j.to_string()).is_ok() {
                println!("(wrote {path:?})");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_runs_and_reports() {
        let mut b = Bencher { warmup_iters: 1, min_iters: 3, max_iters: 5, target_seconds: 0.01, ..Default::default() };
        let mut count = 0;
        b.bench("noop", |_| count += 1);
        assert!(count >= 4); // warmup + >= 3 timed
        let r = &b.results()[0];
        assert!(r.iters >= 3 && r.iters <= 5);
        assert!(r.summary().mean >= 0.0);
        let j = r.to_json().to_string();
        assert!(j.contains("\"name\":\"noop\""));
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert_eq!(fmt_time(3e-9), "3.0 ns");
    }
}
