//! α/β calibration from the measured-overlap harness (`wagma bench
//! --calibrate`) — closes the PR 2 ROADMAP follow-up ("calibrate the
//! `NetworkModel` α/β terms against the measured harness").
//!
//! The harness runs *serial* (zero-compute) group collectives across a
//! ladder of payload sizes on real engine threads, so every rank arrives
//! together and the measured per-op wait is the full collective latency.
//! With group size 2 each op is exactly one exchange, so the Hockney
//! model predicts `wait(n) = α + 4n·β`. A least-squares affine fit of
//! the (bytes, seconds) samples yields α (intercept) and β (slope) for
//! this host's in-memory transport; γ/contention/δ keep the Aries
//! defaults (they need reduction- and codec-specific microbenchmarks).

use crate::bench::measured_overlap::{run_measured, MeasuredConfig};
use crate::compress::Compression;
use crate::simulator::NetworkModel;
use crate::util::json::{num, obj, Json};

/// One calibration point: payload bytes per exchange and the measured
/// mean collective wait.
#[derive(Debug, Clone, Copy)]
pub struct CalSample {
    pub bytes: f64,
    pub seconds: f64,
}

/// Ordinary least squares for `seconds ≈ alpha + beta * bytes`.
/// Returns `(alpha, beta)`; alpha is clamped at 0 (a negative intercept
/// just means the latency term is below measurement noise).
pub fn fit_alpha_beta(samples: &[CalSample]) -> (f64, f64) {
    assert!(samples.len() >= 2, "need at least two payload sizes to fit");
    let n = samples.len() as f64;
    let mean_b = samples.iter().map(|s| s.bytes).sum::<f64>() / n;
    let mean_t = samples.iter().map(|s| s.seconds).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut var = 0.0;
    for s in samples {
        cov += (s.bytes - mean_b) * (s.seconds - mean_t);
        var += (s.bytes - mean_b) * (s.bytes - mean_b);
    }
    assert!(var > 0.0, "payload sizes must differ");
    let beta = cov / var;
    let alpha = (mean_t - beta * mean_b).max(0.0);
    (alpha, beta.max(0.0))
}

/// Run the calibration ladder and return the fitted model plus the raw
/// samples (for the JSON report).
pub fn calibrate(quick: bool, seed: u64) -> (NetworkModel, Vec<CalSample>) {
    let p = 4usize;
    let steps: u64 = if quick { 20 } else { 60 };
    let dims: &[usize] = if quick {
        &[4096, 32768, 131_072]
    } else {
        &[4096, 16384, 65536, 262_144, 1_048_576]
    };
    let mut samples = Vec::with_capacity(dims.len());
    for &dim in dims {
        let cfg = MeasuredConfig {
            p,
            group_size: 2, // exactly one exchange per op: wait = α + 4n·β
            tau: 0,
            dim,
            steps,
            chunk_elems: 0,
            compression: Compression::None,
            compute: vec![vec![0.0; p]; steps as usize],
            faults: crate::fault::FaultPlan::none(),
        };
        let run = run_measured(&cfg);
        samples.push(CalSample { bytes: (dim * 4) as f64, seconds: run.wait.mean });
    }
    let (alpha, beta) = fit_alpha_beta(&samples);
    let aries = NetworkModel::aries();
    let _ = seed; // the serial ladder is compute-free; kept for CLI symmetry
    (
        NetworkModel { alpha, beta, gamma: aries.gamma, contention: aries.contention, delta: aries.delta },
        samples,
    )
}

/// JSON report for `wagma bench --calibrate`.
pub fn calibration_json(model: &NetworkModel, samples: &[CalSample]) -> Json {
    obj(vec![
        ("alpha_s", num(model.alpha)),
        ("beta_s_per_byte", num(model.beta)),
        ("gamma_s_per_byte", num(model.gamma)),
        ("contention", num(model.contention)),
        ("delta_s_per_byte", num(model.delta)),
        (
            "samples",
            Json::Arr(
                samples
                    .iter()
                    .map(|s| obj(vec![("bytes", num(s.bytes)), ("wait_mean_s", num(s.seconds))]))
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_exact_affine_data() {
        let alpha = 2.5e-6;
        let beta = 1.0 / 12e9;
        let samples: Vec<CalSample> = [1024.0f64, 65536.0, 1048576.0, 4194304.0]
            .iter()
            .map(|&b| CalSample { bytes: b, seconds: alpha + beta * b })
            .collect();
        let (a, b) = fit_alpha_beta(&samples);
        assert!((a - alpha).abs() / alpha < 1e-6, "alpha {a} vs {alpha}");
        assert!((b - beta).abs() / beta < 1e-6, "beta {b} vs {beta}");
    }

    #[test]
    fn fit_clamps_negative_intercepts() {
        // Pure-slope data with noise pushing the intercept negative.
        let samples = [
            CalSample { bytes: 1000.0, seconds: 0.5e-6 },
            CalSample { bytes: 2000.0, seconds: 2.0e-6 },
        ];
        let (a, b) = fit_alpha_beta(&samples);
        assert_eq!(a, 0.0);
        assert!(b > 0.0);
    }

    /// End-to-end smoke on the real harness (quick ladder): the fit must
    /// be finite, non-negative, and in a plausible band for in-memory
    /// transport (β far above a real NIC's, α in the sub-millisecond
    /// range).
    #[test]
    fn calibrate_smoke() {
        let (model, samples) = calibrate(true, 1);
        assert_eq!(samples.len(), 3);
        assert!(model.alpha >= 0.0 && model.alpha < 0.05, "alpha {}", model.alpha);
        assert!(model.beta >= 0.0 && model.beta.is_finite());
        let j = calibration_json(&model, &samples).to_string();
        assert!(j.contains("alpha_s"));
    }
}
