//! α/β/δ calibration from the measured-overlap harness (`wagma bench
//! --calibrate`) — closes the PR 2 ROADMAP follow-up ("calibrate the
//! `NetworkModel` α/β terms against the measured harness") and its
//! compression-PR extension (the measured δ codec term).
//!
//! The harness runs *serial* (zero-compute) group collectives across a
//! ladder of payload sizes on real engine threads, so every rank arrives
//! together and the measured per-op wait is the full collective latency.
//! With group size 2 each op is exactly one exchange, so the Hockney
//! model predicts `wait(n) = α + n·β` (n in wire bytes). A least-squares
//! affine fit of the dense (bytes, seconds) samples yields α (intercept)
//! and β (slope) for this host's in-memory transport.
//!
//! A second, *compressed* rung re-runs the same ladder with the Q8
//! quantizer — chosen over top-k because its wire size is a deterministic
//! function of the payload (`2 + ⌈n/4⌉` words), so the rung isolates the
//! codec: `wait_c(raw) = α + wire·β + 2·raw·δ` (encode ours + decode the
//! partner's, each touching every raw byte — the exact pricing of
//! [`NetworkModel::exchange_compressed`]). Solving per rung and averaging
//! gives δ; γ/contention keep the Aries defaults (they need
//! reduction-specific microbenchmarks).

use crate::bench::measured_overlap::{run_measured, MeasuredConfig};
use crate::compress::Compression;
use crate::simulator::NetworkModel;
use crate::util::json::{num, obj, s, Json};

/// One calibration point: payload bytes per exchange and the measured
/// mean collective wait.
#[derive(Debug, Clone, Copy)]
pub struct CalSample {
    pub bytes: f64,
    pub seconds: f64,
}

/// Ordinary least squares for `seconds ≈ alpha + beta * bytes`.
/// Returns `(alpha, beta)`; alpha is clamped at 0 (a negative intercept
/// just means the latency term is below measurement noise).
pub fn fit_alpha_beta(samples: &[CalSample]) -> (f64, f64) {
    assert!(samples.len() >= 2, "need at least two payload sizes to fit");
    let n = samples.len() as f64;
    let mean_b = samples.iter().map(|s| s.bytes).sum::<f64>() / n;
    let mean_t = samples.iter().map(|s| s.seconds).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut var = 0.0;
    for s in samples {
        cov += (s.bytes - mean_b) * (s.seconds - mean_t);
        var += (s.bytes - mean_b) * (s.bytes - mean_b);
    }
    assert!(var > 0.0, "payload sizes must differ");
    let beta = cov / var;
    let alpha = (mean_t - beta * mean_b).max(0.0);
    (alpha, beta.max(0.0))
}

/// One compressed (Q8) rung: raw payload bytes, the codec's deterministic
/// wire bytes, and the measured mean collective wait.
#[derive(Debug, Clone, Copy)]
pub struct CompressedCalSample {
    pub raw_bytes: f64,
    pub wire_bytes: f64,
    pub seconds: f64,
}

/// Solve the codec term from the compressed rungs, given the dense-rung
/// α/β: each rung predicts `seconds = α + wire·β + 2·raw·δ`, so
/// `δ = (seconds - α - wire·β) / (2·raw)`; the rungs are averaged and the
/// result clamped at 0 (sub-noise codecs just mean δ is unmeasurably
/// small on this host, not negative).
pub fn fit_delta(alpha: f64, beta: f64, samples: &[CompressedCalSample]) -> f64 {
    assert!(!samples.is_empty(), "need at least one compressed rung");
    let sum: f64 = samples
        .iter()
        .map(|s| (s.seconds - alpha - beta * s.wire_bytes) / (2.0 * s.raw_bytes))
        .sum();
    (sum / samples.len() as f64).max(0.0)
}

/// Fitted model plus the raw rungs behind it (for the JSON report).
#[derive(Debug, Clone)]
pub struct Calibration {
    pub model: NetworkModel,
    pub samples: Vec<CalSample>,
    pub compressed: Vec<CompressedCalSample>,
}

/// Run the calibration ladder (dense rungs for α/β, Q8 rungs for δ) and
/// return the fit plus the raw samples.
pub fn calibrate(quick: bool, seed: u64) -> Calibration {
    let p = 4usize;
    let steps: u64 = if quick { 20 } else { 60 };
    let dims: &[usize] = if quick {
        &[4096, 32768, 131_072]
    } else {
        &[4096, 16384, 65536, 262_144, 1_048_576]
    };
    let run_ladder = |compression: Compression| -> Vec<(usize, f64)> {
        dims.iter()
            .map(|&dim| {
                let cfg = MeasuredConfig {
                    p,
                    group_size: 2, // exactly one exchange per op
                    tau: 0,
                    dim,
                    steps,
                    chunk_elems: 0,
                    compression,
                    compute: vec![vec![0.0; p]; steps as usize],
                    faults: crate::fault::FaultPlan::none(),
                };
                (dim, run_measured(&cfg).wait.mean)
            })
            .collect()
    };
    let samples: Vec<CalSample> = run_ladder(Compression::None)
        .into_iter()
        .map(|(dim, seconds)| CalSample { bytes: (dim * 4) as f64, seconds })
        .collect();
    let (alpha, beta) = fit_alpha_beta(&samples);
    let q8 = Compression::QuantizeQ8;
    let compressed: Vec<CompressedCalSample> = run_ladder(q8)
        .into_iter()
        .map(|(dim, seconds)| CompressedCalSample {
            raw_bytes: (dim * 4) as f64,
            wire_bytes: q8.wire_bytes(dim * 4) as f64,
            seconds,
        })
        .collect();
    let delta = fit_delta(alpha, beta, &compressed);
    let aries = NetworkModel::aries();
    let _ = seed; // the serial ladder is compute-free; kept for CLI symmetry
    Calibration {
        model: NetworkModel {
            alpha,
            beta,
            gamma: aries.gamma,
            contention: aries.contention,
            delta,
        },
        samples,
        compressed,
    }
}

/// JSON report for `wagma bench --calibrate`.
pub fn calibration_json(cal: &Calibration) -> Json {
    let model = &cal.model;
    obj(vec![
        ("alpha_s", num(model.alpha)),
        ("beta_s_per_byte", num(model.beta)),
        ("gamma_s_per_byte", num(model.gamma)),
        ("contention", num(model.contention)),
        ("delta_s_per_byte", num(model.delta)),
        // α/β/δ come from this host's ladder; γ/contention are still the
        // Aries defaults.
        ("delta_source", s("measured")),
        (
            "samples",
            Json::Arr(
                cal.samples
                    .iter()
                    .map(|sm| {
                        obj(vec![("bytes", num(sm.bytes)), ("wait_mean_s", num(sm.seconds))])
                    })
                    .collect(),
            ),
        ),
        (
            "compressed_samples",
            Json::Arr(
                cal.compressed
                    .iter()
                    .map(|sm| {
                        obj(vec![
                            ("raw_bytes", num(sm.raw_bytes)),
                            ("wire_bytes", num(sm.wire_bytes)),
                            ("wait_mean_s", num(sm.seconds)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_exact_affine_data() {
        let alpha = 2.5e-6;
        let beta = 1.0 / 12e9;
        let samples: Vec<CalSample> = [1024.0f64, 65536.0, 1048576.0, 4194304.0]
            .iter()
            .map(|&b| CalSample { bytes: b, seconds: alpha + beta * b })
            .collect();
        let (a, b) = fit_alpha_beta(&samples);
        assert!((a - alpha).abs() / alpha < 1e-6, "alpha {a} vs {alpha}");
        assert!((b - beta).abs() / beta < 1e-6, "beta {b} vs {beta}");
    }

    #[test]
    fn fit_clamps_negative_intercepts() {
        // Pure-slope data with noise pushing the intercept negative.
        let samples = [
            CalSample { bytes: 1000.0, seconds: 0.5e-6 },
            CalSample { bytes: 2000.0, seconds: 2.0e-6 },
        ];
        let (a, b) = fit_alpha_beta(&samples);
        assert_eq!(a, 0.0);
        assert!(b > 0.0);
    }

    /// `fit_delta` recovers an exactly-affine codec term from synthetic
    /// rungs priced by the model it inverts.
    #[test]
    fn fit_delta_recovers_codec_term() {
        let (alpha, beta, delta) = (2.0e-6, 1.0 / 8e9, 1.0 / 16e9);
        let rungs: Vec<CompressedCalSample> = [16384.0f64, 131072.0, 1048576.0]
            .iter()
            .map(|&raw| {
                let wire = raw / 4.0 + 8.0; // q8-shaped: quarter the bytes + header
                CompressedCalSample {
                    raw_bytes: raw,
                    wire_bytes: wire,
                    seconds: alpha + beta * wire + 2.0 * delta * raw,
                }
            })
            .collect();
        let d = fit_delta(alpha, beta, &rungs);
        assert!((d - delta).abs() / delta < 1e-9, "delta {d} vs {delta}");
        // Sub-noise rungs clamp to zero rather than going negative.
        let noisy = [CompressedCalSample { raw_bytes: 4096.0, wire_bytes: 1032.0, seconds: 0.0 }];
        assert_eq!(fit_delta(alpha, beta, &noisy), 0.0);
    }

    /// End-to-end smoke on the real harness (quick ladder): the fit must
    /// be finite, non-negative, and in a plausible band for in-memory
    /// transport (β far above a real NIC's, α in the sub-millisecond
    /// range). δ is measured (clamped ≥ 0) and reported as such.
    #[test]
    fn calibrate_smoke() {
        let cal = calibrate(true, 1);
        assert_eq!(cal.samples.len(), 3);
        assert_eq!(cal.compressed.len(), 3);
        let model = &cal.model;
        assert!(model.alpha >= 0.0 && model.alpha < 0.05, "alpha {}", model.alpha);
        assert!(model.beta >= 0.0 && model.beta.is_finite());
        assert!(model.delta >= 0.0 && model.delta.is_finite(), "delta {}", model.delta);
        // The Q8 rung really shrinks the wire.
        for c in &cal.compressed {
            assert!(c.wire_bytes < c.raw_bytes / 3.0, "q8 wire {} raw {}", c.wire_bytes, c.raw_bytes);
        }
        let j = calibration_json(&cal).to_string();
        assert!(j.contains("alpha_s"));
        assert!(j.contains("delta_source") && j.contains("measured"), "{j}");
        assert!(j.contains("compressed_samples"));
    }
}
