//! Measured (wall-clock) overlap harness — the thread-backed counterpart
//! of the simulator's layered mode (ROADMAP: "Measured overlap").
//!
//! The discrete-event simulator *predicts* how much communication hides
//! under backprop when exchanges stream as fused buckets. This harness
//! *measures* it: real compute-thread work (busy-wait shaped by the
//! preset's imbalance process, time-scaled down) runs against real
//! [`CollectiveEngine`] collectives whose chunk granularity comes from the
//! PR-1 [`FusionPlan`], and we record per-op exposed wait, wall-clock
//! iteration times, bytes memcpy'd per iteration, and buffer-pool
//! allocation counts.
//!
//! Four runs per preset quantify the overlap:
//!
//! * **layered / flat** — chunked (plan-granularity) vs whole-payload
//!   exchanges, under the preset's imbalance;
//! * **serial references** — the same two engine configurations with zero
//!   compute, so every rank arrives at the collective together and the
//!   full collective latency is exposed.
//!
//! The *achieved overlap fraction* is `1 - wait(imbalanced)/wait(serial)`:
//! the share of the collective's serial latency that disappeared under
//! compute (wait-avoiding passive execution + chunk streaming). The same
//! JSON carries the simulator's layered-vs-flat exposed-communication
//! fraction for the matching preset ([`simulated_overlap_fraction`]), so
//! `BENCH_engine.json` is a direct simulator-vs-measured comparison.
//!
//! Bytes-copied accounting is deterministic (the engine's copy counter
//! increments are code-structural, not timing-dependent), which is what
//! makes the CI regression check against a checked-in baseline sound.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::collectives::allreduce::RING_THRESHOLD;
use crate::collectives::engine::{ActivationMode, CollectiveEngine, EngineConfig, EngineStats};
use crate::collectives::AllreduceAlgo;
use crate::comm::world;
use crate::compress::Compression;
use crate::config::preset;
use crate::data::StepDelays;
use crate::fault::FaultPlan;
use crate::optim::Algorithm;
use crate::sched::{FusionConfig, FusionPlan, LayerProfile};
use crate::simulator::{simulated_overlap_fraction, NetworkModel};
use crate::telemetry::TelemetryRegistry;
use crate::topology::{log2_exact, Grouping};
use crate::trace::{attribute, critical_path_events, now_ns, HistogramRegistry, Lane, TraceEvent, TraceKind};
use crate::util::json::{num, obj, s, Json};
use crate::util::stats::Summary;

/// One engine-backed measurement run.
#[derive(Debug, Clone)]
pub struct MeasuredConfig {
    pub p: usize,
    pub group_size: usize,
    pub tau: u64,
    pub dim: usize,
    pub steps: u64,
    /// Engine streaming granularity (0 = whole-payload exchanges).
    pub chunk_elems: usize,
    /// Per-bucket wire compression for the engine's exchanges.
    pub compression: Compression,
    /// Per-step, per-rank compute seconds (steps × p). Empty inner values
    /// are not allowed; use zeros for a serial reference.
    pub compute: Vec<Vec<f64>>,
    /// Deterministic fault schedule. A crashed rank's application stops
    /// issuing collectives from its crash iteration; survivors route
    /// around it via the plan-derived membership view. The empty plan
    /// takes literally the pre-fault engine paths.
    pub faults: FaultPlan,
}

/// Wall-clock measurements aggregated over all ranks.
#[derive(Debug, Clone)]
pub struct MeasuredRun {
    /// Exposed per-op wait: publish → result, seconds.
    pub wait: Summary,
    /// Full per-iteration wall time per rank, seconds.
    pub iter: Summary,
    pub wall_seconds: f64,
    /// Engine-side payload bytes memcpy'd, averaged per rank-iteration
    /// (deterministic: ring reassembly on sync iterations only when the
    /// application publishes by move).
    pub copied_bytes_per_iter: f64,
    pub sent_bytes_per_iter: f64,
    /// Total data-payload bytes on the wire across all ranks, exact
    /// (ctrl frames carry no payload, so this is deterministic and equals
    /// the sum of the telemetry registry's per-rank `wire_bytes`).
    pub sent_bytes_total: u64,
    /// Pool misses across all ranks (fixed after warmup).
    pub pool_allocs: u64,
    pub group_collectives: u64,
    pub global_syncs: u64,
    /// Merged per-rank trace timelines (app + engine lanes), sorted by
    /// start time.
    pub trace: Vec<TraceEvent>,
    /// Events lost to ring overflow across all ranks (0 at these scales).
    pub dropped_trace_events: u64,
    /// Butterfly phases completed as identity (dead/suspect peer), all
    /// ranks. Deterministic for plan-declared crashes.
    pub skipped_phases: u64,
    /// Group collectives with at least one skipped phase, all ranks.
    pub degraded_iters: u64,
    /// Application iterations actually executed across all ranks (crashed
    /// ranks stop at their crash iteration).
    pub survivor_steps: u64,
    /// Engine-thread ns blocked in group-phase receives, all ranks.
    pub wait_group_ns: u64,
}

/// Spin-accurate busy wait (sleeps the bulk, spins the tail).
fn busy_compute(d: Duration) {
    if d.is_zero() {
        return;
    }
    let t0 = Instant::now();
    if d > Duration::from_millis(2) {
        thread::sleep(d - Duration::from_millis(1));
    }
    while t0.elapsed() < d {
        std::hint::spin_loop();
    }
}

/// Run `cfg.steps` WAGMA-style iterations (publish → group allreduce, with
/// the every-τ global sync) on real engine threads and measure.
pub fn run_measured(cfg: &MeasuredConfig) -> MeasuredRun {
    run_measured_with(cfg, None)
}

/// [`run_measured`] with a live-telemetry registry attached to every
/// engine: steps, wait attribution, wire bytes, membership, and staleness
/// stream into `telemetry` while the run is in flight (atomics only — the
/// measured counters are bit-identical with and without it). The registry
/// must be sized for `cfg.p` ranks.
pub fn run_measured_with(
    cfg: &MeasuredConfig,
    telemetry: Option<Arc<TelemetryRegistry>>,
) -> MeasuredRun {
    assert_eq!(cfg.compute.len(), cfg.steps as usize, "one compute row per step");
    assert!(cfg.compute.iter().all(|row| row.len() == cfg.p));
    let ecfg = EngineConfig {
        p: cfg.p,
        group_size: cfg.group_size,
        tau: cfg.tau,
        dynamic_groups: true,
        sync_algo: AllreduceAlgo::Auto,
        activation: ActivationMode::Solo,
        chunk_elems: cfg.chunk_elems,
        compression: cfg.compression,
        trace: true,
        recv_deadline_ns: 0,
        // With a live fault plan the group receives are deadline-bounded
        // (the plan's deadline); generous retries keep transient
        // scheduling hiccups on a loaded CI box from registering as
        // spurious suspects. Irrelevant when the plan is empty (the
        // effective deadline is 0 = the legacy blocking path).
        recv_retries: if cfg.faults.is_empty() { 0 } else { 5 },
    };
    let faults = Arc::new(cfg.faults.clone());
    let start = Instant::now();
    let engines: Vec<CollectiveEngine> = world(cfg.p)
        .into_iter()
        .map(|ep| {
            let r = ep.rank() as f32;
            CollectiveEngine::spawn_instrumented(
                ep,
                ecfg,
                vec![r; cfg.dim],
                faults.clone(),
                telemetry.clone(),
            )
        })
        .collect();
    let compute = Arc::new(cfg.compute.clone());
    let dim = cfg.dim;
    let steps = cfg.steps;
    let handles: Vec<_> = engines
        .into_iter()
        .map(|eng| {
            let compute = compute.clone();
            let faults = faults.clone();
            thread::spawn(move || {
                let rank = eng.rank();
                let crash = faults.crash_iter(rank);
                let tracer = eng.tracer();
                let mut waits = Vec::with_capacity(steps as usize);
                let mut iters = Vec::with_capacity(steps as usize);
                for t in 0..steps {
                    if crash.is_some_and(|ci| t >= ci) {
                        // Fail-stop: the application issues nothing from
                        // its crash iteration on; survivors route around
                        // it via the plan-derived membership view.
                        break;
                    }
                    let it0 = Instant::now();
                    let comp0 = now_ns();
                    busy_compute(Duration::from_secs_f64(compute[t as usize][rank]));
                    let mut ev =
                        TraceEvent::new(TraceKind::Compute, Lane::App, comp0, now_ns() - comp0);
                    ev.version = t;
                    tracer.record(ev);
                    let w = vec![rank as f32 + t as f32; dim];
                    let c0 = Instant::now();
                    eng.publish_owned(w, t);
                    if eng.config().is_sync_iter(t) {
                        let sum = eng.global_sync(t);
                        std::hint::black_box(&sum);
                    } else {
                        let res = eng.group_allreduce(t);
                        std::hint::black_box(&res.sum);
                    }
                    waits.push(c0.elapsed().as_secs_f64());
                    iters.push(it0.elapsed().as_secs_f64());
                }
                let stats = eng.shutdown();
                (waits, iters, stats, tracer.drain())
            })
        })
        .collect();
    let mut waits = Vec::new();
    let mut iters = Vec::new();
    let mut stats: Vec<EngineStats> = Vec::new();
    let mut trace = Vec::new();
    let mut survivor_steps = 0u64;
    for h in handles {
        let (w, i, st, tr) = h.join().unwrap();
        survivor_steps += w.len() as u64;
        waits.extend(w);
        iters.extend(i);
        stats.push(st);
        trace.extend(tr);
    }
    trace.sort_by_key(|e| (e.t_ns, e.rank, e.lane.index(), e.kind.index()));
    let rank_iters = (cfg.p as u64 * steps) as f64;
    MeasuredRun {
        wait: Summary::of(&waits),
        iter: Summary::of(&iters),
        wall_seconds: start.elapsed().as_secs_f64(),
        copied_bytes_per_iter: stats.iter().map(|s| s.copied_bytes).sum::<u64>() as f64
            / rank_iters,
        sent_bytes_per_iter: stats.iter().map(|s| s.sent_bytes).sum::<u64>() as f64 / rank_iters,
        sent_bytes_total: stats.iter().map(|s| s.sent_bytes).sum(),
        pool_allocs: stats.iter().map(|s| s.pool_allocs).sum(),
        group_collectives: stats.iter().map(|s| s.group_collectives).sum(),
        global_syncs: stats.iter().map(|s| s.global_syncs).sum(),
        trace,
        dropped_trace_events: stats.iter().map(|s| s.dropped_trace_events).sum(),
        skipped_phases: stats.iter().map(|s| s.skipped_phases).sum(),
        degraded_iters: stats.iter().map(|s| s.degraded_iters).sum(),
        survivor_steps,
        wait_group_ns: stats.iter().map(|s| s.wait_group_ns).sum(),
    }
}

/// Payload bytes the pre-refactor engine memcpy'd per rank-iteration for
/// the same schedule — the baseline of the acceptance criterion. Derived
/// from the seed implementation's copy sites: `publish` appended into the
/// send buffer (n), each collective cloned the buffer as its contribution
/// snapshot (n), each butterfly phase cloned the accumulator for the send
/// (or materialized `to_vec` chunks totalling n), and each ring step
/// copied its segment out (2(P-1) · n/P across the sync).
pub fn legacy_copied_bytes_per_iter(
    dim: usize,
    p: usize,
    group_size: usize,
    tau: u64,
    steps: u64,
) -> f64 {
    let n = (dim * 4) as f64;
    let phases = log2_exact(group_size.max(1).next_power_of_two()) as f64;
    let syncs = if tau == 0 { 0 } else { (1..=steps).filter(|t| t % tau == 0).count() as u64 };
    let groups = steps - syncs;
    let group_cost = n + n + phases * n;
    let sync_comm = if p > 2 && dim >= RING_THRESHOLD {
        2.0 * (p as f64 - 1.0) * (n / p as f64)
    } else {
        log2_exact(p.max(1)) as f64 * n
    };
    let sync_cost = n + n + sync_comm;
    (groups as f64 * group_cost + syncs as f64 * sync_cost) / steps as f64
}

/// Scaled-down measurement shape for one paper preset.
pub struct PresetCase {
    pub name: String,
    pub p: usize,
    pub dim: usize,
    pub steps: u64,
    pub tau: u64,
    pub group_size: usize,
    pub chunk_elems: usize,
    pub compute_mean: f64,
    pub buckets: usize,
}

/// Derive the scaled measurement case: model dimension shrunk ~128×, the
/// preset's imbalance process time-scaled to a few milliseconds of compute
/// per step, and the engine chunk granularity set so one phase streams as
/// many chunks as the PR-1 fusion plan has buckets.
pub fn preset_case(name: &str, quick: bool) -> PresetCase {
    let pre = preset(name).unwrap_or_else(|| panic!("unknown preset {name}"));
    let p = if quick { 4 } else { 8 };
    let dim = (pre.model_params / 128).max(RING_THRESHOLD);
    let steps = if quick { 12 } else { 40 };
    let profile = LayerProfile::for_model_bytes(pre.model_params * 4);
    let plan = FusionPlan::threshold(&profile, FusionConfig::default().threshold_bytes);
    let buckets = plan.num_buckets().max(1);
    PresetCase {
        name: name.to_string(),
        p,
        dim,
        steps,
        tau: pre.tau,
        group_size: Grouping::sqrt_group_size(p),
        chunk_elems: dim.div_ceil(buckets),
        compute_mean: if quick { 0.002 } else { 0.004 },
        buckets,
    }
}

/// Compute-time matrix for the case: the preset's imbalance process,
/// rescaled so its mean lands on `compute_mean` (0 ⇒ serial reference).
pub fn compute_matrix(case: &PresetCase, serial: bool, seed: u64) -> Vec<Vec<f64>> {
    if serial {
        return vec![vec![0.0; case.p]; case.steps as usize];
    }
    let pre = preset(&case.name).unwrap();
    let scale = case.compute_mean / pre.imbalance.mean();
    let mut delays = StepDelays::new(pre.imbalance, case.p, seed);
    delays
        .sample_many(case.steps as usize)
        .into_iter()
        .map(|row| row.into_iter().map(|d| d * scale).collect())
        .collect()
}

/// Full measurement + simulator comparison for one preset. Returns the
/// JSON object embedded in `BENCH_engine.json` and prints a summary row.
pub fn bench_preset(name: &str, quick: bool, seed: u64) -> Json {
    bench_preset_compressed(name, quick, seed, Compression::TopK { ratio: 0.1 })
}

/// [`bench_preset`] with an explicit compressed arm: alongside the
/// layered/flat (uncompressed) runs and their serial references, the same
/// layered schedule runs with per-bucket wire compression, so the report
/// carries measured bytes-on-wire and achieved overlap with and without
/// compression. `Compression::None` skips the compressed arm.
pub fn bench_preset_compressed(name: &str, quick: bool, seed: u64, comp: Compression) -> Json {
    bench_preset_traced(name, quick, seed, comp).0
}

/// [`bench_preset_compressed`] that also hands back the layered run's
/// merged trace timeline, for Chrome-trace export (`wagma bench --trace`)
/// and the measured-vs-simulated attribution diff (`wagma trace`).
pub fn bench_preset_traced(
    name: &str,
    quick: bool,
    seed: u64,
    comp: Compression,
) -> (Json, Vec<TraceEvent>) {
    bench_preset_instrumented(name, quick, seed, comp, None)
}

/// [`bench_preset_traced`] with a live-telemetry registry attached to the
/// *layered* (headline) arm, so a sampler/scrape endpoint observes the
/// measurement while it runs. The reference arms stay uninstrumented —
/// their counters would pollute the per-rank registry with runs that are
/// not the one being dashboarded. The registry must be sized for the
/// case's `p` ([`preset_case`]).
pub fn bench_preset_instrumented(
    name: &str,
    quick: bool,
    seed: u64,
    comp: Compression,
    telemetry: Option<Arc<TelemetryRegistry>>,
) -> (Json, Vec<TraceEvent>) {
    let case = preset_case(name, quick);
    let mk = |chunk_elems: usize, serial: bool, compression: Compression| -> MeasuredRun {
        let cfg = MeasuredConfig {
            p: case.p,
            group_size: case.group_size,
            tau: case.tau,
            dim: case.dim,
            steps: case.steps,
            chunk_elems,
            compression,
            compute: compute_matrix(&case, serial, seed),
            faults: FaultPlan::none(),
        };
        run_measured(&cfg)
    };
    let layered = run_measured_with(
        &MeasuredConfig {
            p: case.p,
            group_size: case.group_size,
            tau: case.tau,
            dim: case.dim,
            steps: case.steps,
            chunk_elems: case.chunk_elems,
            compression: Compression::None,
            compute: compute_matrix(&case, false, seed),
            faults: FaultPlan::none(),
        },
        telemetry,
    );
    let flat = mk(0, false, Compression::None);
    let layered_serial = mk(case.chunk_elems, true, Compression::None);
    let flat_serial = mk(0, true, Compression::None);
    let compressed = (!comp.is_none()).then(|| mk(case.chunk_elems, false, comp));
    let compressed_serial = (!comp.is_none()).then(|| mk(case.chunk_elems, true, comp));

    let overlap = |run: &MeasuredRun, serial: &MeasuredRun| -> f64 {
        if serial.wait.mean > 1e-9 {
            1.0 - run.wait.mean / serial.wait.mean
        } else {
            0.0
        }
    };
    let layered_overlap = overlap(&layered, &layered_serial);
    let flat_overlap = overlap(&flat, &flat_serial);
    let compressed_overlap = match (&compressed, &compressed_serial) {
        (Some(c), Some(cs)) => overlap(c, cs),
        _ => 0.0,
    };
    let wire_reduction = compressed
        .as_ref()
        .map(|c| layered.sent_bytes_per_iter / c.sent_bytes_per_iter.max(1.0))
        .unwrap_or(1.0);

    let legacy =
        legacy_copied_bytes_per_iter(case.dim, case.p, case.group_size, case.tau, case.steps);
    let copy_reduction = legacy / layered.copied_bytes_per_iter.max(1.0);

    // Simulator-side validation at the preset's true scale (P = 64, full
    // model bytes): layered-vs-flat exposed communication, plus the same
    // configuration with wire compression priced in.
    let pre = preset(name).unwrap();
    // Keep the preset's own fusion tuning; the hook forces layered on/off.
    let sim_cfg = pre.sim_config(Algorithm::Wagma, 64, seed);
    let (sim_flat, sim_layered, sim_frac) = simulated_overlap_fraction(&sim_cfg);
    let sim_compressed = (!comp.is_none()).then(|| {
        let mut c_cfg = sim_cfg.clone();
        c_cfg.compress = comp;
        crate::simulator::simulate(&c_cfg)
    });

    // Critical-path attribution (trace/critpath). The measured layered
    // arm is wall-clock (what `wagma critpath --explain` diffs); the two
    // simulator arms are analytic — deterministic per seed — which is
    // what `--check-critpath-baseline` gates: the preset-scale mirrored
    // sim, and the race-free P=1 shape whose class partition is the
    // bit-exactness pin (compute share is exactly 1 there: no peers, no
    // wire, no gaps).
    let crit_steps = 24usize;
    let sim_crit_cp = {
        let mut c = sim_cfg.clone();
        c.trace = true;
        c.steps = c.steps.min(crit_steps);
        critical_path_events(&crate::simulator::simulate(&c).trace)
    };
    let p1_crit_cp = {
        let mut c = sim_cfg.clone();
        c.p = 1;
        c.trace = true;
        c.steps = c.steps.min(crit_steps);
        critical_path_events(&crate::simulator::simulate(&c).trace)
    };
    let layered_cp = critical_path_events(&layered.trace);
    let crit_arm = |cp: &crate::trace::CritPath, p: usize| {
        let extra = vec![
            ("p", num(p as f64)),
            ("steps", num(sim_cfg.steps.min(crit_steps) as f64)),
            ("partition_exact", Json::Bool(cp.partition_exact())),
        ];
        match cp.to_json() {
            Json::Obj(mut m) => {
                for (k, v) in extra {
                    m.insert(k.to_string(), v);
                }
                Json::Obj(m)
            }
            other => other,
        }
    };

    println!(
        "{:<6} P{} dim {:>7} chunks {:>3}  wait p50 {:.3} ms (flat {:.3})  overlap {:>5.2} (flat {:>5.2}, sim {:.2})  copied/iter {:>9.0} B (legacy {:>11.0}, {:.0}x)",
        case.name,
        case.p,
        case.dim,
        case.buckets,
        layered.wait.p50 * 1e3,
        flat.wait.p50 * 1e3,
        layered_overlap,
        flat_overlap,
        sim_frac,
        layered.copied_bytes_per_iter,
        legacy,
        copy_reduction,
    );
    if let Some(c) = &compressed {
        let codec = match comp {
            Compression::TopK { ratio } => format!("topk (ratio {ratio})"),
            _ => comp.name().to_string(),
        };
        println!(
            "       compression {codec}: wire {:>9.0} B/iter vs {:>9.0} uncompressed ({:.1}x), overlap {:>5.2}",
            c.sent_bytes_per_iter,
            layered.sent_bytes_per_iter,
            wire_reduction,
            compressed_overlap,
        );
    }
    {
        let mk = layered_cp.makespan_ns().max(1) as f64;
        println!(
            "       critpath: measured compute {:>4.1}% wait {:>4.1}%  sim {} on-path spans / {} wire B  p1 exact {}",
            100.0 * layered_cp.class_ns[0] as f64 / mk,
            100.0 * layered_cp.class_ns[1] as f64 / mk,
            sim_crit_cp.onpath_spans(),
            sim_crit_cp.onpath_wire_bytes,
            p1_crit_cp.partition_exact(),
        );
    }

    // Trace/attribution summary from the layered run's merged timeline.
    // Span counts and bytes-on-wire are code-structural (same determinism
    // argument as `sent_bytes`), so they are baseline-gateable; the wait
    // percentiles and attribution seconds are wall-clock.
    let att = attribute(&layered.trace, &NetworkModel::aries());
    let wait_hist = HistogramRegistry::from_events(
        layered.trace.iter().filter(|e| e.lane == Lane::App && e.kind == TraceKind::Wait),
    );
    let wh = wait_hist.kind(TraceKind::Wait);
    let trace_json = obj(vec![
        ("phase_spans", num(att.phase_spans as f64)),
        ("tau_sync_spans", num(att.tau_sync_spans as f64)),
        ("phase_wire_bytes", num(att.phase_wire_bytes as f64)),
        ("sync_wire_bytes", num(att.sync_wire_bytes as f64)),
        ("dropped_events", num(layered.dropped_trace_events as f64)),
        ("wait_p50_s", num(wh.quantile(0.5) * 1e-9)),
        ("wait_p99_s", num(wh.quantile(0.99) * 1e-9)),
        ("attribution", att.to_json()),
    ]);

    let run_json = |r: &MeasuredRun, ov: f64| {
        obj(vec![
            ("wait_p50_s", num(r.wait.p50)),
            ("wait_p99_s", num(r.wait.p99)),
            ("wait_mean_s", num(r.wait.mean)),
            ("iter_p50_s", num(r.iter.p50)),
            ("iter_p99_s", num(r.iter.p99)),
            ("copied_bytes_per_iter", num(r.copied_bytes_per_iter)),
            ("sent_bytes_per_iter", num(r.sent_bytes_per_iter)),
            ("pool_allocs", num(r.pool_allocs as f64)),
            ("overlap_fraction", num(ov)),
        ])
    };
    let json = obj(vec![
        ("preset", s(&case.name)),
        ("p", num(case.p as f64)),
        ("dim", num(case.dim as f64)),
        ("steps", num(case.steps as f64)),
        ("tau", num(case.tau as f64)),
        ("group_size", num(case.group_size as f64)),
        ("chunk_elems", num(case.chunk_elems as f64)),
        ("plan_buckets", num(case.buckets as f64)),
        ("compute_mean_s", num(case.compute_mean)),
        ("measured_layered", run_json(&layered, layered_overlap)),
        ("measured_flat", run_json(&flat, flat_overlap)),
        (
            "compression",
            obj(vec![
                ("kind", s(comp.name())),
                // Only the top-k codec has a keep ratio.
                (
                    "topk_ratio",
                    match comp {
                        Compression::TopK { ratio } => num(ratio),
                        _ => Json::Null,
                    },
                ),
                ("wire_reduction_x", num(wire_reduction)),
            ]),
        ),
        (
            "measured_compressed",
            compressed
                .as_ref()
                .map(|c| run_json(c, compressed_overlap))
                .unwrap_or(Json::Null),
        ),
        ("serial_wait_p50_s", num(layered_serial.wait.p50)),
        // Deterministic snapshot counters for the layered (telemetered)
        // arm — the values `--check-telemetry-baseline` gates. `steps`
        // is application iterations across all ranks; `wire_bytes` is
        // total data payload on the wire (ctrl frames are free), which
        // equals the sum of the live registry's per-rank `wire_bytes`.
        (
            "telemetry",
            obj(vec![
                ("steps", num(layered.survivor_steps as f64)),
                ("wire_bytes", num(layered.sent_bytes_total as f64)),
            ]),
        ),
        ("trace", trace_json),
        (
            "critpath",
            obj(vec![
                // Measured (wall-clock) arm — the one the explainer diffs.
                ("layered", layered_cp.to_json()),
                // Deterministic analytic arms — the ones the gate checks.
                ("sim", crit_arm(&sim_crit_cp, 64)),
                ("p1", crit_arm(&p1_crit_cp, 1)),
            ]),
        ),
        (
            "legacy_model",
            obj(vec![
                ("copied_bytes_per_iter", num(legacy)),
                ("copy_reduction_x", num(copy_reduction)),
            ]),
        ),
        (
            "simulator",
            obj(vec![
                ("p", num(64.0)),
                ("flat_makespan_s", num(sim_flat.makespan)),
                ("layered_makespan_s", num(sim_layered.makespan)),
                ("ideal_makespan_s", num(sim_flat.ideal_makespan)),
                ("exposed_flat_s", num(sim_flat.exposed_comm())),
                ("exposed_layered_s", num(sim_layered.exposed_comm())),
                ("overlap_fraction", num(sim_frac)),
                ("wire_bytes_per_iter", num(sim_flat.wire_bytes_per_iter)),
            ]),
        ),
        (
            "simulator_compressed",
            sim_compressed
                .as_ref()
                .map(|r| {
                    obj(vec![
                        ("makespan_s", num(r.makespan)),
                        ("exposed_s", num(r.exposed_comm())),
                        ("wire_bytes_per_iter", num(r.wire_bytes_per_iter)),
                        (
                            "wire_reduction_x",
                            num(sim_flat.wire_bytes_per_iter / r.wire_bytes_per_iter.max(1.0)),
                        ),
                    ])
                })
                .unwrap_or(Json::Null),
        ),
    ]);
    (json, layered.trace)
}

/// Fault-injection smoke for one preset: the layered measured schedule
/// under the preset's imbalance, with a plan-declared fail-stop
/// (`wagma bench --faults`). Returns the JSON object embedded in
/// `BENCH_faults.json` and prints a summary row.
///
/// The gate-worthy fields (`skipped_phases`, `degraded_iters`,
/// `survivor_steps`) are membership-structural, not timing-dependent:
/// plan-declared crashes flip the shared membership view at the crash
/// iteration on every rank, so each survivor skips exactly the butterfly
/// phases whose partner is dead — the same determinism argument as
/// `copied_bytes`. Timing noise can only add *extra* suspect-skips on
/// top (hence the baseline check uses a lower bound plus a slack factor,
/// not equality).
pub fn bench_fault_preset(name: &str, quick: bool, seed: u64, spec: &str) -> anyhow::Result<Json> {
    let case = preset_case(name, quick);
    let plan = FaultPlan::parse(spec, case.p, case.steps, seed)
        .map_err(|e| anyhow::anyhow!("bad --faults spec {spec:?}: {e}"))?;
    let crash = plan.crashes.first().copied();
    let cfg = MeasuredConfig {
        p: case.p,
        group_size: case.group_size,
        tau: case.tau,
        dim: case.dim,
        steps: case.steps,
        chunk_elems: case.chunk_elems,
        compression: Compression::None,
        compute: compute_matrix(&case, false, seed),
        faults: plan.clone(),
    };
    let r = run_measured(&cfg);
    println!(
        "{:<6} P{} {:<10} crash {}  skipped phases {:>3}  degraded iters {:>3}  survivor steps {:>4}  wait p99 {:.3} ms  group wait {:.3} ms",
        case.name,
        case.p,
        spec,
        crash.map(|c| format!("r{}@{}", c.rank, c.at_iter)).unwrap_or_else(|| "-".into()),
        r.skipped_phases,
        r.degraded_iters,
        r.survivor_steps,
        r.wait.p99 * 1e3,
        r.wait_group_ns as f64 * 1e-6,
    );
    Ok(obj(vec![
        ("preset", s(&case.name)),
        ("p", num(case.p as f64)),
        ("steps", num(case.steps as f64)),
        ("tau", num(case.tau as f64)),
        ("group_size", num(case.group_size as f64)),
        ("spec", s(spec)),
        ("crash_rank", crash.map(|c| num(c.rank as f64)).unwrap_or(Json::Null)),
        ("crash_at", crash.map(|c| num(c.at_iter as f64)).unwrap_or(Json::Null)),
        ("deadline_s", num(plan.deadline_s)),
        ("skipped_phases", num(r.skipped_phases as f64)),
        ("degraded_iters", num(r.degraded_iters as f64)),
        ("survivor_steps", num(r.survivor_steps as f64)),
        ("group_collectives", num(r.group_collectives as f64)),
        ("global_syncs", num(r.global_syncs as f64)),
        ("wait_p99_s", num(r.wait.p99)),
        ("wait_group_s", num(r.wait_group_ns as f64 * 1e-9)),
        ("wall_seconds", num(r.wall_seconds)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_smoke_runs_and_copies_match_model() {
        let steps = 6u64;
        let p = 2usize;
        let cfg = MeasuredConfig {
            p,
            group_size: 2,
            tau: 3,
            dim: 64,
            steps,
            chunk_elems: 16,
            compression: Compression::None,
            compute: vec![vec![0.0005; p]; steps as usize],
            faults: FaultPlan::none(),
        };
        let r = run_measured(&cfg);
        assert_eq!(r.group_collectives + r.global_syncs, steps * p as u64);
        assert!(r.wait.p50 >= 0.0 && r.iter.p50 >= 0.0005);
        // publish_owned + refcount sends: P=2 takes the recursive-doubling
        // sync path, and with at least one reduction phase the engine
        // memcpy's nothing at all.
        assert_eq!(r.copied_bytes_per_iter, 0.0);
        assert!(r.sent_bytes_per_iter > 0.0);
    }

    #[test]
    fn legacy_model_counts_publish_snapshot_and_phases() {
        // Group-only schedule (tau = 0), S = 4 → 2 phases: legacy copies
        // publish + snapshot + 2 sends = 4n per iteration.
        let n = (1000 * 4) as f64;
        let per_iter = legacy_copied_bytes_per_iter(1000, 8, 4, 0, 10);
        assert_eq!(per_iter, 4.0 * n);
        // With tau = 2 on a ring-sized payload, half the iterations pay the
        // ring's 2(P-1)/P segment copies instead of the phase clones.
        let dim = RING_THRESHOLD;
        let nb = (dim * 4) as f64;
        let per_iter = legacy_copied_bytes_per_iter(dim, 8, 4, 2, 10);
        let sync = 2.0 * nb + 2.0 * 7.0 * (nb / 8.0);
        let group = 4.0 * nb;
        assert!((per_iter - (group * 5.0 + sync * 5.0) / 10.0).abs() < 1e-6);
    }

    /// Measured-harness acceptance: the same schedule with top-k 0.1
    /// sends ≥ 4x fewer bytes on the wire (deterministic: `sent_bytes`
    /// counts data chunks only, whose number and size are
    /// code-structural).
    #[test]
    fn compressed_run_cuts_measured_wire_bytes_4x() {
        let steps = 8u64;
        let p = 4usize;
        let mk = |compression: Compression| -> MeasuredRun {
            run_measured(&MeasuredConfig {
                p,
                group_size: 2,
                tau: 0,
                dim: 4096,
                steps,
                chunk_elems: 1024,
                compression,
                compute: vec![vec![0.0; p]; steps as usize],
                faults: FaultPlan::none(),
            })
        };
        let plain = mk(Compression::None);
        let topk = mk(Compression::TopK { ratio: 0.1 });
        let reduction = plain.sent_bytes_per_iter / topk.sent_bytes_per_iter;
        assert!(reduction >= 4.0, "measured wire reduction {reduction}");
        assert_eq!(topk.group_collectives, steps * p as u64);
        // The compressed arm of the preset report carries the same fields.
        let j = bench_preset_compressed("fig4", true, 7, Compression::TopK { ratio: 0.1 });
        let c = j.get("measured_compressed").expect("compressed arm present");
        let wire = c
            .get("sent_bytes_per_iter")
            .and_then(|v| v.as_f64())
            .expect("sent bytes reported");
        let base = j
            .get("measured_layered")
            .and_then(|m| m.get("sent_bytes_per_iter"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(base / wire >= 4.0, "preset wire reduction {}", base / wire);
    }

    /// The bench report's `critpath` block: all three arms present, and
    /// the race-free P=1 analytic arm partitions exactly into pure
    /// compute (no peers, no wire, no gaps) — the bit-exactness pin the
    /// baseline gate relies on.
    #[test]
    fn bench_report_carries_deterministic_critpath_block() {
        let j = bench_preset_compressed("fig4", true, 7, Compression::None);
        let c = j.get("critpath").expect("critpath block");
        for arm in ["layered", "sim", "p1"] {
            assert!(
                c.get(arm).and_then(|a| a.get("makespan_ns")).is_some(),
                "missing critpath arm {arm}"
            );
        }
        let p1 = c.get("p1").unwrap();
        assert_eq!(p1.get("partition_exact").and_then(|v| v.as_bool()), Some(true));
        let share = p1
            .get("class_share")
            .and_then(|cs| cs.get("compute"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!(share > 0.999, "p1 compute share {share}");
        assert_eq!(
            p1.get("onpath_wire_bytes").and_then(|v| v.as_f64()),
            Some(0.0),
            "no wire at P=1"
        );
        // The preset-scale sim arm is peer-bound, not compute-only.
        let sim = c.get("sim").unwrap();
        assert!(sim.get("onpath_wire_bytes").and_then(|v| v.as_f64()).unwrap_or(0.0) >= 0.0);
        assert_eq!(sim.get("partition_exact").and_then(|v| v.as_bool()), Some(true));
    }

    #[test]
    fn preset_cases_are_scaled_sanely() {
        for name in ["fig4", "fig7", "fig10"] {
            let c = preset_case(name, true);
            assert!(c.dim >= RING_THRESHOLD);
            assert!(c.chunk_elems > 0 && c.chunk_elems < c.dim);
            assert!(c.buckets > 1, "{name} plan must split");
            let m = compute_matrix(&c, false, 1);
            assert_eq!(m.len(), c.steps as usize);
            let mean: f64 =
                m.iter().flatten().sum::<f64>() / (c.steps as usize * c.p) as f64;
            assert!(mean > 0.0 && mean < 0.1, "{name} scaled mean {mean}");
            let serial = compute_matrix(&c, true, 1);
            assert!(serial.iter().flatten().all(|&d| d == 0.0));
        }
    }
}
