//! In-process message-passing substrate, standing in for the paper's
//! MPI + fflib stack.
//!
//! Each simulated process ("rank") owns an [`Endpoint`]: per-peer
//! **mailbox lanes** (sharded locks — one data lane and one control lane
//! per sending peer) plus a reusable [`BufferPool`]. Messages carry a
//! [`Tag`] (collective kind, version, phase) and are matched MPI-style: a
//! blocking receive for a specific `(source, tag)` leaves non-matching
//! traffic queued in its sender's lane, so out-of-order arrivals are never
//! lost and never contend with the matched path.
//!
//! ## Zero-copy payloads
//!
//! Bulk data travels as a [`Chunk`]: a refcounted [`SharedBuf`]
//! (`Arc<PoolVec>`) plus a byte range. Sending is a refcount bump; a
//! chunked exchange sends range *views* of one buffer instead of
//! materializing per-chunk vectors. Buffers allocated from a
//! [`BufferPool`] return to their home pool when the last reference drops
//! (wherever that happens), so steady-state traffic performs no
//! allocation and no payload memcpy. [`Endpoint::copied_bytes`] counts
//! the bytes that *are* memcpy'd (e.g. direct-mode fallbacks), for the
//! measured-overlap bench.
//!
//! ## Lock structure
//!
//! The old implementation funneled all traffic through one
//! `mpsc::channel` plus an unmatched-message map. Now each peer has its
//! own `Mutex<Lane>`; the only shared state touched on the steady-state
//! path is that single lane lock. A `(Mutex<u64>, Condvar)` wake channel
//! is consulted **only when a receiver actually has to block** (the
//! `waiters` atomic gates the notify, so uncontended sends never touch
//! it).
//!
//! Wire substitution note (DESIGN.md §2): the paper runs over Cray Aries
//! with MPI point-to-point; we run over in-memory lanes. The *protocol*
//! content — tags, versions, activation control messages, schedule
//! ordering — is identical; only the transport differs.

// Hot-path panics are lint debt here: every `unwrap` in the mailbox or
// endpoint is a potential engine-thread abort under faults.
#![warn(clippy::unwrap_used)]

use std::collections::VecDeque;
use std::fmt;
use std::ops::Deref;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Typed error returned by the deadline receive paths: the deadline
/// elapsed with no matching message. Carries what the receive was waiting
/// for so callers (the engine's suspicion machinery) can attribute the
/// timeout to a peer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvTimeout {
    /// A matched data receive timed out waiting on `(src, tag)`.
    Data { src: usize, tag: Tag },
    /// A control receive timed out with no control traffic pending.
    Ctrl,
}

impl fmt::Display for RecvTimeout {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecvTimeout::Data { src, tag } => {
                write!(f, "receive deadline elapsed waiting on rank {src} for {tag:?}")
            }
            RecvTimeout::Ctrl => write!(f, "receive deadline elapsed waiting for control traffic"),
        }
    }
}

impl std::error::Error for RecvTimeout {}

/// What a message is for. Collective schedules never confuse traffic from
/// different collective families because the kind is part of the match.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgKind {
    /// Butterfly exchange inside a (group) allreduce.
    Exchange,
    /// Global synchronous allreduce phase.
    Sync,
    /// Point-to-point data (gossip baselines: D-PSGD, SGP).
    P2p,
}

/// MPI-style message tag: kind + collective version (training iteration)
/// + phase within the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag {
    pub kind: MsgKind,
    pub version: u64,
    pub phase: u32,
}

impl Tag {
    pub fn exchange(version: u64, phase: u32) -> Tag {
        Tag { kind: MsgKind::Exchange, version, phase }
    }

    pub fn sync(version: u64, phase: u32) -> Tag {
        Tag { kind: MsgKind::Sync, version, phase }
    }

    pub fn p2p(version: u64, phase: u32) -> Tag {
        Tag { kind: MsgKind::P2p, version, phase }
    }
}

/// Causal wire stamp: the *producing* side's span identity, carried in
/// the message header alongside the tag. A receiver's wait span gains a
/// happens-before edge to the send that satisfied it — this is the
/// metadata the cross-rank causal DAG ([`crate::trace::causal`]) is
/// stitched from. `send_ns` is on the sender's trace clock (all ranks
/// share the process-wide epoch, so it is directly comparable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stamp {
    /// Sending rank.
    pub src: u32,
    /// Collective version (training iteration) of the producing span.
    pub version: u64,
    /// Schedule phase of the producing span.
    pub phase: u32,
    /// Trace-clock time of the send.
    pub send_ns: u64,
}

// ---------------------------------------------------------------------------
// Buffer pool + shared payloads
// ---------------------------------------------------------------------------

/// Cap on the number of idle buffers a pool retains (protects against a
/// pathological producer pattern hoarding memory).
const POOL_FREE_CAP: usize = 64;

#[derive(Default)]
struct PoolState {
    free: Vec<Vec<f32>>,
    allocs: u64,
    takes: u64,
    puts: u64,
}

/// Counters describing a pool's lifetime behaviour. After warmup a healthy
/// steady state keeps `allocs` fixed while `takes`/`puts` keep growing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Fresh heap allocations performed (pool misses).
    pub allocs: u64,
    /// Buffers handed out.
    pub takes: u64,
    /// Buffers returned.
    pub puts: u64,
    /// Currently idle buffers.
    pub free: usize,
}

/// A shared, thread-safe free list of `Vec<f32>` payload buffers.
/// Cloning is cheap (one `Arc`); every clone refers to the same pool.
#[derive(Clone, Default)]
pub struct BufferPool {
    inner: Arc<Mutex<PoolState>>,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Take a buffer of exactly `n` elements. Reused buffers keep their
    /// previous contents in the prefix — callers must fully overwrite.
    ///
    /// Only free buffers whose capacity already covers `n` are reused
    /// (preferring the most recently returned), so the `resize` below
    /// never reallocates and `allocs` honestly counts every heap
    /// allocation even under mixed-size traffic (full models and ring
    /// segments share one pool).
    pub fn take(&self, n: usize) -> PoolVec {
        let mut v = {
            let mut st = self.inner.lock().unwrap();
            st.takes += 1;
            match st.free.iter().rposition(|v| v.capacity() >= n) {
                Some(i) => st.free.swap_remove(i),
                None => {
                    st.allocs += 1;
                    Vec::with_capacity(n)
                }
            }
        };
        v.resize(n, 0.0);
        PoolVec { data: v, home: Some(self.clone()) }
    }

    /// Wrap an externally-allocated vector so it retires into this pool
    /// when its last reference drops.
    pub fn adopt(&self, data: Vec<f32>) -> PoolVec {
        PoolVec { data, home: Some(self.clone()) }
    }

    /// Return a raw vector to the free list. Every non-empty return is
    /// counted in `puts` (so `takes - puts` bounds outstanding buffers);
    /// beyond [`POOL_FREE_CAP`] idle buffers the storage is dropped rather
    /// than retained.
    pub fn put(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut st = self.inner.lock().unwrap();
        st.puts += 1;
        if st.free.len() < POOL_FREE_CAP {
            st.free.push(v);
        }
    }

    pub fn stats(&self) -> PoolStats {
        let st = self.inner.lock().unwrap();
        PoolStats { allocs: st.allocs, takes: st.takes, puts: st.puts, free: st.free.len() }
    }
}

/// A payload buffer that knows its home pool: when the last owner drops
/// it — on whichever thread that happens — the storage returns to the
/// pool it came from. Buffers created with [`PoolVec::unpooled`] simply
/// deallocate.
pub struct PoolVec {
    data: Vec<f32>,
    home: Option<BufferPool>,
}

impl PoolVec {
    /// A buffer with no home pool (plain heap lifetime).
    pub fn unpooled(data: Vec<f32>) -> PoolVec {
        PoolVec { data, home: None }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Extract the storage, detaching it from the pool (used to hand a
    /// result to the application as a plain `Vec`).
    pub fn into_data(mut self) -> Vec<f32> {
        self.home = None;
        std::mem::take(&mut self.data)
    }
}

impl Drop for PoolVec {
    fn drop(&mut self) {
        if let Some(home) = self.home.take() {
            home.put(std::mem::take(&mut self.data));
        }
    }
}

impl Deref for PoolVec {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl fmt::Debug for PoolVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "PoolVec(len {}, pooled {})", self.data.len(), self.home.is_some())
    }
}

/// Refcounted payload storage shared between sender and receiver(s).
pub type SharedBuf = Arc<PoolVec>;

/// Wrap a plain vector as a sharable buffer with no pool affinity.
pub fn shared(data: Vec<f32>) -> SharedBuf {
    Arc::new(PoolVec::unpooled(data))
}

/// A view of (a range of) a [`SharedBuf`] — the unit of data transfer.
/// Cloning or sending a chunk is a refcount bump; no payload bytes move.
#[derive(Clone)]
pub struct Chunk {
    buf: SharedBuf,
    lo: usize,
    hi: usize,
}

impl Chunk {
    /// View of the whole buffer.
    pub fn full(buf: SharedBuf) -> Chunk {
        let hi = buf.len();
        Chunk { buf, lo: 0, hi }
    }

    /// View of `buf[lo..hi]`.
    pub fn range(buf: SharedBuf, lo: usize, hi: usize) -> Chunk {
        assert!(lo <= hi && hi <= buf.len(), "chunk range {lo}..{hi} of {}", buf.len());
        Chunk { buf, lo, hi }
    }

    /// Freshly-owned full view of `data` (no extra copy: the vector moves
    /// into the shared allocation's header).
    pub fn from_vec(data: Vec<f32>) -> Chunk {
        Chunk::full(shared(data))
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf.as_slice()[self.lo..self.hi]
    }

    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    /// Owned vector of the viewed contents. Zero-copy when this is the
    /// sole reference to a full-range buffer; otherwise one memcpy, which
    /// the caller should record in [`Endpoint::copied_bytes`].
    pub fn into_vec(self) -> Vec<f32> {
        if self.lo == 0 && self.hi == self.buf.len() {
            match Arc::try_unwrap(self.buf) {
                Ok(pv) => return pv.into_data(),
                Err(shared) => return shared.as_slice().to_vec(),
            }
        }
        self.as_slice().to_vec()
    }
}

impl Deref for Chunk {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl fmt::Debug for Chunk {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Chunk({}..{} of {})", self.lo, self.hi, self.buf.len())
    }
}

impl PartialEq for Chunk {
    fn eq(&self, other: &Chunk) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f32>> for Chunk {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for Chunk {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

// ---------------------------------------------------------------------------
// Messages
// ---------------------------------------------------------------------------

/// Control payloads. Bulk data travels separately as tagged [`Chunk`]s;
/// control messages are matched by arrival, not by tag.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Collective activation (paper §III-A1): `root` is the activator whose
    /// binomial tree this message travels down; `version` names the
    /// collective instance being triggered.
    Activation { root: usize, version: u64 },
    /// Majority-mode arrival notice (paper §VI / eager-SGD): sent to the
    /// version leader, which activates once a quorum has arrived.
    Arrival { version: u64 },
    /// Application thread → its own engine: request active participation in
    /// group collective `version`.
    AppGroup { version: u64 },
    /// Application thread → its own engine: run the global synchronous
    /// allreduce for iteration `version` (the every-τ model synchronization).
    AppSync { version: u64 },
    /// Death notice: `rank` has fail-stopped and will send nothing more.
    /// Broadcast once by a crashing rank's engine (fault injection) so
    /// peers can mark it dead without burning a detection deadline.
    Dead { rank: usize },
    /// Tear down the engine loop.
    Quit,
}

/// A control message in flight.
#[derive(Debug, Clone)]
pub struct Message {
    pub src: usize,
    pub tag: Tag,
    pub payload: Payload,
}

// ---------------------------------------------------------------------------
// Per-peer mailbox lanes
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Lane {
    data: VecDeque<(Tag, Stamp, Chunk)>,
    ctrl: VecDeque<Message>,
}

/// One rank's inbox: a lane per sending peer, plus a wake channel used
/// only while a receiver is blocked.
struct MailboxShared {
    lanes: Vec<Mutex<Lane>>,
    /// Total queued control messages across all lanes (fast-path gate: a
    /// matched receive only scans the control lanes when this is nonzero).
    ctrl_pending: AtomicUsize,
    /// Receivers currently (about to be) blocked; senders skip the wake
    /// lock entirely while this is zero.
    waiters: AtomicUsize,
    /// Pure lock-pairing state for the condvar: waiters re-attempt their
    /// pop under this lock and notifiers acquire it before signalling, so
    /// a push can never slip between a re-attempt and the wait.
    wake: Mutex<()>,
    cv: Condvar,
}

impl MailboxShared {
    fn new(p: usize) -> MailboxShared {
        MailboxShared {
            lanes: (0..p).map(|_| Mutex::new(Lane::default())).collect(),
            ctrl_pending: AtomicUsize::new(0),
            waiters: AtomicUsize::new(0),
            wake: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    fn notify(&self) {
        if self.waiters.load(Ordering::SeqCst) > 0 {
            // Acquire/release the wake lock so this notify cannot land in
            // the gap between a waiter's re-attempt and its wait.
            drop(self.wake.lock().unwrap());
            self.cv.notify_all();
        }
    }

    fn push_data(&self, src: usize, tag: Tag, stamp: Stamp, chunk: Chunk) {
        self.lanes[src].lock().unwrap().data.push_back((tag, stamp, chunk));
        self.notify();
    }

    fn push_ctrl(&self, src: usize, msg: Message) {
        // Increment BEFORE the push so `ctrl_pending` always over-counts,
        // never under-counts: a scanner that pops an as-yet-uncounted
        // message must not decrement on behalf of a different queued one
        // (which would make that message invisible forever). A transient
        // over-count only costs one extra scan.
        self.ctrl_pending.fetch_add(1, Ordering::SeqCst);
        self.lanes[src].lock().unwrap().ctrl.push_back(msg);
        self.notify();
    }

    fn try_pop_data(&self, src: usize, tag: Tag) -> Option<(Stamp, Chunk)> {
        let mut lane = self.lanes[src].lock().unwrap();
        let pos = lane.data.iter().position(|(t, _, _)| *t == tag)?;
        lane.data.remove(pos).map(|(_, st, c)| (st, c))
    }

    fn try_pop_ctrl(&self) -> Option<Message> {
        if self.ctrl_pending.load(Ordering::SeqCst) == 0 {
            return None;
        }
        for lane in &self.lanes {
            let mut l = lane.lock().unwrap();
            if let Some(m) = l.ctrl.pop_front() {
                self.ctrl_pending.fetch_sub(1, Ordering::SeqCst);
                return Some(m);
            }
        }
        None
    }

    fn pending_data(&self) -> usize {
        self.lanes.iter().map(|l| l.lock().unwrap().data.len()).sum()
    }

    /// One parked wait round of a blocking receive: register as a waiter,
    /// re-run the actual pop under the wake lock, and park on the condvar
    /// if it still comes up empty. Missed-wakeup-safe: a push completing
    /// before our registration is found by the re-attempt; one completing
    /// after it sees `waiters != 0` and must take the wake lock to notify,
    /// which it cannot do between our re-attempt and the wait. Re-running
    /// the pop itself (rather than a cheap readiness predicate) means a
    /// transiently over-counting `ctrl_pending` parks here instead of
    /// spinning until the preempted sender finishes its push.
    fn wait_round<T>(&self, mut attempt: impl FnMut(&Self) -> Option<T>) -> Option<T> {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let guard = self.wake.lock().unwrap();
        let got = attempt(self);
        if got.is_none() {
            let guard = self.cv.wait(guard).unwrap();
            drop(guard);
        } else {
            drop(guard);
        }
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        got
    }

    /// [`MailboxShared::wait_round`] with a deadline: parks at most until
    /// `deadline` (via `Condvar::wait_timeout`). The missed-wakeup
    /// argument is unchanged — a timeout-expired return simply hands
    /// control back to the caller's retry loop, which re-attempts once
    /// more before declaring the deadline missed.
    fn wait_round_deadline<T>(
        &self,
        deadline: Instant,
        mut attempt: impl FnMut(&Self) -> Option<T>,
    ) -> Option<T> {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let guard = self.wake.lock().unwrap();
        let got = attempt(self);
        if got.is_none() {
            let now = Instant::now();
            if now < deadline {
                let (guard, _timed_out) = self.cv.wait_timeout(guard, deadline - now).unwrap();
                drop(guard);
            } else {
                drop(guard);
            }
        } else {
            drop(guard);
        }
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        got
    }

    /// Non-blocking matched receive. Pending control traffic is drained
    /// before data — activations and app requests must never queue behind
    /// bulk payloads (the old single-FIFO delivered them in arrival order;
    /// control-first is the conservative refinement).
    fn try_recv_matched(&self, src: usize, tag: Tag) -> Option<Result<(Stamp, Chunk), Message>> {
        if let Some(m) = self.try_pop_ctrl() {
            return Some(Err(m));
        }
        self.try_pop_data(src, tag).map(Ok)
    }

    /// Blocking: the data message matching `(src, tag)` (`Ok`), or any
    /// control message (`Err`) so the caller can service it and retry.
    fn recv_data_or_ctrl_blocking(&self, src: usize, tag: Tag) -> Result<(Stamp, Chunk), Message> {
        loop {
            if let Some(r) = self.try_recv_matched(src, tag) {
                return r;
            }
            if let Some(r) = self.wait_round(|s| s.try_recv_matched(src, tag)) {
                return r;
            }
        }
    }

    /// Blocking receive of the next control message (engine idle loop).
    fn recv_ctrl_blocking(&self) -> Message {
        loop {
            if let Some(m) = self.try_pop_ctrl() {
                return m;
            }
            if let Some(m) = self.wait_round(|s| s.try_pop_ctrl()) {
                return m;
            }
        }
    }

    /// [`MailboxShared::recv_data_or_ctrl_blocking`] bounded by `deadline`.
    fn recv_data_or_ctrl_deadline(
        &self,
        src: usize,
        tag: Tag,
        deadline: Instant,
    ) -> Result<Result<(Stamp, Chunk), Message>, RecvTimeout> {
        loop {
            if let Some(r) = self.try_recv_matched(src, tag) {
                return Ok(r);
            }
            if Instant::now() >= deadline {
                return Err(RecvTimeout::Data { src, tag });
            }
            if let Some(r) = self.wait_round_deadline(deadline, |s| s.try_recv_matched(src, tag)) {
                return Ok(r);
            }
        }
    }

    /// [`MailboxShared::recv_ctrl_blocking`] bounded by `deadline`.
    fn recv_ctrl_deadline(&self, deadline: Instant) -> Result<Message, RecvTimeout> {
        loop {
            if let Some(m) = self.try_pop_ctrl() {
                return Ok(m);
            }
            if Instant::now() >= deadline {
                return Err(RecvTimeout::Ctrl);
            }
            if let Some(m) = self.wait_round_deadline(deadline, |s| s.try_pop_ctrl()) {
                return Ok(m);
            }
        }
    }
}

/// Cloneable handle that injects control messages into one rank's inbox —
/// handed to the application thread so it can signal its engine.
#[derive(Clone)]
pub struct MailboxSender {
    inbox: Arc<MailboxShared>,
    src: usize,
}

impl MailboxSender {
    pub fn send(&self, msg: Message) {
        self.inbox.push_ctrl(self.src, msg);
    }
}

// ---------------------------------------------------------------------------
// Endpoint
// ---------------------------------------------------------------------------

/// Per-rank communication endpoint.
pub struct Endpoint {
    rank: usize,
    p: usize,
    peers: Vec<Arc<MailboxShared>>,
    inbox: Arc<MailboxShared>,
    pool: BufferPool,
    /// Messages delivered, for metrics.
    pub sent_msgs: u64,
    pub sent_bytes: u64,
    /// Payload bytes memcpy'd by this endpoint's owner (sends and receives
    /// themselves are refcount bumps; this counts the residual copies).
    pub copied_bytes: u64,
    /// Causal stamp of the most recent matched data receive; consumed by
    /// [`Endpoint::take_stamp`] so the engine can pin the happens-before
    /// edge on the wait span the receive satisfied.
    last_stamp: Option<Stamp>,
}

/// Build a fully-connected world of `p` endpoints.
pub fn world(p: usize) -> Vec<Endpoint> {
    let shareds: Vec<Arc<MailboxShared>> =
        (0..p).map(|_| Arc::new(MailboxShared::new(p))).collect();
    (0..p)
        .map(|rank| Endpoint {
            rank,
            p,
            peers: shareds.clone(),
            inbox: shareds[rank].clone(),
            pool: BufferPool::new(),
            sent_msgs: 0,
            sent_bytes: 0,
            copied_bytes: 0,
            last_stamp: None,
        })
        .collect()
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn p(&self) -> usize {
        self.p
    }

    /// This endpoint's buffer pool (cloneable handle).
    pub fn pool(&self) -> &BufferPool {
        &self.pool
    }

    /// A sender that delivers into this endpoint's own mailbox — handed to
    /// the application thread so it can signal its engine.
    pub fn self_sender(&self) -> MailboxSender {
        MailboxSender { inbox: self.inbox.clone(), src: self.rank }
    }

    /// Send tagged data to `dst`, taking ownership of the vector (it moves
    /// into a shared buffer; no payload copy). Never blocks.
    pub fn send(&mut self, dst: usize, tag: Tag, data: Vec<f32>) {
        self.send_chunk(dst, tag, Chunk::from_vec(data));
    }

    /// Send a chunk (refcount bump) to `dst`. Never blocks. The message
    /// header carries a causal [`Stamp`] naming the producing span.
    pub fn send_chunk(&mut self, dst: usize, tag: Tag, chunk: Chunk) {
        self.sent_msgs += 1;
        self.sent_bytes += (chunk.len() * 4) as u64;
        let stamp = Stamp {
            src: self.rank as u32,
            version: tag.version,
            phase: tag.phase,
            send_ns: crate::trace::now_ns(),
        };
        self.peers[dst].push_data(self.rank, tag, stamp, chunk);
    }

    /// Causal stamp of the most recent matched data receive, consuming
    /// it. `None` if no data has arrived since the last call.
    pub fn take_stamp(&mut self) -> Option<Stamp> {
        self.last_stamp.take()
    }

    /// Send a control payload to `dst`.
    pub fn send_ctrl(&mut self, dst: usize, payload: Payload) {
        self.sent_msgs += 1;
        self.peers[dst].push_ctrl(
            self.rank,
            Message { src: self.rank, tag: Tag::exchange(0, 0), payload },
        );
    }

    /// Blocking receive of the data message matching `(src, tag)`.
    /// Non-matching data stays queued in its sender's lane; control
    /// messages are handed to `on_ctrl` as they arrive (the engine
    /// forwards activations inline from here so tree broadcasts never
    /// stall behind a busy schedule).
    pub fn recv_data(
        &mut self,
        src: usize,
        tag: Tag,
        mut on_ctrl: impl FnMut(&mut Self, Message),
    ) -> Chunk {
        loop {
            match self.inbox.recv_data_or_ctrl_blocking(src, tag) {
                Ok((stamp, chunk)) => {
                    self.last_stamp = Some(stamp);
                    return chunk;
                }
                Err(msg) => on_ctrl(self, msg),
            }
        }
    }

    /// Matched receive that yields to the caller whenever a control message
    /// arrives instead of blocking through it: returns `Some(chunk)` when
    /// the `(src, tag)` data message is available, or pushes exactly one
    /// control message into `ctrl` and returns `None` so the caller can
    /// service it (activation forwarding) and call again.
    pub fn recv_data_or_ctrl(
        &mut self,
        src: usize,
        tag: Tag,
        ctrl: &mut Vec<Message>,
    ) -> Option<Chunk> {
        match self.inbox.recv_data_or_ctrl_blocking(src, tag) {
            Ok((stamp, chunk)) => {
                self.last_stamp = Some(stamp);
                Some(chunk)
            }
            Err(msg) => {
                ctrl.push(msg);
                None
            }
        }
    }

    /// Deadline-bounded matched receive: like [`Endpoint::recv_data`], but
    /// gives up with a typed [`RecvTimeout`] if `(src, tag)` has not
    /// arrived by `deadline`. A peer that never sends can no longer hang
    /// the calling thread forever — the engine's degraded exchange paths
    /// build on this.
    pub fn recv_deadline(
        &mut self,
        src: usize,
        tag: Tag,
        deadline: Instant,
        mut on_ctrl: impl FnMut(&mut Self, Message),
    ) -> Result<Chunk, RecvTimeout> {
        loop {
            match self.inbox.recv_data_or_ctrl_deadline(src, tag, deadline)? {
                Ok((stamp, chunk)) => {
                    self.last_stamp = Some(stamp);
                    return Ok(chunk);
                }
                Err(msg) => on_ctrl(self, msg),
            }
        }
    }

    /// Deadline-bounded form of [`Endpoint::recv_data_or_ctrl`]: yields
    /// `Ok(Some(chunk))` on a match, `Ok(None)` after pushing exactly one
    /// control message into `ctrl`, or `Err(RecvTimeout)` once `deadline`
    /// passes with neither.
    pub fn recv_data_or_ctrl_deadline(
        &mut self,
        src: usize,
        tag: Tag,
        deadline: Instant,
        ctrl: &mut Vec<Message>,
    ) -> Result<Option<Chunk>, RecvTimeout> {
        match self.inbox.recv_data_or_ctrl_deadline(src, tag, deadline)? {
            Ok((stamp, chunk)) => {
                self.last_stamp = Some(stamp);
                Ok(Some(chunk))
            }
            Err(msg) => {
                ctrl.push(msg);
                Ok(None)
            }
        }
    }

    /// Blocking receive of the next control message (engine idle loop).
    /// Data messages are untouched: they wait in their lanes for the
    /// matched receive of the schedule that wants them.
    pub fn recv_ctrl(&mut self) -> Message {
        self.inbox.recv_ctrl_blocking()
    }

    /// Deadline-bounded form of [`Endpoint::recv_ctrl`].
    pub fn recv_ctrl_deadline(&mut self, deadline: Instant) -> Result<Message, RecvTimeout> {
        self.inbox.recv_ctrl_deadline(deadline)
    }

    /// Non-blocking receive of a control message.
    pub fn try_recv_ctrl(&mut self) -> Option<Message> {
        self.inbox.try_pop_ctrl()
    }

    /// Symmetric exchange with `partner`: send our buffer, receive theirs.
    /// The building block of butterfly phases in *direct* (engine-less)
    /// mode, used by the synchronous baselines.
    pub fn sendrecv(&mut self, partner: usize, tag: Tag, data: Vec<f32>) -> Vec<f32> {
        self.send(partner, tag, data);
        let chunk = self.recv_data(partner, tag, |_, m| {
            panic!("unexpected control message in direct mode: {m:?}")
        });
        chunk.into_vec()
    }

    /// Number of data messages received but not yet consumed by a matched
    /// receive (test/debug hook: a clean shutdown should leave zero for
    /// protocols that consume all traffic).
    pub fn unmatched_len(&self) -> usize {
        self.inbox.pending_data()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;
    use std::thread;

    #[test]
    fn tag_matching_out_of_order() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            // Send phase 1 before phase 0; receiver asks for 0 first.
            e1.send(0, Tag::exchange(7, 1), vec![2.0]);
            e1.send(0, Tag::exchange(7, 0), vec![1.0]);
            e1
        });
        let a = e0.recv_data(1, Tag::exchange(7, 0), |_, _| {});
        let b = e0.recv_data(1, Tag::exchange(7, 1), |_, _| {});
        assert_eq!(a, vec![1.0]);
        assert_eq!(b, vec![2.0]);
        assert_eq!(e0.unmatched_len(), 0);
        h.join().unwrap();
    }

    #[test]
    fn sendrecv_pairs() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || e1.sendrecv(0, Tag::sync(0, 0), vec![10.0, 20.0]));
        let got0 = e0.sendrecv(1, Tag::sync(0, 0), vec![1.0, 2.0]);
        let got1 = h.join().unwrap();
        assert_eq!(got0, vec![10.0, 20.0]);
        assert_eq!(got1, vec![1.0, 2.0]);
    }

    #[test]
    fn ctrl_messages_reach_handler() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            e1.send_ctrl(0, Payload::Activation { root: 1, version: 3 });
            e1.send(0, Tag::exchange(3, 0), vec![5.0]);
            e1
        });
        let mut acts = Vec::new();
        let data = e0.recv_data(1, Tag::exchange(3, 0), |_, m| {
            if let Payload::Activation { root, version } = m.payload {
                acts.push((root, version));
            }
        });
        assert_eq!(data, vec![5.0]);
        assert_eq!(acts, vec![(1, 3)]);
        h.join().unwrap();
    }

    #[test]
    fn self_sender_delivers() {
        let mut eps = world(1);
        let mut e0 = eps.pop().unwrap();
        let tx = e0.self_sender();
        tx.send(Message {
            src: 0,
            tag: Tag::exchange(0, 0),
            payload: Payload::AppGroup { version: 9 },
        });
        match e0.recv_ctrl().payload {
            Payload::AppGroup { version } => assert_eq!(version, 9),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn byte_accounting() {
        let mut eps = world(2);
        let mut e0 = eps.remove(0);
        e0.send(1, Tag::p2p(0, 0), vec![0.0; 100]);
        assert_eq!(e0.sent_bytes, 400);
        assert_eq!(e0.sent_msgs, 1);
    }

    #[test]
    fn receives_surface_the_causal_stamp() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        assert_eq!(e0.take_stamp(), None);
        let h = thread::spawn(move || {
            e1.send(0, Tag::exchange(6, 2), vec![1.0]);
        });
        let _ = e0.recv_data(1, Tag::exchange(6, 2), |_, _| {});
        let st = e0.take_stamp().expect("matched receive records a stamp");
        assert_eq!((st.src, st.version, st.phase), (1, 6, 2));
        assert!(st.send_ns > 0);
        // Consumed: a second take is empty until the next receive.
        assert_eq!(e0.take_stamp(), None);
        h.join().unwrap();
    }

    #[test]
    fn chunk_views_share_storage_without_copying() {
        let buf = shared((0..10).map(|i| i as f32).collect());
        let a = Chunk::range(buf.clone(), 0, 4);
        let b = Chunk::range(buf.clone(), 4, 10);
        assert_eq!(a.len(), 4);
        assert_eq!(&a[..], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&b[..], &[4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        assert_eq!(Arc::strong_count(&buf), 3);
        // Full-range sole-owner extraction is a move, not a copy.
        drop((a, b));
        let c = Chunk::full(buf);
        let v = c.into_vec();
        assert_eq!(v.len(), 10);
    }

    #[test]
    fn chunked_send_is_refcounted_views() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            let buf = shared(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
            e1.send_chunk(0, Tag::exchange(0, 0), Chunk::range(buf.clone(), 0, 3));
            e1.send_chunk(0, Tag::exchange(0, 1), Chunk::range(buf.clone(), 3, 5));
            e1.sent_bytes
        });
        let c0 = e0.recv_data(1, Tag::exchange(0, 0), |_, _| {});
        let c1 = e0.recv_data(1, Tag::exchange(0, 1), |_, _| {});
        assert_eq!(&c0[..], &[1.0, 2.0, 3.0]);
        assert_eq!(&c1[..], &[4.0, 5.0]);
        assert_eq!(h.join().unwrap(), 20);
    }

    #[test]
    fn pool_recycles_buffers_across_threads() {
        let pool = BufferPool::new();
        let a = pool.take(16);
        let b = pool.take(16);
        assert_eq!(pool.stats().allocs, 2);
        // Drop on another thread still returns home.
        let pa = Arc::new(a);
        let h = {
            let pa = pa.clone();
            thread::spawn(move || drop(pa))
        };
        h.join().unwrap();
        drop(pa);
        drop(b);
        let st = pool.stats();
        assert_eq!(st.allocs, 2);
        assert_eq!(st.free, 2);
        // Subsequent takes are pool hits.
        let c = pool.take(8);
        assert_eq!(c.len(), 8);
        assert_eq!(pool.stats().allocs, 2);
    }

    #[test]
    fn pool_detach_via_into_data() {
        let pool = BufferPool::new();
        let v = pool.take(4).into_data();
        assert_eq!(v.len(), 4);
        drop(v);
        // Detached buffers never return.
        assert_eq!(pool.stats().free, 0);
    }

    #[test]
    fn recv_deadline_times_out_with_typed_error() {
        let mut eps = world(2);
        let mut e0 = eps.remove(0);
        let tag = Tag::exchange(5, 0);
        let t0 = Instant::now();
        let deadline = t0 + std::time::Duration::from_millis(30);
        let err = e0.recv_deadline(1, tag, deadline, |_, _| {}).unwrap_err();
        assert_eq!(err, RecvTimeout::Data { src: 1, tag });
        let waited = t0.elapsed();
        assert!(waited >= std::time::Duration::from_millis(30), "returned early: {waited:?}");
        assert!(waited < std::time::Duration::from_secs(5), "hung: {waited:?}");
        // The error is a real `std::error::Error` with a useful message.
        let msg = err.to_string();
        assert!(msg.contains("rank 1"), "{msg}");
    }

    #[test]
    fn recv_deadline_returns_data_sent_before_deadline() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            e1.send(0, Tag::sync(2, 0), vec![7.0]);
        });
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        let got = e0.recv_deadline(1, Tag::sync(2, 0), deadline, |_, _| {}).unwrap();
        assert_eq!(got, vec![7.0]);
        h.join().unwrap();
    }

    #[test]
    fn recv_deadline_still_services_ctrl_traffic() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            e1.send_ctrl(0, Payload::Activation { root: 1, version: 4 });
            e1.send(0, Tag::exchange(4, 0), vec![9.0]);
        });
        let mut acts = Vec::new();
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        let data = e0
            .recv_deadline(1, Tag::exchange(4, 0), deadline, |_, m| {
                if let Payload::Activation { root, version } = m.payload {
                    acts.push((root, version));
                }
            })
            .unwrap();
        assert_eq!(data, vec![9.0]);
        assert_eq!(acts, vec![(1, 4)]);
        h.join().unwrap();
    }

    #[test]
    fn recv_ctrl_deadline_times_out_and_delivers() {
        let mut eps = world(1);
        let mut e0 = eps.pop().unwrap();
        let err =
            e0.recv_ctrl_deadline(Instant::now() + std::time::Duration::from_millis(20));
        assert_eq!(err.unwrap_err(), RecvTimeout::Ctrl);
        let tx = e0.self_sender();
        tx.send(Message {
            src: 0,
            tag: Tag::exchange(0, 0),
            payload: Payload::AppSync { version: 2 },
        });
        let msg = e0
            .recv_ctrl_deadline(Instant::now() + std::time::Duration::from_secs(10))
            .unwrap();
        match msg.payload {
            Payload::AppSync { version } => assert_eq!(version, 2),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn blocked_receiver_wakes_on_late_send() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            e1.send(0, Tag::sync(1, 0), vec![42.0]);
        });
        let got = e0.recv_data(1, Tag::sync(1, 0), |_, _| {});
        assert_eq!(got, vec![42.0]);
        h.join().unwrap();
    }
}
