//! In-process message-passing substrate, standing in for the paper's
//! MPI + fflib stack.
//!
//! Each simulated process ("rank") owns an [`Endpoint`]: a single-consumer
//! mailbox plus senders to every other rank. Messages carry a [`Tag`]
//! (collective kind, version, phase) and are matched MPI-style: a blocking
//! receive for a specific `(source, tag)` buffers any non-matching traffic
//! in an unmatched-message queue so out-of-order arrivals are never lost.
//!
//! Wire substitution note (DESIGN.md §2): the paper runs over Cray Aries
//! with MPI point-to-point; we run over unbounded in-memory channels. The
//! *protocol* content — tags, versions, activation control messages,
//! schedule ordering — is identical; only the transport differs.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};

/// What a message is for. Collective schedules never confuse traffic from
/// different collective families because the kind is part of the match.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgKind {
    /// Butterfly exchange inside a (group) allreduce.
    Exchange,
    /// Global synchronous allreduce phase.
    Sync,
    /// Point-to-point data (gossip baselines: D-PSGD, SGP).
    P2p,
}

/// MPI-style message tag: kind + collective version (training iteration)
/// + phase within the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tag {
    pub kind: MsgKind,
    pub version: u64,
    pub phase: u32,
}

impl Tag {
    pub fn exchange(version: u64, phase: u32) -> Tag {
        Tag { kind: MsgKind::Exchange, version, phase }
    }

    pub fn sync(version: u64, phase: u32) -> Tag {
        Tag { kind: MsgKind::Sync, version, phase }
    }

    pub fn p2p(version: u64, phase: u32) -> Tag {
        Tag { kind: MsgKind::P2p, version, phase }
    }
}

/// Message payloads. Data messages participate in tag matching; control
/// messages are delivered to the endpoint's control handler immediately.
#[derive(Debug, Clone)]
pub enum Payload {
    /// Tagged bulk data (model / gradient vectors).
    Data(Vec<f32>),
    /// Collective activation (paper §III-A1): `root` is the activator whose
    /// binomial tree this message travels down; `version` names the
    /// collective instance being triggered.
    Activation { root: usize, version: u64 },
    /// Majority-mode arrival notice (paper §VI / eager-SGD): sent to the
    /// version leader, which activates once a quorum has arrived.
    Arrival { version: u64 },
    /// Application thread → its own engine: request active participation in
    /// group collective `version`.
    AppGroup { version: u64 },
    /// Application thread → its own engine: run the global synchronous
    /// allreduce for iteration `version` (the every-τ model synchronization).
    AppSync { version: u64 },
    /// Tear down the engine loop.
    Quit,
}

/// A message in flight.
#[derive(Debug, Clone)]
pub struct Message {
    pub src: usize,
    pub tag: Tag,
    pub payload: Payload,
}

/// Per-rank communication endpoint.
pub struct Endpoint {
    rank: usize,
    p: usize,
    txs: Vec<Sender<Message>>,
    rx: Receiver<Message>,
    unmatched: HashMap<(usize, Tag), VecDeque<Vec<f32>>>,
    /// Messages delivered, for metrics.
    pub sent_msgs: u64,
    pub sent_bytes: u64,
}

/// Build a fully-connected world of `p` endpoints.
pub fn world(p: usize) -> Vec<Endpoint> {
    let mut txs = Vec::with_capacity(p);
    let mut rxs = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(rank, rx)| Endpoint {
            rank,
            p,
            txs: txs.clone(),
            rx,
            unmatched: HashMap::new(),
            sent_msgs: 0,
            sent_bytes: 0,
        })
        .collect()
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn p(&self) -> usize {
        self.p
    }

    /// A sender that delivers into this endpoint's own mailbox — handed to
    /// the application thread so it can signal its engine.
    pub fn self_sender(&self) -> Sender<Message> {
        self.txs[self.rank].clone()
    }

    /// Send tagged data to `dst`. Never blocks (unbounded channel); errors
    /// from already-terminated peers are ignored, matching the semantics of
    /// fire-and-forget activation traffic at teardown.
    pub fn send(&mut self, dst: usize, tag: Tag, data: Vec<f32>) {
        self.sent_msgs += 1;
        self.sent_bytes += (data.len() * 4) as u64;
        let _ = self.txs[dst].send(Message { src: self.rank, tag, payload: Payload::Data(data) });
    }

    /// Send a control payload to `dst`.
    pub fn send_ctrl(&mut self, dst: usize, payload: Payload) {
        self.sent_msgs += 1;
        let _ = self.txs[dst].send(Message {
            src: self.rank,
            tag: Tag { kind: MsgKind::Exchange, version: 0, phase: 0 },
            payload,
        });
    }

    /// Blocking receive of the data message matching `(src, tag)`.
    /// Non-matching data is buffered; control messages are handed to
    /// `on_ctrl` as they arrive (the engine forwards activations inline from
    /// here so tree broadcasts never stall behind a busy schedule).
    pub fn recv_data(
        &mut self,
        src: usize,
        tag: Tag,
        mut on_ctrl: impl FnMut(&mut Self, Message),
    ) -> Vec<f32> {
        loop {
            if let Some(q) = self.unmatched.get_mut(&(src, tag)) {
                if let Some(data) = q.pop_front() {
                    if q.is_empty() {
                        self.unmatched.remove(&(src, tag));
                    }
                    return data;
                }
            }
            let msg = self.rx.recv().expect("endpoint mailbox closed while receiving");
            match msg.payload {
                Payload::Data(data) => {
                    if msg.src == src && msg.tag == tag {
                        return data;
                    }
                    self.unmatched.entry((msg.src, msg.tag)).or_default().push_back(data);
                }
                _ => on_ctrl(self, msg),
            }
        }
    }

    /// Insert a data message into the unmatched buffer directly (used by
    /// the engine when its idle loop pulls a data message that a future
    /// matched receive will want).
    pub fn stash(&mut self, src: usize, tag: Tag, data: Vec<f32>) {
        self.unmatched.entry((src, tag)).or_default().push_back(data);
    }

    /// Matched receive that yields to the caller whenever a control message
    /// arrives instead of blocking through it: returns `Some(data)` when the
    /// `(src, tag)` data message is available, or pushes exactly one control
    /// message into `ctrl` and returns `None` so the caller can service it
    /// (activation forwarding) and call again.
    pub fn recv_data_or_ctrl(
        &mut self,
        src: usize,
        tag: Tag,
        ctrl: &mut Vec<Message>,
    ) -> Option<Vec<f32>> {
        loop {
            if let Some(q) = self.unmatched.get_mut(&(src, tag)) {
                if let Some(data) = q.pop_front() {
                    if q.is_empty() {
                        self.unmatched.remove(&(src, tag));
                    }
                    return Some(data);
                }
            }
            let msg = self.rx.recv().expect("endpoint mailbox closed while receiving");
            match msg.payload {
                Payload::Data(data) => {
                    if msg.src == src && msg.tag == tag {
                        return Some(data);
                    }
                    self.unmatched.entry((msg.src, msg.tag)).or_default().push_back(data);
                }
                _ => {
                    ctrl.push(msg);
                    return None;
                }
            }
        }
    }

    /// Blocking receive of any message (engine idle loop).
    pub fn recv_any(&mut self) -> Message {
        // Drain buffered data first? Buffered data was already "received";
        // the engine idle loop only cares about fresh control traffic, and
        // buffered entries stay matched for future recv_data calls.
        self.rx.recv().expect("endpoint mailbox closed")
    }

    /// Non-blocking receive of any message.
    pub fn try_recv_any(&mut self) -> Option<Message> {
        self.rx.try_recv().ok()
    }

    /// Symmetric exchange with `partner`: send our buffer, receive theirs.
    /// The building block of butterfly phases in *direct* (engine-less)
    /// mode, used by the synchronous baselines.
    pub fn sendrecv(&mut self, partner: usize, tag: Tag, data: Vec<f32>) -> Vec<f32> {
        self.send(partner, tag, data);
        self.recv_data(partner, tag, |_, m| {
            panic!("unexpected control message in direct mode: {m:?}")
        })
    }

    /// Number of unmatched buffered messages (test/debug hook: a clean
    /// shutdown should leave zero for protocols that consume all traffic).
    pub fn unmatched_len(&self) -> usize {
        self.unmatched.values().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn tag_matching_out_of_order() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            // Send phase 1 before phase 0; receiver asks for 0 first.
            e1.send(0, Tag::exchange(7, 1), vec![2.0]);
            e1.send(0, Tag::exchange(7, 0), vec![1.0]);
            e1
        });
        let a = e0.recv_data(1, Tag::exchange(7, 0), |_, _| {});
        let b = e0.recv_data(1, Tag::exchange(7, 1), |_, _| {});
        assert_eq!(a, vec![1.0]);
        assert_eq!(b, vec![2.0]);
        assert_eq!(e0.unmatched_len(), 0);
        h.join().unwrap();
    }

    #[test]
    fn sendrecv_pairs() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || e1.sendrecv(0, Tag::sync(0, 0), vec![10.0, 20.0]));
        let got0 = e0.sendrecv(1, Tag::sync(0, 0), vec![1.0, 2.0]);
        let got1 = h.join().unwrap();
        assert_eq!(got0, vec![10.0, 20.0]);
        assert_eq!(got1, vec![1.0, 2.0]);
    }

    #[test]
    fn ctrl_messages_reach_handler() {
        let mut eps = world(2);
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let h = thread::spawn(move || {
            e1.send_ctrl(0, Payload::Activation { root: 1, version: 3 });
            e1.send(0, Tag::exchange(3, 0), vec![5.0]);
            e1
        });
        let mut acts = Vec::new();
        let data = e0.recv_data(1, Tag::exchange(3, 0), |_, m| {
            if let Payload::Activation { root, version } = m.payload {
                acts.push((root, version));
            }
        });
        assert_eq!(data, vec![5.0]);
        assert_eq!(acts, vec![(1, 3)]);
        h.join().unwrap();
    }

    #[test]
    fn self_sender_delivers() {
        let mut eps = world(1);
        let mut e0 = eps.pop().unwrap();
        let tx = e0.self_sender();
        tx.send(Message {
            src: 0,
            tag: Tag::exchange(0, 0),
            payload: Payload::AppGroup { version: 9 },
        })
        .unwrap();
        match e0.recv_any().payload {
            Payload::AppGroup { version } => assert_eq!(version, 9),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn byte_accounting() {
        let mut eps = world(2);
        let mut e0 = eps.remove(0);
        e0.send(1, Tag::p2p(0, 0), vec![0.0; 100]);
        assert_eq!(e0.sent_bytes, 400);
        assert_eq!(e0.sent_msgs, 1);
    }
}
