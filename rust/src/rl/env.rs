//! Procedurally-generated gridworld navigation environment.
//!
//! The agent starts at a random free cell and must reach a random goal
//! cell. Observations (matching the `policy_tiny` artifact's `obs_dim=32`):
//! a 5×5 egocentric obstacle window (25), the normalized goal offset (2),
//! normalized agent position (2), normalized distance-to-goal (1), and
//! remaining-time fraction (1), padded to 32. Actions: N/E/S/W. Reward:
//! +1 at goal (episode ends), -0.01 per step, small shaping on distance.
//! Episodes also end on the step limit — and environment difficulty is
//! randomized per episode, giving the heavy-tailed collection times of
//! Fig. 9.

use crate::util::rng::Xoshiro256;

pub const OBS_DIM: usize = 32;
pub const ACTIONS: usize = 4;

/// One observation vector (length [`OBS_DIM`]).
pub type Observation = Vec<f32>;

/// Result of one environment step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepOutcome {
    pub reward: f32,
    pub done: bool,
    /// True if the episode ended by reaching the goal.
    pub success: bool,
}

/// Gridworld with per-episode procedural generation.
pub struct GridWorld {
    rng: Xoshiro256,
    size: usize,
    grid: Vec<bool>, // true = obstacle
    agent: (usize, usize),
    goal: (usize, usize),
    steps: usize,
    max_steps: usize,
    /// Initial Manhattan distance (for SPL-style scoring).
    init_dist: usize,
}

impl GridWorld {
    pub fn new(seed: u64) -> GridWorld {
        let mut w = GridWorld {
            rng: Xoshiro256::seed_from_u64(seed),
            size: 0,
            grid: Vec::new(),
            agent: (0, 0),
            goal: (0, 0),
            steps: 0,
            max_steps: 0,
            init_dist: 0,
        };
        w.reset();
        w
    }

    /// Start a new episode with freshly-generated difficulty.
    pub fn reset(&mut self) -> Observation {
        // Difficulty knobs: size 6..16, obstacle density 0..0.35.
        self.size = 6 + self.rng.usize_below(11);
        let density = self.rng.next_f64() * 0.35;
        self.grid = (0..self.size * self.size)
            .map(|_| self.rng.next_f64() < density)
            .collect();
        self.agent = self.random_free_cell();
        loop {
            self.goal = self.random_free_cell();
            if self.goal != self.agent {
                break;
            }
        }
        self.steps = 0;
        self.max_steps = self.size * self.size; // harder rooms run longer
        self.init_dist = self.manhattan();
        self.observe()
    }

    fn random_free_cell(&mut self) -> (usize, usize) {
        loop {
            let x = self.rng.usize_below(self.size);
            let y = self.rng.usize_below(self.size);
            if !self.grid[y * self.size + x] {
                return (x, y);
            }
        }
    }

    fn manhattan(&self) -> usize {
        self.agent.0.abs_diff(self.goal.0) + self.agent.1.abs_diff(self.goal.1)
    }

    fn occupied(&self, x: isize, y: isize) -> bool {
        if x < 0 || y < 0 || x >= self.size as isize || y >= self.size as isize {
            return true;
        }
        self.grid[y as usize * self.size + x as usize]
    }

    /// Current observation vector.
    pub fn observe(&self) -> Observation {
        let mut obs = Vec::with_capacity(OBS_DIM);
        let (ax, ay) = (self.agent.0 as isize, self.agent.1 as isize);
        for dy in -2..=2isize {
            for dx in -2..=2isize {
                obs.push(if self.occupied(ax + dx, ay + dy) { 1.0 } else { 0.0 });
            }
        }
        let s = self.size as f32;
        obs.push((self.goal.0 as f32 - self.agent.0 as f32) / s);
        obs.push((self.goal.1 as f32 - self.agent.1 as f32) / s);
        obs.push(self.agent.0 as f32 / s);
        obs.push(self.agent.1 as f32 / s);
        obs.push(self.manhattan() as f32 / (2.0 * s));
        obs.push(1.0 - self.steps as f32 / self.max_steps as f32);
        debug_assert_eq!(obs.len(), 31);
        obs.push(0.0); // pad to OBS_DIM
        obs
    }

    /// Take action 0..4 (N/E/S/W). Returns the outcome; on `done` the
    /// caller should `reset()`.
    pub fn step(&mut self, action: usize) -> StepOutcome {
        assert!(action < ACTIONS);
        let before = self.manhattan() as f32;
        let (dx, dy) = [(0isize, -1isize), (1, 0), (0, 1), (-1, 0)][action];
        let nx = self.agent.0 as isize + dx;
        let ny = self.agent.1 as isize + dy;
        if !self.occupied(nx, ny) {
            self.agent = (nx as usize, ny as usize);
        }
        self.steps += 1;
        let after = self.manhattan() as f32;
        if self.agent == self.goal {
            return StepOutcome { reward: 1.0, done: true, success: true };
        }
        if self.steps >= self.max_steps {
            return StepOutcome { reward: -0.1, done: true, success: false };
        }
        // Step penalty + dense distance shaping (potential-based, so the
        // optimal policy is unchanged; the density is what makes the task
        // learnable within the small experiment budgets).
        StepOutcome { reward: -0.01 + 0.2 * (before - after), done: false, success: false }
    }

    /// SPL-style score for a finished successful episode: shortest / taken.
    pub fn spl(&self, success: bool) -> f32 {
        if !success {
            return 0.0;
        }
        self.init_dist as f32 / (self.steps.max(self.init_dist) as f32)
    }

    pub fn episode_steps(&self) -> usize {
        self.steps
    }

    pub fn size(&self) -> usize {
        self.size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observation_shape_and_range() {
        let mut w = GridWorld::new(1);
        for _ in 0..20 {
            let obs = w.observe();
            assert_eq!(obs.len(), OBS_DIM);
            assert!(obs.iter().all(|v| v.is_finite() && v.abs() <= 2.0));
            let a = w.rng_action();
            let o = w.step(a);
            if o.done {
                w.reset();
            }
        }
    }

    #[test]
    fn episodes_terminate() {
        let mut w = GridWorld::new(2);
        for _ in 0..50 {
            let mut steps = 0;
            loop {
                let o = w.step(0);
                steps += 1;
                if o.done {
                    break;
                }
                assert!(steps <= 16 * 16 + 1);
            }
            w.reset();
        }
    }

    #[test]
    fn reaching_goal_rewards_and_succeeds() {
        // Drive the agent greedily toward the goal; on clear boards this
        // succeeds often. Check reward signs and SPL in [0, 1].
        let mut w = GridWorld::new(3);
        let mut successes = 0;
        for _ in 0..100 {
            loop {
                let (ax, ay) = w.agent;
                let (gx, gy) = w.goal;
                let action = if gx > ax {
                    1
                } else if gx < ax {
                    3
                } else if gy > ay {
                    2
                } else {
                    0
                };
                let o = w.step(action);
                if o.done {
                    if o.success {
                        successes += 1;
                        assert!(o.reward > 0.9);
                        let spl = w.spl(true);
                        assert!((0.0..=1.0).contains(&spl), "spl {spl}");
                    }
                    w.reset();
                    break;
                }
            }
        }
        assert!(successes > 20, "greedy should succeed sometimes: {successes}");
    }

    #[test]
    fn episode_lengths_are_heavy_tailed() {
        // The Fig. 9 mechanism: random-policy episode lengths vary by >10x.
        let mut w = GridWorld::new(4);
        let mut lens = Vec::new();
        for _ in 0..300 {
            let mut steps = 0;
            loop {
                let a = w.rng_action();
                steps += 1;
                if w.step(a).done {
                    break;
                }
            }
            lens.push(steps as f64);
            w.reset();
        }
        let s = crate::util::stats::Summary::of(&lens);
        // Wide spread: the longest episodes dwarf the shortest quartile,
        // and the distribution is right-skewed (mean > median).
        assert!(s.max / s.p25.max(1.0) > 2.5, "max {} p25 {}", s.max, s.p25);
        assert!(s.max / s.min.max(1.0) > 5.0, "max {} min {}", s.max, s.min);
    }

    impl GridWorld {
        pub(crate) fn rng_action(&mut self) -> usize {
            self.rng.usize_below(ACTIONS)
        }
    }
}
