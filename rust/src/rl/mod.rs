//! Reinforcement-learning substrate: a procedurally-generated gridworld
//! navigation environment (the Habitat analogue) plus PPO rollout
//! machinery driven by the AOT policy artifact.
//!
//! Substitution fidelity (DESIGN.md §2): the paper's RL workload property
//! that matters to WAGMA-SGD is *heavy-tailed experience-collection time*
//! (episodes end early on failure; environments vary in difficulty). Our
//! gridworld reproduces the mechanism: rooms of random size/obstacle
//! density, episode length varies from a handful of steps (adjacent goal /
//! quick failure) to hundreds (hard mazes), so per-iteration collection
//! time is naturally heavy-tailed (validated against Fig. 9's shape in the
//! figure harness).

pub mod env;
pub mod ppo;

pub use env::{GridWorld, Observation, StepOutcome};
pub use ppo::{collect_rollout, gae, PpoBatch, RolloutConfig};
