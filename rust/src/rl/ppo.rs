//! PPO rollout machinery: vectorized environments, GAE advantages, and
//! batch assembly for the `policy_tiny` AOT artifact.
//!
//! The policy is abstracted as a closure `(obs [R, OBS_DIM] row-major, R)
//! -> (logp [R, ACTIONS], value [R])` so the same machinery runs against
//! the PJRT artifact (examples/benches) or a synthetic policy (tests).

use crate::model::{Batch, DataArg};
use crate::rl::env::{GridWorld, ACTIONS, OBS_DIM};
use crate::util::rng::Xoshiro256;

/// Rollout configuration. `envs * horizon` must equal the policy
/// artifact's training batch (256 for `policy_tiny`).
#[derive(Debug, Clone, Copy)]
pub struct RolloutConfig {
    /// Parallel (vectorized) environments per worker.
    pub envs: usize,
    /// Steps collected per environment per iteration.
    pub horizon: usize,
    pub gamma: f32,
    pub lam: f32,
}

impl Default for RolloutConfig {
    fn default() -> RolloutConfig {
        RolloutConfig { envs: 64, horizon: 4, gamma: 0.99, lam: 0.95 }
    }
}

/// Assembled PPO minibatch + rollout statistics.
#[derive(Debug, Clone)]
pub struct PpoBatch {
    /// Training batch in the `policy` artifact's ABI order:
    /// obs, actions, advantages, returns, old log-probs.
    pub batch: Batch,
    /// Mean undiscounted return of episodes finished during collection.
    pub mean_return: f32,
    /// Mean SPL of finished episodes (success weighted by path length).
    pub mean_spl: f32,
    pub episodes_finished: usize,
    /// Environment steps executed (== envs * horizon).
    pub env_steps: usize,
}

/// Generalized advantage estimation over one env's trajectory.
/// `rewards[t]`, `values[t]`, `dones[t]` for t in 0..T, plus the bootstrap
/// value after the last step. Returns (advantages, returns).
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    dones: &[bool],
    bootstrap: f32,
    gamma: f32,
    lam: f32,
) -> (Vec<f32>, Vec<f32>) {
    let t_max = rewards.len();
    let mut adv = vec![0.0f32; t_max];
    let mut last = 0.0f32;
    for t in (0..t_max).rev() {
        let next_value = if t + 1 < t_max { values[t + 1] } else { bootstrap };
        let nonterminal = if dones[t] { 0.0 } else { 1.0 };
        let delta = rewards[t] + gamma * next_value * nonterminal - values[t];
        last = delta + gamma * lam * nonterminal * last;
        adv[t] = last;
    }
    let ret: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, ret)
}

/// Collect one rollout with `policy` over persistent `envs`, tracking
/// per-env episode returns in `ep_returns` across calls.
pub fn collect_rollout(
    policy: &mut dyn FnMut(&[f32], usize) -> (Vec<f32>, Vec<f32>),
    envs: &mut [GridWorld],
    ep_returns: &mut [f32],
    cfg: &RolloutConfig,
    rng: &mut Xoshiro256,
) -> PpoBatch {
    let e = cfg.envs;
    let t_max = cfg.horizon;
    assert_eq!(envs.len(), e);
    assert_eq!(ep_returns.len(), e);

    let mut obs_t: Vec<Vec<f32>> = Vec::with_capacity(t_max); // [T][E*OBS]
    let mut act_t: Vec<Vec<i32>> = Vec::with_capacity(t_max);
    let mut logp_t: Vec<Vec<f32>> = Vec::with_capacity(t_max);
    let mut val_t: Vec<Vec<f32>> = Vec::with_capacity(t_max);
    let mut rew_t: Vec<Vec<f32>> = Vec::with_capacity(t_max);
    let mut done_t: Vec<Vec<bool>> = Vec::with_capacity(t_max);

    let mut finished_returns: Vec<f32> = Vec::new();
    let mut finished_spl: Vec<f32> = Vec::new();

    for _ in 0..t_max {
        let mut obs = Vec::with_capacity(e * OBS_DIM);
        for env in envs.iter() {
            obs.extend(env.observe());
        }
        let (logp, value) = policy(&obs, e);
        debug_assert_eq!(logp.len(), e * ACTIONS);
        debug_assert_eq!(value.len(), e);

        let mut actions = Vec::with_capacity(e);
        let mut chosen_logp = Vec::with_capacity(e);
        let mut rewards = Vec::with_capacity(e);
        let mut dones = Vec::with_capacity(e);
        for (i, env) in envs.iter_mut().enumerate() {
            let row = &logp[i * ACTIONS..(i + 1) * ACTIONS];
            let a = sample_categorical(row, rng);
            let outcome = env.step(a);
            ep_returns[i] += outcome.reward;
            actions.push(a as i32);
            chosen_logp.push(row[a]);
            rewards.push(outcome.reward);
            dones.push(outcome.done);
            if outcome.done {
                finished_returns.push(ep_returns[i]);
                finished_spl.push(env.spl(outcome.success));
                ep_returns[i] = 0.0;
                env.reset();
            }
        }
        obs_t.push(obs);
        act_t.push(actions);
        logp_t.push(chosen_logp);
        val_t.push(value);
        rew_t.push(rewards);
        done_t.push(dones);
    }

    // Bootstrap values at the post-rollout observations.
    let mut final_obs = Vec::with_capacity(e * OBS_DIM);
    for env in envs.iter() {
        final_obs.extend(env.observe());
    }
    let (_, bootstrap) = policy(&final_obs, e);

    // Per-env GAE, then flatten [T, E] -> [T*E] (row-major by time).
    let mut adv_flat = vec![0.0f32; t_max * e];
    let mut ret_flat = vec![0.0f32; t_max * e];
    for i in 0..e {
        let rewards: Vec<f32> = (0..t_max).map(|t| rew_t[t][i]).collect();
        let values: Vec<f32> = (0..t_max).map(|t| val_t[t][i]).collect();
        let dones: Vec<bool> = (0..t_max).map(|t| done_t[t][i]).collect();
        let (adv, ret) = gae(&rewards, &values, &dones, bootstrap[i], cfg.gamma, cfg.lam);
        for t in 0..t_max {
            adv_flat[t * e + i] = adv[t];
            ret_flat[t * e + i] = ret[t];
        }
    }
    // Normalize advantages (standard PPO practice; keeps the surrogate
    // scale stable across heterogeneous episodes).
    normalize(&mut adv_flat);

    let n = t_max * e;
    let mut obs_flat = Vec::with_capacity(n * OBS_DIM);
    let mut act_flat = Vec::with_capacity(n);
    let mut logp_flat = Vec::with_capacity(n);
    for t in 0..t_max {
        obs_flat.extend_from_slice(&obs_t[t]);
        act_flat.extend(&act_t[t]);
        logp_flat.extend(&logp_t[t]);
    }

    let mean = |v: &[f32]| if v.is_empty() { 0.0 } else { v.iter().sum::<f32>() / v.len() as f32 };
    PpoBatch {
        batch: Batch::new(vec![
            DataArg::f32(vec![n, OBS_DIM], obs_flat),
            DataArg::i32(vec![n], act_flat),
            DataArg::f32(vec![n], adv_flat),
            DataArg::f32(vec![n], ret_flat),
            DataArg::f32(vec![n], logp_flat),
        ]),
        mean_return: mean(&finished_returns),
        mean_spl: mean(&finished_spl),
        episodes_finished: finished_returns.len(),
        env_steps: n,
    }
}

/// Sample from a categorical given log-probs.
fn sample_categorical(logp: &[f32], rng: &mut Xoshiro256) -> usize {
    let u = rng.next_f32();
    let mut acc = 0.0f32;
    for (i, &lp) in logp.iter().enumerate() {
        acc += lp.exp();
        if u < acc {
            return i;
        }
    }
    logp.len() - 1
}

fn normalize(xs: &mut [f32]) {
    let n = xs.len() as f32;
    let mean: f32 = xs.iter().sum::<f32>() / n;
    let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    for x in xs.iter_mut() {
        *x = (*x - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gae_matches_hand_computation() {
        // Single transition, terminal: adv = r - v.
        let (adv, ret) = gae(&[1.0], &[0.4], &[true], 9.9, 0.99, 0.95);
        assert!((adv[0] - 0.6).abs() < 1e-6);
        assert!((ret[0] - 1.0).abs() < 1e-6);
        // Two steps, no terminal, gamma=1, lam=1: adv0 = r0 + r1 + boot - v0.
        let (adv, _) = gae(&[0.5, 0.5], &[0.0, 0.0], &[false, false], 2.0, 1.0, 1.0);
        assert!((adv[0] - 3.0).abs() < 1e-6);
        assert!((adv[1] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn gae_resets_at_done() {
        // A done at t=0 must stop credit flowing from t=1.
        let (adv, _) = gae(&[1.0, 100.0], &[0.0, 0.0], &[true, false], 50.0, 0.99, 0.95);
        assert!((adv[0] - 1.0).abs() < 1e-6, "no bootstrap across done: {}", adv[0]);
    }

    fn uniform_policy() -> impl FnMut(&[f32], usize) -> (Vec<f32>, Vec<f32>) {
        |_obs: &[f32], rows: usize| {
            let lp = (0.25f32).ln();
            (vec![lp; rows * ACTIONS], vec![0.0; rows])
        }
    }

    #[test]
    fn rollout_batch_shapes() {
        let cfg = RolloutConfig { envs: 8, horizon: 4, ..Default::default() };
        let mut envs: Vec<GridWorld> = (0..8).map(|i| GridWorld::new(100 + i)).collect();
        let mut ep_ret = vec![0.0; 8];
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut pol = uniform_policy();
        let pb = collect_rollout(&mut pol, &mut envs, &mut ep_ret, &cfg, &mut rng);
        assert_eq!(pb.env_steps, 32);
        assert_eq!(pb.batch.args[0].shape(), &[32, OBS_DIM]);
        assert_eq!(pb.batch.args[1].shape(), &[32]);
        // Advantages are normalized: mean ~ 0, std ~ 1.
        if let DataArg::F32 { values, .. } = &pb.batch.args[2] {
            let mean: f32 = values.iter().sum::<f32>() / values.len() as f32;
            assert!(mean.abs() < 1e-4, "adv mean {mean}");
        }
        // old_logp = ln(0.25) everywhere under the uniform policy.
        if let DataArg::F32 { values, .. } = &pb.batch.args[4] {
            assert!(values.iter().all(|v| (v - 0.25f32.ln()).abs() < 1e-6));
        }
    }

    #[test]
    fn episode_stats_accumulate_across_rollouts() {
        let cfg = RolloutConfig { envs: 4, horizon: 16, ..Default::default() };
        let mut envs: Vec<GridWorld> = (0..4).map(|i| GridWorld::new(i)).collect();
        let mut ep_ret = vec![0.0; 4];
        let mut rng = Xoshiro256::seed_from_u64(6);
        let mut pol = uniform_policy();
        let mut total_eps = 0;
        for _ in 0..20 {
            let pb = collect_rollout(&mut pol, &mut envs, &mut ep_ret, &cfg, &mut rng);
            total_eps += pb.episodes_finished;
            assert!(pb.mean_spl >= 0.0 && pb.mean_spl <= 1.0);
        }
        assert!(total_eps > 0, "random policy should finish some episodes");
    }

    #[test]
    fn categorical_sampler_respects_distribution() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        // p = [0.7, 0.1, 0.1, 0.1]
        let logp: Vec<f32> = [0.7f32, 0.1, 0.1, 0.1].iter().map(|p| p.ln()).collect();
        let mut counts = [0usize; 4];
        for _ in 0..10_000 {
            counts[sample_categorical(&logp, &mut rng)] += 1;
        }
        assert!(counts[0] > 6_500 && counts[0] < 7_500, "{counts:?}");
    }
}
