//! Training metrics: per-rank step records, merged run summaries, and
//! CSV/JSON emitters for the figure harnesses.

use std::io::Write;
use std::path::Path;

use crate::trace::TraceEvent;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats::Summary;

/// One training step as observed by one rank.
#[derive(Debug, Clone, PartialEq)]
pub struct StepRecord {
    pub t: u64,
    pub loss: f32,
    /// Wall-clock seconds spent in this iteration (compute + comm).
    pub wall: f64,
    /// Staleness of this rank's contribution (WAGMA/eager only; 0 = fresh).
    pub staleness: u64,
}

/// Everything one rank reports at the end of a run.
#[derive(Debug, Clone, Default)]
pub struct RankMetrics {
    pub rank: usize,
    pub steps: Vec<StepRecord>,
    pub total_seconds: f64,
    pub sent_msgs: u64,
    pub sent_bytes: u64,
    /// Periodic evaluation metric (accuracy / eval loss / mean return),
    /// as (step, value).
    pub evals: Vec<(u64, f32)>,
    /// Drained trace events (app + engine lanes) from this rank's
    /// recorder; empty when tracing was disabled.
    pub trace: Vec<TraceEvent>,
}

/// Merged result of a multi-rank training run.
#[derive(Debug, Clone, Default)]
pub struct TrainResult {
    pub algo: String,
    pub p: usize,
    pub per_rank: Vec<RankMetrics>,
    /// Final model per rank (post-run consensus check / evaluation).
    pub final_params: Vec<Vec<f32>>,
    pub wall_seconds: f64,
}

impl TrainResult {
    /// Samples (or experience steps) per second across the whole cluster.
    pub fn throughput(&self, samples_per_step: usize) -> f64 {
        let total_steps: usize = self.per_rank.iter().map(|r| r.steps.len()).sum();
        (total_steps * samples_per_step) as f64 / self.wall_seconds.max(1e-12)
    }

    /// Mean training loss per iteration index, averaged over ranks.
    pub fn loss_curve(&self) -> Vec<(u64, f32)> {
        if self.per_rank.is_empty() {
            return Vec::new();
        }
        let steps = self.per_rank.iter().map(|r| r.steps.len()).min().unwrap_or(0);
        (0..steps)
            .map(|i| {
                let sum: f32 = self.per_rank.iter().map(|r| r.steps[i].loss).sum();
                (self.per_rank[0].steps[i].t, sum / self.per_rank.len() as f32)
            })
            .collect()
    }

    /// Distribution of per-iteration wall times across all ranks/steps.
    pub fn iter_time_summary(&self) -> Summary {
        let all: Vec<f64> =
            self.per_rank.iter().flat_map(|r| r.steps.iter().map(|s| s.wall)).collect();
        Summary::of(&all)
    }

    /// Mean staleness across all contributions (0 for synchronous algos).
    pub fn mean_staleness(&self) -> f64 {
        let (mut n, mut sum) = (0u64, 0u64);
        for r in &self.per_rank {
            for st in &r.steps {
                n += 1;
                sum += st.staleness;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }

    /// Maximum pairwise L∞ distance between final rank models — the model
    /// consistency check (must be ~0 right after a global sync).
    pub fn model_divergence(&self) -> f32 {
        let mut worst = 0.0f32;
        for a in &self.final_params {
            for b in &self.final_params {
                worst = worst.max(crate::util::max_abs_diff(a, b));
            }
        }
        worst
    }

    /// Mean of per-rank eval curves: (step, mean value).
    pub fn eval_curve(&self) -> Vec<(u64, f32)> {
        let Some(first) = self.per_rank.first() else { return Vec::new() };
        let n_evals = self.per_rank.iter().map(|r| r.evals.len()).min().unwrap_or(0);
        (0..n_evals)
            .map(|i| {
                let sum: f32 = self.per_rank.iter().map(|r| r.evals[i].1).sum();
                (first.evals[i].0, sum / self.per_rank.len() as f32)
            })
            .collect()
    }

    /// All trace events across ranks, merged and sorted by start time
    /// (ties broken by rank so the order is deterministic).
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        let mut all: Vec<TraceEvent> =
            self.per_rank.iter().flat_map(|r| r.trace.iter().copied()).collect();
        all.sort_by_key(|e| (e.t_ns, e.rank, e.lane.index(), e.kind.index()));
        all
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("algo", s(&self.algo)),
            ("p", num(self.p as f64)),
            ("wall_seconds", num(self.wall_seconds)),
            (
                "loss_curve",
                arr(self
                    .loss_curve()
                    .into_iter()
                    .map(|(t, l)| arr([num(t as f64), num(l as f64)]))),
            ),
            (
                "eval_curve",
                arr(self
                    .eval_curve()
                    .into_iter()
                    .map(|(t, v)| arr([num(t as f64), num(v as f64)]))),
            ),
            ("mean_staleness", num(self.mean_staleness())),
            ("model_divergence", num(self.model_divergence() as f64)),
        ])
    }
}

/// Minimal CSV writer for figure series.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        writeln!(self.file, "{}", fields.join(","))
    }

    pub fn rowf(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| format!("{f}")).collect();
        self.row(&strs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_result() -> TrainResult {
        let mk_rank = |rank: usize, base: f32| RankMetrics {
            rank,
            steps: (0..4)
                .map(|t| StepRecord {
                    t,
                    loss: base - t as f32 * 0.1,
                    wall: 0.01,
                    staleness: rank as u64,
                })
                .collect(),
            total_seconds: 0.04,
            sent_msgs: 10,
            sent_bytes: 1000,
            evals: vec![(0, 0.1), (2, 0.5)],
            trace: Vec::new(),
        };
        TrainResult {
            algo: "test".into(),
            p: 2,
            per_rank: vec![mk_rank(0, 1.0), mk_rank(1, 2.0)],
            final_params: vec![vec![1.0, 2.0], vec![1.0, 2.5]],
            wall_seconds: 0.04,
        }
    }

    #[test]
    fn curves_and_summaries() {
        let r = mk_result();
        let lc = r.loss_curve();
        assert_eq!(lc.len(), 4);
        assert!((lc[0].1 - 1.5).abs() < 1e-6);
        assert!((r.mean_staleness() - 0.5).abs() < 1e-9);
        assert!((r.model_divergence() - 0.5).abs() < 1e-6);
        assert_eq!(r.eval_curve(), vec![(0, 0.1), (2, 0.5)]);
        // 8 steps total / 0.04 s * batch 4 = 800 samples/s.
        assert!((r.throughput(4) - 800.0).abs() < 1e-6);
    }

    #[test]
    fn json_emits() {
        let j = mk_result().to_json();
        let text = j.to_string();
        assert!(text.contains("\"algo\":\"test\""));
        assert!(crate::util::json::Json::parse(&text).is_ok());
    }

    #[test]
    fn csv_writer_roundtrip() {
        let dir = std::env::temp_dir().join("wagma_csv_test");
        let path = dir.join("x.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
        let _ = std::fs::remove_dir_all(dir);
    }
}
