//! Discrete-event cluster simulator — the at-scale substrate.
//!
//! The paper's Fig. 4/7/10 run on up to 1,024 GPU nodes of Piz Daint. That
//! hardware is substituted (DESIGN.md §2) by an event-driven simulation
//! that executes the *same* communication schedules — recursive-doubling
//! phases, butterfly group exchanges with engine-level (wait-avoiding)
//! participation, ring/gossip dependencies — over an α-β network model
//! calibrated to an Aries-class interconnect, with per-rank compute times
//! drawn from the paper's three imbalance processes.
//!
//! What the simulation preserves: who waits for whom (the synchronization
//! structure of each algorithm), message counts/sizes, activation latency,
//! the τ-periodic global barrier, straggler lag accumulation. What it
//! abstracts: per-packet behaviour and congestion (first-order contention
//! is modelled via the per-phase serialization term).

pub mod network;
pub mod sim;

pub use network::NetworkModel;
pub use sim::{simulate, simulated_overlap_fraction, SimConfig, SimResult};
