//! Per-algorithm timing simulation.
//!
//! State per rank: `app[i]` — when rank i's application finishes iteration
//! t; `engine[i]` — when its communication engine is next free. Each
//! algorithm advances these through its own synchronization structure;
//! compute times come from the imbalance process.

use crate::collectives::allreduce::RING_THRESHOLD;
use crate::compress::Compression;
use crate::data::{ImbalanceModel, StepDelays};
use crate::fault::FaultPlan;
use crate::optim::{pair_avg, Algorithm};
use crate::sched::{Bucket, FusionConfig, FusionMode, FusionPlan, LayerProfile};
use crate::simulator::network::NetworkModel;
use crate::topology::{log2_exact, Grouping};
use crate::trace::{Lane, TraceEvent, TraceKind};
use crate::util::stats::Summary;

/// Simulation configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    pub algo: Algorithm,
    pub p: usize,
    pub steps: usize,
    /// Flat model size in bytes (gradient/model message payload).
    pub model_bytes: usize,
    /// WAGMA/eager τ.
    pub tau: u64,
    /// WAGMA group size (0 = √P).
    pub group_size: usize,
    pub dynamic_groups: bool,
    pub local_sgd_h: u64,
    pub sgp_neighbors: usize,
    pub imbalance: ImbalanceModel,
    pub net: NetworkModel,
    pub seed: u64,
    /// Layer-aware fusion / overlap knobs. With `fusion.layered = false`
    /// (the default) every exchange is the seed's flat `model_bytes` blob
    /// fired after compute — existing results are reproduced exactly. With
    /// `layered = true` the allreduce-style algorithms (WAGMA, eager-SGD,
    /// Allreduce-SGD, Local SGD's averaging steps) consume the bucket
    /// timeline from [`crate::sched`]: each bucket's collective starts as
    /// soon as its layers' backprop completes, overlapping communication
    /// with the rest of the backward pass. The gossip baselines (D-PSGD,
    /// SGP, AD-PSGD) keep flat payloads — their per-step exchanges are not
    /// bucket-scheduled collectives.
    pub fusion: FusionConfig,
    /// Per-bucket wire compression for the engine-backed collectives
    /// (WAGMA / eager-SGD group exchanges and their every-τ ring sync) —
    /// exactly the paths the real [`crate::collectives::engine`]
    /// compresses. The direct-mode baselines (Allreduce-SGD, Local SGD,
    /// the gossip algorithms) stay uncompressed, as in the real runners.
    pub compress: Compression,
    /// Emit the analytic timeline as [`TraceEvent`]s — the same schema the
    /// real engine records — so one tool can diff simulated vs. measured
    /// overlap per phase. Off by default: tracing a long run materializes
    /// `O(steps · p · buckets · phases)` events.
    pub trace: bool,
    /// Deterministic fault schedule (crashes, stalls, skew, link jitter) —
    /// the same [`FaultPlan`] the real engine consumes. An empty plan is
    /// arithmetically invisible: every fault adjustment is guarded, so
    /// fault-free results stay bit-identical to the pre-fault simulator.
    pub faults: FaultPlan,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            algo: Algorithm::Wagma,
            p: 64,
            steps: 200,
            model_bytes: 25_559_081 * 4, // ResNet-50 f32
            tau: 10,
            group_size: 0,
            dynamic_groups: true,
            local_sgd_h: 1,
            sgp_neighbors: 2,
            imbalance: ImbalanceModel::fig4(),
            net: NetworkModel::aries(),
            seed: 42,
            fusion: FusionConfig::default(),
            compress: Compression::None,
            trace: false,
            faults: FaultPlan::none(),
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub algo: String,
    pub p: usize,
    pub steps: usize,
    /// Time at which the last rank finished everything.
    pub makespan: f64,
    /// Makespan with zero communication cost (the paper's "ideal"
    /// rectangle tops).
    pub ideal_makespan: f64,
    /// Per-iteration cluster-wide completion-time deltas.
    pub iter_times: Vec<f64>,
    /// Mean lag (seconds) between fastest and slowest rank entering each
    /// iteration — the straggler-absorption metric.
    pub mean_skew: f64,
    /// Modelled bytes-on-wire sent per rank per iteration (collective
    /// payload traffic; activations are latency-only). For the compressed
    /// engine paths this counts the *encoded* volume — the simulator-side
    /// counterpart of the measured harness's `sent_bytes_per_iter`.
    pub wire_bytes_per_iter: f64,
    /// Analytic timeline in the engine's event schema (empty unless
    /// `SimConfig::trace`), sorted by start time.
    pub trace: Vec<TraceEvent>,
}

impl SimConfig {
    /// Does this configuration actually take the layered path? The gossip
    /// baselines (D-PSGD, SGP, AD-PSGD) ignore `fusion.layered`: their
    /// per-step exchanges are not bucket-scheduled collectives.
    pub fn layered_active(&self) -> bool {
        self.fusion.layered
            && matches!(
                self.algo,
                Algorithm::Wagma
                    | Algorithm::EagerSgd
                    | Algorithm::AllreduceSgd
                    | Algorithm::LocalSgd
            )
    }

    /// Collective size the fusion planner costs against — the group
    /// butterfly for WAGMA, the global allreduce for everything else.
    /// Single source of truth shared by `simulate`, the fusion figure,
    /// and the fusion bench.
    pub fn fusion_participants(&self) -> usize {
        let group_size = if self.group_size == 0 {
            Grouping::sqrt_group_size(self.p)
        } else {
            self.group_size
        };
        match self.algo {
            Algorithm::Wagma => group_size.min(self.p).max(2),
            _ => self.p.max(2),
        }
    }
}

impl SimResult {
    /// Samples/second with per-rank batch `b`.
    pub fn throughput(&self, b: usize) -> f64 {
        (self.p * b * self.steps) as f64 / self.makespan
    }

    /// Communication time not hidden under compute — the simulator-side
    /// quantity the measured-overlap bench validates against wall clock:
    /// `makespan - ideal_makespan` (the "exposed" cost above the paper's
    /// ideal rectangle tops).
    pub fn exposed_comm(&self) -> f64 {
        (self.makespan - self.ideal_makespan).max(0.0)
    }

    pub fn ideal_throughput(&self, b: usize) -> f64 {
        (self.p * b * self.steps) as f64 / self.ideal_makespan
    }

    pub fn iter_time_summary(&self) -> Summary {
        Summary::of(&self.iter_times)
    }
}

/// Run the timing simulation.
pub fn simulate(cfg: &SimConfig) -> SimResult {
    assert!(cfg.p.is_power_of_two(), "P must be a power of two");
    let p = cfg.p;
    let n = cfg.model_bytes;
    let net = cfg.net;
    let mut delays = StepDelays::new(cfg.imbalance, p, cfg.seed);

    let group_size = if cfg.group_size == 0 {
        Grouping::sqrt_group_size(p)
    } else {
        cfg.group_size
    };
    let grouping = if cfg.dynamic_groups {
        Grouping::new(p, group_size.min(p))
    } else {
        Grouping::fixed(p, group_size.min(p))
    };

    // Layered mode: one fusion plan per run, sized against the collective
    // this algorithm actually issues every iteration (group butterfly for
    // WAGMA, global allreduce otherwise). Algorithms whose exchanges are
    // not bucket-scheduled collectives never build a plan.
    // Compression applies to the engine-backed paths only (group
    // exchanges + their τ-sync), mirroring the real runners: the
    // direct-mode baselines never compress.
    let engine_comp = match cfg.algo {
        Algorithm::Wagma | Algorithm::EagerSgd => cfg.compress,
        _ => Compression::None,
    };
    let layered: Option<FusionPlan> = if cfg.layered_active() {
        let profile = LayerProfile::for_model_bytes(n);
        Some(FusionPlan::build_compressed(
            &profile,
            &cfg.fusion,
            &net,
            cfg.fusion_participants(),
            cfg.imbalance.mean(),
            engine_comp,
        ))
    } else {
        None
    };
    // Group collectives always run through the bucket recurrence: the
    // layered plan when active, else one flat full-payload bucket —
    // numerically identical to the seed's flat path (`ready_frac = 1`
    // makes every bucket-ready time the plain arrival time; pinned
    // bit-for-bit by the layered/flat equivalence tests).
    let flat_plan = FusionPlan {
        mode: FusionMode::Flat,
        buckets: vec![Bucket { first: 0, last: 0, bytes: n, ready_frac: 1.0 }],
    };
    let group_plan: &FusionPlan = layered.as_ref().unwrap_or(&flat_plan);

    // app[i]: when rank i's app finished iteration t-1 (incl. waiting for
    // the data it needs). engine[i]: when its comm engine is next free.
    let mut app = vec![0.0f64; p];
    let mut engine = vec![0.0f64; p];
    let mut ideal = vec![0.0f64; p];
    let mut iter_times = Vec::with_capacity(cfg.steps);
    let mut skew_acc = 0.0;
    let mut prev_max = 0.0f64;
    let mut wire_total = 0.0f64;
    let mut trace: Vec<TraceEvent> = Vec::new();

    for t in 0..cfg.steps {
        let mut compute = delays.sample_step();
        // Fault arithmetic. Every adjustment is guarded so an empty plan
        // leaves each f64 bit-identical to the pre-fault simulator.
        for i in 0..p {
            let skew = cfg.faults.skew_of(i);
            if skew != 1.0 {
                compute[i] *= skew;
            }
            let stall = cfg.faults.stall_s(i, t as u64);
            if stall > 0.0 {
                compute[i] += stall;
            }
            // Inbound link jitter, hashed on the rank's predecessor link —
            // the simulator-level image of the engine's per-link jitter.
            let jitter = cfg.faults.jitter_s((i + p - 1) % p, i, t as u64);
            if jitter > 0.0 {
                compute[i] += jitter;
            }
        }
        // Fail-stop mask: a crashed rank freezes (no compute, no traffic)
        // and is excluded from every fold below. With no crashes the mask
        // is all-true and the filtered folds reduce the same sequences.
        let alive: Vec<bool> = (0..p).map(|i| !cfg.faults.crash_at(i, t as u64)).collect();
        let any_dead = alive.iter().any(|&a| !a);
        wire_total += iteration_wire_bytes(cfg, t, group_size, group_plan, engine_comp);
        let start_min = masked(&app, &alive).fold(f64::INFINITY, f64::min);
        let start_max = masked(&app, &alive).fold(f64::NEG_INFINITY, f64::max);
        skew_acc += start_max - start_min;
        for i in 0..p {
            if alive[i] {
                ideal[i] += compute[i];
            }
        }
        // Arrival of each app at the communication call site.
        let arrival: Vec<f64> = (0..p).map(|i| app[i] + compute[i]).collect();
        // Failure-detection penalty the *synchronous* baselines pay every
        // iteration once any rank is dead: without wait-avoidance the
        // collective blocks on a detection deadline before re-forming.
        // Priced per suspect rank — each dead peer is a separate timeout
        // the membership protocol must confirm, so losing k ranks costs
        // k deadlines per iteration, not one flat charge. The `any_dead`
        // guard keeps empty fault plans bitwise neutral.
        let dead_count = alive.iter().filter(|&&a| !a).count();
        let penalty =
            if any_dead { cfg.faults.deadline_s.max(0.0) * dead_count as f64 } else { 0.0 };
        if cfg.trace {
            for i in 0..p {
                if cfg.faults.crash_iter(i) == Some(t as u64) {
                    let mut ev = TraceEvent::new(
                        TraceKind::Fault,
                        Lane::Engine,
                        ns(app[i]),
                        ns(cfg.faults.deadline_s.max(0.0)),
                    );
                    ev.rank = i as u32;
                    ev.version = t as u64;
                    trace.push(ev);
                }
            }
        }
        // Pre-compute app times: the bucket recurrence places per-bucket
        // gradient ready points inside the backward pass relative to these.
        let app_prev: Vec<f64> = app.clone();
        if cfg.trace {
            for i in 0..p {
                if !alive[i] {
                    continue;
                }
                let mut ev =
                    TraceEvent::new(TraceKind::Compute, Lane::App, ns(app_prev[i]), ns(compute[i]));
                ev.rank = i as u32;
                ev.version = t as u64;
                trace.push(ev);
            }
        }

        match cfg.algo {
            Algorithm::AllreduceSgd => {
                if let Some(plan) = &layered {
                    layered_sync_allreduce_step(
                        &mut app, &app_prev, &compute, plan, &net, p, Compression::None, &alive,
                        penalty,
                    );
                } else {
                    sync_allreduce_step(&mut app, &arrival, net.allreduce(n, p), &alive, penalty);
                }
            }
            Algorithm::LocalSgd => {
                let h = cfg.local_sgd_h.max(1);
                if (t as u64 + 1) % h == 0 {
                    if let Some(plan) = &layered {
                        layered_sync_allreduce_step(
                            &mut app, &app_prev, &compute, plan, &net, p, Compression::None,
                            &alive, penalty,
                        );
                    } else {
                        sync_allreduce_step(
                            &mut app, &arrival, net.allreduce(n, p), &alive, penalty,
                        );
                    }
                } else {
                    for i in 0..p {
                        if alive[i] {
                            app[i] = arrival[i];
                        }
                    }
                }
            }
            Algorithm::DPsgd => {
                // Paper §II-B: "processes advance synchronously with a
                // single global clock" — every iteration starts when the
                // slowest rank arrives; communication is only the two
                // neighbor exchanges.
                let cost = 2.0 * net.exchange(n, 3);
                sync_allreduce_step(&mut app, &arrival, cost, &alive, penalty);
            }
            Algorithm::Sgp => {
                // SGP is likewise synchronous per iteration (Table I:
                // staleness "none"); k directed pushes per step.
                let k = cfg.sgp_neighbors.max(1);
                let _ = log2_exact(p); // graph validity
                let cost = k as f64 * net.exchange(n, k + 1);
                sync_allreduce_step(&mut app, &arrival, cost, &alive, penalty);
            }
            Algorithm::AdPsgd => {
                // Fully asynchronous: communication overlaps compute; the
                // only residual cost is the atomic pairwise blend (payload
                // serialization at the receiving host, not overlappable).
                let blend = n as f64 * net.gamma;
                for i in 0..p {
                    if alive[i] {
                        app[i] = arrival[i] + blend;
                    }
                }
            }
            Algorithm::PairAveraging => {
                // One blocking partner per iteration on the rotating
                // hypercube pairing. Quorum 2 makes the baseline cheap but
                // brittle: a dead partner stalls the survivor a full
                // detection deadline, every time the rotation lands on it.
                let cost = net.exchange(n, 2);
                for i in 0..p {
                    if !alive[i] {
                        continue;
                    }
                    if p == 1 {
                        app[i] = arrival[i];
                        continue;
                    }
                    let q = pair_avg::partner_of(i, t as u64, p);
                    app[i] = if alive[q] {
                        arrival[i].max(arrival[q]) + cost
                    } else {
                        arrival[i] + cfg.faults.deadline_s.max(0.0)
                    };
                }
            }
            Algorithm::Wagma | Algorithm::EagerSgd => {
                let s = if cfg.algo == Algorithm::EagerSgd { p } else { group_size };
                let is_sync = cfg.tau != 0 && (t as u64 + 1) % cfg.tau == 0;
                if is_sync {
                    // The τ-sync re-forms over survivors without a
                    // detection stall: membership is deterministic from
                    // the shared plan (no penalty — the wait-avoiding
                    // contrast the elastic figure quantifies).
                    if let Some(plan) = &layered {
                        layered_sync_allreduce_step(
                            &mut app, &app_prev, &compute, plan, &net, p, engine_comp, &alive,
                            0.0,
                        );
                    } else {
                        let cost = sync_allreduce_cost(&net, n, p, engine_comp);
                        sync_allreduce_step(&mut app, &arrival, cost, &alive, 0.0);
                    }
                    // Engine-lane τ-sync spans: the barrier wait from each
                    // rank's arrival to the slowest rank, then the
                    // collective itself (only its exposed tail when the
                    // layered schedule hid part of it under compute).
                    if cfg.trace {
                        let arrival_max = masked(&arrival, &alive).fold(f64::NEG_INFINITY, f64::max);
                        // The rank whose late arrival set the barrier — the
                        // causal peer every other rank's barrier wait points
                        // at (mirrors the engine's wire-stamped blocked
                        // receive; first argmax on ties).
                        let slowest = (0..p)
                            .filter(|&i| alive[i])
                            .fold(None::<usize>, |acc, i| match acc {
                                Some(j) if arrival[j] >= arrival[i] => Some(j),
                                _ => Some(i),
                            });
                        let end = (0..p).find(|&i| alive[i]).map_or(app[0], |i| app[i]);
                        let sync_wire =
                            iteration_wire_bytes(cfg, t, group_size, group_plan, engine_comp)
                                as u64;
                        for i in 0..p {
                            if !alive[i] {
                                continue;
                            }
                            let barrier = ns(arrival_max).saturating_sub(ns(arrival[i]));
                            if barrier > 0 {
                                let mut w = TraceEvent::new(
                                    TraceKind::Wait,
                                    Lane::Engine,
                                    ns(arrival[i]),
                                    barrier,
                                );
                                w.rank = i as u32;
                                w.version = t as u64;
                                if let Some(q) = slowest {
                                    if q != i {
                                        w.peer = q as u32;
                                    }
                                }
                                trace.push(w);
                            }
                            let mut ts = TraceEvent::new(
                                TraceKind::TauSync,
                                Lane::Engine,
                                ns(arrival_max),
                                ns(end).saturating_sub(ns(arrival_max)),
                            );
                            ts.rank = i as u32;
                            ts.version = t as u64;
                            ts.bytes = sync_wire;
                            if let Some(q) = slowest {
                                if q != i {
                                    ts.peer = q as u32;
                                }
                            }
                            trace.push(ts);
                        }
                    }
                    engine.copy_from_slice(&app);
                } else {
                    layered_group_step(
                        &mut app,
                        &mut engine,
                        &app_prev,
                        &compute,
                        &arrival,
                        &grouping,
                        s,
                        t as u64,
                        group_plan,
                        &net,
                        p,
                        engine_comp,
                        &alive,
                        cfg.trace.then_some(&mut trace),
                    );
                }
            }
        }
        // App-lane wait spans — the exposed windows the attribution report
        // decomposes: time between a rank's arrival at the communication
        // call site and its app resuming.
        if cfg.trace {
            for i in 0..p {
                if !alive[i] {
                    continue;
                }
                let wait = ns(app[i]).saturating_sub(ns(arrival[i]));
                if wait > 0 {
                    let mut w = TraceEvent::new(TraceKind::Wait, Lane::App, ns(arrival[i]), wait);
                    w.rank = i as u32;
                    w.version = t as u64;
                    trace.push(w);
                }
            }
        }
        let cur_max = app.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        iter_times.push(cur_max - prev_max);
        prev_max = cur_max;
    }
    trace.sort_by_key(|e| (e.t_ns, e.rank, e.lane.index(), e.kind.index()));

    SimResult {
        algo: cfg.algo.name().to_string(),
        p,
        steps: cfg.steps,
        makespan: prev_max,
        ideal_makespan: ideal.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        iter_times,
        mean_skew: skew_acc / cfg.steps as f64,
        wire_bytes_per_iter: wire_total / cfg.steps as f64,
        trace,
    }
}

/// Seconds → integer nanoseconds on the simulated event clock.
fn ns(x: f64) -> u64 {
    (x.max(0.0) * 1e9).round() as u64
}

/// Iterate the values of `v` whose rank is alive. Folding over this (rather
/// than the whole slice) keeps dead ranks from dragging a frozen timestamp
/// into cluster-wide maxima; with everyone alive it visits exactly the same
/// values in the same order, so fault-free runs stay bit-identical.
fn masked<'a>(v: &'a [f64], alive: &'a [bool]) -> impl Iterator<Item = f64> + 'a {
    v.iter().zip(alive).filter(|(_, &a)| a).map(|(x, _)| *x)
}

/// Every-τ global allreduce cost under the engine's compression policy:
/// the compressed ring for ring-sized payloads, the exact best-of
/// allreduce otherwise (small syncs are latency-bound; the engine keeps
/// them uncompressed).
fn sync_allreduce_cost(net: &NetworkModel, n_bytes: usize, p: usize, comp: Compression) -> f64 {
    if comp.is_none() || p <= 2 || n_bytes / 4 < RING_THRESHOLD {
        net.allreduce(n_bytes, p)
    } else {
        net.allreduce_ring_compressed(n_bytes, comp.wire_bytes(n_bytes), p)
    }
}

/// Modelled bytes-on-wire one rank sends during iteration `t` (collective
/// payload traffic only; activation control messages are latency, not
/// volume). The engine-backed algorithms count encoded bytes when
/// compression is on; everything else counts raw payload bytes, matching
/// the real runners' `sent_bytes` accounting.
fn iteration_wire_bytes(
    cfg: &SimConfig,
    t: usize,
    group_size: usize,
    group_plan: &FusionPlan,
    comp: Compression,
) -> f64 {
    let n = cfg.model_bytes;
    let p = cfg.p;
    let direct_allreduce = |n: usize| -> f64 {
        if p <= 1 {
            0.0
        } else if p > 2 && n / 4 >= RING_THRESHOLD {
            2.0 * (p - 1) as f64 * (n / p) as f64
        } else {
            log2_exact(p) as f64 * n as f64
        }
    };
    match cfg.algo {
        Algorithm::AllreduceSgd => direct_allreduce(n),
        Algorithm::LocalSgd => {
            if (t as u64 + 1) % cfg.local_sgd_h.max(1) == 0 {
                direct_allreduce(n)
            } else {
                0.0
            }
        }
        Algorithm::DPsgd => 2.0 * n as f64,
        Algorithm::Sgp => cfg.sgp_neighbors.max(1) as f64 * n as f64,
        Algorithm::AdPsgd => n as f64,
        Algorithm::PairAveraging => n as f64,
        Algorithm::Wagma | Algorithm::EagerSgd => {
            let s = if cfg.algo == Algorithm::EagerSgd { p } else { group_size };
            let is_sync = cfg.tau != 0 && (t as u64 + 1) % cfg.tau == 0;
            if is_sync {
                if comp.is_none() || p <= 2 || n / 4 < RING_THRESHOLD {
                    direct_allreduce(n)
                } else {
                    2.0 * (p - 1) as f64 * comp.wire_bytes(n / p) as f64
                }
            } else {
                let phases = log2_exact(s.min(p)) as f64;
                group_plan
                    .buckets
                    .iter()
                    .map(|b| phases * comp.wire_bytes(b.bytes) as f64)
                    .sum()
            }
        }
    }
}

/// Simulator-side overlap validation hook for the measured bench: run the
/// same configuration flat and layered and report the fraction of exposed
/// communication the layered schedule hides,
/// `1 - exposed(layered) / exposed(flat)`.
pub fn simulated_overlap_fraction(cfg: &SimConfig) -> (SimResult, SimResult, f64) {
    let mut flat_cfg = cfg.clone();
    flat_cfg.fusion.layered = false;
    let mut layered_cfg = cfg.clone();
    layered_cfg.fusion.layered = true;
    let flat = simulate(&flat_cfg);
    let layered = simulate(&layered_cfg);
    let frac = if flat.exposed_comm() > 0.0 {
        1.0 - layered.exposed_comm() / flat.exposed_comm()
    } else {
        0.0
    };
    (flat, layered, frac)
}

/// Synchronous allreduce: everyone starts when the slowest *surviving*
/// rank arrives. `penalty` is the per-iteration detection stall a
/// synchronous collective pays once membership has shrunk (it must time
/// out on the dead rank every round); it is `0.0` in fault-free runs and
/// the addition is skipped entirely then so timings stay bit-identical.
fn sync_allreduce_step(app: &mut [f64], arrival: &[f64], cost: f64, alive: &[bool], penalty: f64) {
    let start = masked(arrival, alive).fold(f64::NEG_INFINITY, f64::max);
    for (i, a) in app.iter_mut().enumerate() {
        if !alive[i] {
            continue;
        }
        let v = start + cost;
        *a = if penalty > 0.0 { v + penalty } else { v };
    }
}

/// Layered synchronous allreduce (Allreduce-SGD, Local SGD averaging, the
/// every-τ WAGMA sync): bucket `b` becomes ready on rank `i` at
/// `app_prev[i] + compute[i] * ready_frac(b)` — i.e. partway through the
/// backward pass — and the cluster-wide collective for `b` starts once
/// every rank's bucket is ready AND the previous bucket finished (one
/// serial communication engine, as in MG-WFBP). The iteration ends at
/// `max(last bucket finish, slowest compute)`.
#[allow(clippy::too_many_arguments)]
fn layered_sync_allreduce_step(
    app: &mut [f64],
    app_prev: &[f64],
    compute: &[f64],
    plan: &FusionPlan,
    net: &NetworkModel,
    p: usize,
    comp: Compression,
    alive: &[bool],
    penalty: f64,
) {
    let mut finish = f64::NEG_INFINITY;
    for b in &plan.buckets {
        let ready = (0..p)
            .filter(|&i| alive[i])
            .map(|i| app_prev[i] + compute[i] * b.ready_frac)
            .fold(f64::NEG_INFINITY, f64::max);
        let start = ready.max(finish);
        let comm = if comp.is_none() {
            net.allreduce(b.bytes, p)
        } else {
            net.allreduce_compressed(b.bytes, comp.wire_bytes(b.bytes), p)
        };
        finish = start + comm;
    }
    let arrival_max = (0..p)
        .filter(|&i| alive[i])
        .map(|i| app_prev[i] + compute[i])
        .fold(f64::NEG_INFINITY, f64::max);
    let end = finish.max(arrival_max);
    for (i, a) in app.iter_mut().enumerate() {
        if !alive[i] {
            continue;
        }
        *a = if penalty > 0.0 { end + penalty } else { end };
    }
}

/// Wait-avoiding group allreduce iteration (the paper's §III semantics at
/// the timing level), applied per fused bucket in backprop-completion
/// order — the flat payload is simply the single-bucket plan
/// (`ready_frac = 1`, so every bucket-ready time is the plain arrival):
///
/// * the first *bucket-ready* rank activates; activation reaches every
///   engine after the binomial-tree latency;
/// * an engine joins at `max(engine_free, min(own bucket ready,
///   activation))` — a busy app does NOT delay its engine (passive, stale
///   contribution), which is exactly the wait-avoidance;
/// * `log2(S)` butterfly phases relax pairwise on the bucket's bytes with
///   the dynamic grouping's partners (for eager-SGD `s == p`: plain
///   recursive-doubling masks);
/// * engines serialize across buckets; the app continues at
///   `max(own arrival, own engine completion)` — for stragglers the
///   collective is already done when they arrive.
#[allow(clippy::too_many_arguments)]
fn layered_group_step(
    app: &mut [f64],
    engine: &mut [f64],
    app_prev: &[f64],
    compute: &[f64],
    arrival: &[f64],
    grouping: &Grouping,
    s: usize,
    t: u64,
    plan: &FusionPlan,
    net: &NetworkModel,
    p: usize,
    comp: Compression,
    alive: &[bool],
    mut tr: Option<&mut Vec<TraceEvent>>,
) {
    let phases = log2_exact(s.min(p));
    for bucket in &plan.buckets {
        let ready: Vec<f64> =
            (0..p).map(|i| app_prev[i] + compute[i] * bucket.ready_frac).collect();
        let activator = masked(&ready, alive).fold(f64::INFINITY, f64::min);
        let act = activator + net.activation(p);
        // A dead rank's engine lane is frozen; it neither joins nor delays.
        let mut times: Vec<f64> = (0..p)
            .map(|i| if alive[i] { engine[i].max(ready[i].min(act)) } else { engine[i] })
            .collect();
        let cost = if comp.is_none() {
            net.exchange(bucket.bytes, s.min(p))
        } else {
            net.exchange_compressed(bucket.bytes, comp.wire_bytes(bucket.bytes), s.min(p))
        };
        let wire = comp.wire_bytes(bucket.bytes) as u64;
        // Per-side δ codec time inside each phase (the `exchange_compressed`
        // pricing pays it twice: encode ours, decode the partner's).
        let codec = if comp.is_none() { 0u64 } else { ns(net.delta * bucket.bytes as f64) };
        for r in 0..phases {
            let prev = times.clone();
            for i in 0..p {
                if !alive[i] {
                    continue;
                }
                let partner = if s >= p {
                    i ^ (1usize << r)
                } else {
                    grouping.partner(i, t, r)
                };
                if !alive[partner] {
                    // Degraded phase: the exchange with a dead partner
                    // completes as identity (the engine's skipped_phases
                    // path) — no cost, no progress from that peer. The
                    // dead partner rides in `peer` so the causal graph
                    // keeps degraded phases attached via the membership
                    // oracle edge.
                    times[i] = prev[i];
                    if let Some(sink) = tr.as_deref_mut() {
                        let mut ev =
                            TraceEvent::new(TraceKind::Fault, Lane::Engine, ns(prev[i]), 0);
                        ev.rank = i as u32;
                        ev.version = t;
                        ev.phase = r;
                        ev.peer = partner as u32;
                        sink.push(ev);
                    }
                    continue;
                }
                times[i] = prev[i].max(prev[partner]) + cost;
                if let Some(sink) = tr.as_deref_mut() {
                    let t0 = ns(prev[i]);
                    // A rank whose bucket was not ready when activation
                    // arrived contributes its stale payload passively.
                    let passive = act < ready[i];
                    let stamp = |mut ev: TraceEvent| {
                        ev.rank = i as u32;
                        ev.version = t;
                        ev.phase = r;
                        ev.passive = passive;
                        // Schedule partner = causal peer, exactly what the
                        // real engine stamps from the wire.
                        ev.peer = partner as u32;
                        ev
                    };
                    let mut ev = stamp(TraceEvent::new(
                        TraceKind::GroupExchangePhase,
                        Lane::Engine,
                        t0,
                        ns(times[i]) - t0,
                    ));
                    ev.bytes = wire;
                    sink.push(ev);
                    let wait = ns(prev[partner]).saturating_sub(t0);
                    if wait > 0 {
                        sink.push(stamp(TraceEvent::new(TraceKind::Wait, Lane::Engine, t0, wait)));
                    }
                    if codec > 0 {
                        sink.push(stamp(TraceEvent::new(TraceKind::Encode, Lane::Engine, t0, codec)));
                        sink.push(stamp(TraceEvent::new(TraceKind::Decode, Lane::Engine, t0, codec)));
                    }
                }
            }
        }
        engine.copy_from_slice(&times);
    }
    for i in 0..p {
        if alive[i] {
            app[i] = arrival[i].max(engine[i]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ImbalanceModel;

    fn base(algo: Algorithm, p: usize) -> SimConfig {
        SimConfig { algo, p, steps: 100, seed: 7, ..Default::default() }
    }

    #[test]
    fn balanced_workload_all_algos_near_ideal_plus_comm() {
        // With zero imbalance, every algorithm's makespan = ideal + comm.
        for algo in Algorithm::all() {
            let cfg = SimConfig {
                imbalance: ImbalanceModel::Balanced { base: 0.4, jitter: 0.0 },
                ..base(algo, 16)
            };
            let r = simulate(&cfg);
            assert!(
                r.makespan >= r.ideal_makespan,
                "{}: makespan below ideal",
                algo.name()
            );
            assert!(
                r.makespan < r.ideal_makespan * 1.6,
                "{}: overhead too large: {} vs {}",
                algo.name(),
                r.makespan,
                r.ideal_makespan
            );
        }
    }

    #[test]
    fn wagma_beats_synchronous_under_stragglers() {
        // Fig. 4 protocol: WAGMA must outperform Allreduce/local/D-PSGD/SGP,
        // and lose only to AD-PSGD.
        let p = 64;
        let thr = |algo: Algorithm| {
            let r = simulate(&SimConfig { imbalance: ImbalanceModel::fig4(), ..base(algo, p) });
            r.throughput(128)
        };
        let wagma = thr(Algorithm::Wagma);
        let allreduce = thr(Algorithm::AllreduceSgd);
        let local = thr(Algorithm::LocalSgd);
        let dpsgd = thr(Algorithm::DPsgd);
        let sgp = thr(Algorithm::Sgp);
        let adpsgd = thr(Algorithm::AdPsgd);
        let eager = thr(Algorithm::EagerSgd);
        assert!(wagma > allreduce, "wagma {wagma} vs allreduce {allreduce}");
        assert!(wagma > local, "wagma {wagma} vs local {local}");
        assert!(wagma > dpsgd, "wagma {wagma} vs dpsgd {dpsgd}");
        assert!(wagma > sgp, "wagma {wagma} vs sgp {sgp}");
        assert!(wagma > eager * 0.99, "wagma {wagma} vs eager {eager}");
        assert!(adpsgd > wagma, "adpsgd {adpsgd} vs wagma {wagma}");
    }

    #[test]
    fn speedup_grows_with_scale() {
        // Paper: WAGMA's advantage over Allreduce-SGD grows with node
        // count (1.25x at 64 → 1.37x at 256 measured). Our α-β-contention
        // model reproduces the growth through P=64 and saturates at larger
        // P (documented in EXPERIMENTS.md): assert growth in the 4→64
        // region and no collapse afterwards.
        let ratio = |p: usize| {
            let w = simulate(&SimConfig {
                imbalance: ImbalanceModel::fig4(),
                ..base(Algorithm::Wagma, p)
            });
            let a = simulate(&SimConfig {
                imbalance: ImbalanceModel::fig4(),
                ..base(Algorithm::AllreduceSgd, p)
            });
            w.throughput(128) / a.throughput(128)
        };
        let r4 = ratio(4);
        let r16 = ratio(16);
        let r64 = ratio(64);
        let r256 = ratio(256);
        assert!(r16 > r4, "speedup must grow 4→16: {r4} -> {r16}");
        assert!(r64 > r16 * 0.98, "speedup must not shrink 16→64: {r16} -> {r64}");
        assert!(r64 > 1.2, "64-node speedup {r64}");
        assert!(r256 > r64 * 0.9, "no collapse at 256: {r64} -> {r256}");
    }

    #[test]
    fn straggler_skew_absorbed_by_wagma_but_not_allreduce() {
        let mk = |algo| SimConfig { imbalance: ImbalanceModel::fig9(), ..base(algo, 32) };
        let w = simulate(&mk(Algorithm::Wagma));
        let a = simulate(&mk(Algorithm::AllreduceSgd));
        // Allreduce's apps all enter each iteration together (skew 0);
        // WAGMA lets fast ranks run ahead between syncs.
        assert!(a.mean_skew < 1e-9, "allreduce skew {}", a.mean_skew);
        assert!(w.mean_skew > 0.1, "wagma skew {}", w.mean_skew);
        // But WAGMA's makespan is still smaller.
        assert!(w.makespan < a.makespan);
    }

    #[test]
    fn tau_controls_barrier_frequency() {
        // Smaller τ = more global barriers = slower under imbalance.
        let mk = |tau| SimConfig {
            imbalance: ImbalanceModel::fig9(),
            tau,
            ..base(Algorithm::Wagma, 32)
        };
        let t2 = simulate(&mk(2)).makespan;
        let t10 = simulate(&mk(10)).makespan;
        let t0 = simulate(&mk(0)).makespan; // never sync
        assert!(t10 < t2, "tau=10 {t10} vs tau=2 {t2}");
        assert!(t0 <= t10 * 1.01, "tau=0 {t0} vs tau=10 {t10}");
    }

    #[test]
    fn group_size_tradeoff() {
        // Larger groups cost more per iteration (ablation ❸: S=P drops
        // throughput 1.24x in the paper).
        let mk = |s| SimConfig {
            imbalance: ImbalanceModel::fig4(),
            group_size: s,
            ..base(Algorithm::Wagma, 64)
        };
        let s8 = simulate(&mk(8)).throughput(128);
        let s64 = simulate(&mk(64)).throughput(128);
        assert!(s8 > s64, "S=8 {s8} vs S=64 {s64}");
        let drop = s8 / s64;
        assert!(drop > 1.05 && drop < 2.0, "throughput drop {drop}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = simulate(&base(Algorithm::Wagma, 16));
        let b = simulate(&base(Algorithm::Wagma, 16));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.iter_times, b.iter_times);
    }

    #[test]
    fn layered_flat_bucket_reproduces_flat_results() {
        // fusion.layered with a single full-model bucket (mode = Flat) is
        // numerically identical to the seed's flat path — for the group
        // collectives, the τ syncs, and the synchronous baselines.
        use crate::sched::{FusionConfig, FusionMode};
        for algo in [Algorithm::Wagma, Algorithm::EagerSgd, Algorithm::AllreduceSgd, Algorithm::LocalSgd] {
            let flat = simulate(&base(algo, 16));
            let layered = simulate(&SimConfig {
                fusion: FusionConfig {
                    layered: true,
                    mode: FusionMode::Flat,
                    ..Default::default()
                },
                ..base(algo, 16)
            });
            assert_eq!(flat.makespan, layered.makespan, "{}", algo.name());
            assert_eq!(flat.iter_times, layered.iter_times, "{}", algo.name());
        }
    }

    #[test]
    fn layered_overlap_reduces_makespan() {
        // Bucketed, overlap-scheduled exchanges must strictly beat the flat
        // payload under the Fig. 4 workload (the acceptance criterion's
        // mechanism: communication hides under backprop).
        use crate::sched::FusionConfig;
        for algo in [Algorithm::Wagma, Algorithm::AllreduceSgd] {
            let flat = simulate(&base(algo, 64));
            let layered = simulate(&SimConfig {
                fusion: FusionConfig { layered: true, ..Default::default() },
                ..base(algo, 64)
            });
            assert!(
                layered.makespan < flat.makespan,
                "{}: layered {} vs flat {}",
                algo.name(),
                layered.makespan,
                flat.makespan
            );
            assert!(layered.makespan >= layered.ideal_makespan - 1e-9);
        }
    }

    #[test]
    fn overlap_fraction_hook_positive_under_fig4() {
        // The hook forces layered on/off itself; no fusion override needed.
        let cfg = base(Algorithm::Wagma, 64);
        let (flat, layered, frac) = simulated_overlap_fraction(&cfg);
        assert!(flat.exposed_comm() > 0.0);
        assert!(layered.exposed_comm() >= 0.0);
        assert!(frac > 0.0 && frac <= 1.0, "overlap fraction {frac}");
    }

    /// Simulator-side acceptance: top-k at ratio 0.1 cuts modelled
    /// bytes-on-wire by ≥ 4x on the fig4 shape, and the makespan (hence
    /// the achieved-overlap fraction) is no worse than uncompressed.
    #[test]
    fn compressed_wire_bytes_reduced_4x_with_no_worse_makespan() {
        use crate::compress::Compression;
        let none = simulate(&base(Algorithm::Wagma, 64));
        let topk = simulate(&SimConfig {
            compress: Compression::TopK { ratio: 0.1 },
            ..base(Algorithm::Wagma, 64)
        });
        let reduction = none.wire_bytes_per_iter / topk.wire_bytes_per_iter;
        assert!(reduction >= 4.0, "wire reduction {reduction}");
        assert!(
            topk.makespan <= none.makespan,
            "compressed makespan {} vs {}",
            topk.makespan,
            none.makespan
        );
        assert!(topk.exposed_comm() <= none.exposed_comm() + 1e-9);
        // Same for the layered (bucketed) path.
        use crate::sched::FusionConfig;
        let layered = |comp| {
            simulate(&SimConfig {
                fusion: FusionConfig { layered: true, ..Default::default() },
                compress: comp,
                ..base(Algorithm::Wagma, 64)
            })
        };
        let lf = layered(Compression::None);
        let lc = layered(Compression::TopK { ratio: 0.1 });
        assert!(lf.wire_bytes_per_iter / lc.wire_bytes_per_iter >= 4.0);
        assert!(lc.makespan <= lf.makespan + 1e-9);
    }

    /// The compression knob touches only the engine-backed algorithms:
    /// direct-mode baselines are priced identically with or without it.
    #[test]
    fn baselines_ignore_the_compression_knob() {
        use crate::compress::Compression;
        for algo in [Algorithm::AllreduceSgd, Algorithm::LocalSgd, Algorithm::DPsgd] {
            let plain = simulate(&base(algo, 16));
            let comp = simulate(&SimConfig {
                compress: Compression::TopK { ratio: 0.1 },
                ..base(algo, 16)
            });
            assert_eq!(plain.makespan, comp.makespan, "{}", algo.name());
            assert_eq!(plain.wire_bytes_per_iter, comp.wire_bytes_per_iter);
        }
    }

    /// Wire-byte accounting is internally consistent: q8 lands between
    /// top-k 0.1 and uncompressed; Local SGD's averaging period divides
    /// its traffic; all counts are positive where traffic exists.
    #[test]
    fn wire_accounting_sanity() {
        use crate::compress::Compression;
        let w = |comp| {
            simulate(&SimConfig { compress: comp, ..base(Algorithm::Wagma, 64) })
                .wire_bytes_per_iter
        };
        let none = w(Compression::None);
        let q8 = w(Compression::QuantizeQ8);
        let topk = w(Compression::TopK { ratio: 0.1 });
        assert!(none > q8 && q8 > topk, "none {none} q8 {q8} topk {topk}");
        let h1 = simulate(&SimConfig { local_sgd_h: 1, ..base(Algorithm::LocalSgd, 16) });
        let h4 = simulate(&SimConfig { local_sgd_h: 4, ..base(Algorithm::LocalSgd, 16) });
        assert!(h1.wire_bytes_per_iter > h4.wire_bytes_per_iter * 3.0);
        assert!(h4.wire_bytes_per_iter > 0.0);
        assert!(simulate(&base(Algorithm::Sgp, 16)).wire_bytes_per_iter > 0.0);
    }

    #[test]
    fn scales_to_1024_ranks() {
        let cfg = SimConfig {
            imbalance: ImbalanceModel::fig9(),
            model_bytes: 8_476_421 * 4,
            steps: 50,
            ..base(Algorithm::Wagma, 1024)
        };
        let r = simulate(&cfg);
        assert!(r.makespan.is_finite() && r.makespan > 0.0);
        assert_eq!(r.iter_times.len(), 50);
    }

    /// An empty fault plan is arithmetically invisible: every timing is
    /// bit-identical to the pre-fault simulator, even with a nonzero
    /// detection deadline configured (the deadline only prices *observed*
    /// faults, it is not a standing tax).
    #[test]
    fn empty_fault_plan_is_bitwise_neutral() {
        use crate::fault::FaultPlan;
        for algo in Algorithm::all() {
            let plain = simulate(&base(algo, 16));
            let armed = simulate(&SimConfig {
                faults: FaultPlan { deadline_s: 0.123, ..FaultPlan::none() },
                ..base(algo, 16)
            });
            assert_eq!(plain.makespan, armed.makespan, "{}", algo.name());
            assert_eq!(plain.iter_times, armed.iter_times, "{}", algo.name());
            assert_eq!(plain.mean_skew, armed.mean_skew, "{}", algo.name());
            assert_eq!(plain.wire_bytes_per_iter, armed.wire_bytes_per_iter, "{}", algo.name());
        }
    }

    /// The elastic-membership contrast the figure quantifies: after a
    /// mid-run crash, synchronous Allreduce-SGD pays at least the full
    /// detection deadline every remaining iteration, while wait-avoiding
    /// WAGMA (deterministic membership, no detection stall) loses far
    /// less. PairAveraging sits in between: only the rotation slots that
    /// land on the dead rank stall.
    #[test]
    fn crashes_price_synchronous_baselines_a_deadline_per_iter() {
        use crate::fault::{Crash, FaultPlan};
        let p = 16;
        let steps = 60;
        let crash_at = 30u64;
        let deadline = 0.25;
        let plan = FaultPlan {
            crashes: vec![Crash { rank: 5, at_iter: crash_at }],
            deadline_s: deadline,
            ..FaultPlan::none()
        };
        let run = |algo: Algorithm, faults: FaultPlan| {
            simulate(&SimConfig {
                imbalance: ImbalanceModel::Balanced { base: 0.4, jitter: 0.0 },
                steps,
                faults,
                ..base(algo, p)
            })
        };
        let post_crash_iters = (steps as u64 - crash_at) as f64;

        let ar_plain = run(Algorithm::AllreduceSgd, FaultPlan::none());
        let ar_fault = run(Algorithm::AllreduceSgd, plan.clone());
        let ar_loss = ar_fault.makespan - ar_plain.makespan;
        assert!(
            ar_loss >= deadline * post_crash_iters - 1e-6,
            "allreduce lost {ar_loss} over {post_crash_iters} iters (deadline {deadline})"
        );

        let wg_plain = run(Algorithm::Wagma, FaultPlan::none());
        let wg_fault = run(Algorithm::Wagma, plan.clone());
        let wg_loss = (wg_fault.makespan - wg_plain.makespan).max(0.0);
        assert!(
            wg_loss < deadline * post_crash_iters * 0.25,
            "wagma lost {wg_loss}, expected far less than allreduce's {ar_loss}"
        );

        let pa_plain = run(Algorithm::PairAveraging, FaultPlan::none());
        let pa_fault = run(Algorithm::PairAveraging, plan);
        let pa_loss = pa_fault.makespan - pa_plain.makespan;
        assert!(pa_loss > 0.0, "pair averaging must stall on its dead partner");
        assert!(
            pa_loss < ar_loss,
            "pair averaging ({pa_loss}) should lose less than full-barrier allreduce ({ar_loss})"
        );
    }

    /// Detection latency is priced per suspect rank: losing two ranks
    /// costs the synchronous baselines two deadlines per iteration, not
    /// the old flat one — each dead peer is a separate timeout the
    /// membership protocol confirms (ROADMAP elastic follow-up).
    #[test]
    fn detection_deadline_is_charged_per_suspect_rank() {
        use crate::fault::{Crash, FaultPlan};
        let p = 16;
        let steps = 60;
        let crash_at = 30u64;
        let deadline = 0.25;
        let post_crash_iters = (steps as u64 - crash_at) as f64;
        let run = |faults: FaultPlan| {
            simulate(&SimConfig {
                imbalance: ImbalanceModel::Balanced { base: 0.4, jitter: 0.0 },
                steps,
                faults,
                ..base(Algorithm::AllreduceSgd, p)
            })
        };
        let plain = run(FaultPlan::none());
        let one = run(FaultPlan {
            crashes: vec![Crash { rank: 5, at_iter: crash_at }],
            deadline_s: deadline,
            ..FaultPlan::none()
        });
        let two = run(FaultPlan {
            crashes: vec![
                Crash { rank: 5, at_iter: crash_at },
                Crash { rank: 9, at_iter: crash_at },
            ],
            deadline_s: deadline,
            ..FaultPlan::none()
        });
        let one_loss = one.makespan - plain.makespan;
        let two_loss = two.makespan - plain.makespan;
        assert!(
            two_loss >= 2.0 * deadline * post_crash_iters - 1e-6,
            "two suspects must price two deadlines per iter, lost only {two_loss}"
        );
        assert!(
            two_loss >= one_loss + deadline * post_crash_iters - 1e-6,
            "second suspect added only {} over the first's {one_loss}",
            two_loss - one_loss
        );
    }

    /// Dead ranks stop contributing to skew/ideal folds and their lanes
    /// freeze, but survivors keep making progress and makespan stays
    /// monotone in time.
    #[test]
    fn survivors_keep_progressing_after_crash() {
        use crate::fault::{Crash, FaultPlan};
        let cfg = SimConfig {
            steps: 40,
            faults: FaultPlan {
                crashes: vec![Crash { rank: 3, at_iter: 20 }],
                deadline_s: 0.05,
                ..FaultPlan::none()
            },
            ..base(Algorithm::Wagma, 16)
        };
        let r = simulate(&cfg);
        assert!(r.makespan.is_finite() && r.makespan > 0.0);
        assert_eq!(r.iter_times.len(), 40);
        assert!(r.iter_times.iter().all(|t| *t >= -1e-9), "time went backwards");
        // Post-crash iterations still advance the cluster clock.
        let tail: f64 = r.iter_times[20..].iter().sum();
        assert!(tail > 0.0, "no progress after the crash");
    }
}
