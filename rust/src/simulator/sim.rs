//! Per-algorithm timing simulation.
//!
//! State per rank: `app[i]` — when rank i's application finishes iteration
//! t; `engine[i]` — when its communication engine is next free. Each
//! algorithm advances these through its own synchronization structure;
//! compute times come from the imbalance process.

use crate::data::{ImbalanceModel, StepDelays};
use crate::optim::Algorithm;
use crate::simulator::network::NetworkModel;
use crate::topology::{log2_exact, Grouping};
use crate::util::stats::Summary;

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub algo: Algorithm,
    pub p: usize,
    pub steps: usize,
    /// Flat model size in bytes (gradient/model message payload).
    pub model_bytes: usize,
    /// WAGMA/eager τ.
    pub tau: u64,
    /// WAGMA group size (0 = √P).
    pub group_size: usize,
    pub dynamic_groups: bool,
    pub local_sgd_h: u64,
    pub sgp_neighbors: usize,
    pub imbalance: ImbalanceModel,
    pub net: NetworkModel,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            algo: Algorithm::Wagma,
            p: 64,
            steps: 200,
            model_bytes: 25_559_081 * 4, // ResNet-50 f32
            tau: 10,
            group_size: 0,
            dynamic_groups: true,
            local_sgd_h: 1,
            sgp_neighbors: 2,
            imbalance: ImbalanceModel::fig4(),
            net: NetworkModel::aries(),
            seed: 42,
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub algo: String,
    pub p: usize,
    pub steps: usize,
    /// Time at which the last rank finished everything.
    pub makespan: f64,
    /// Makespan with zero communication cost (the paper's "ideal"
    /// rectangle tops).
    pub ideal_makespan: f64,
    /// Per-iteration cluster-wide completion-time deltas.
    pub iter_times: Vec<f64>,
    /// Mean lag (seconds) between fastest and slowest rank entering each
    /// iteration — the straggler-absorption metric.
    pub mean_skew: f64,
}

impl SimResult {
    /// Samples/second with per-rank batch `b`.
    pub fn throughput(&self, b: usize) -> f64 {
        (self.p * b * self.steps) as f64 / self.makespan
    }

    pub fn ideal_throughput(&self, b: usize) -> f64 {
        (self.p * b * self.steps) as f64 / self.ideal_makespan
    }

    pub fn iter_time_summary(&self) -> Summary {
        Summary::of(&self.iter_times)
    }
}

/// Run the timing simulation.
pub fn simulate(cfg: &SimConfig) -> SimResult {
    assert!(cfg.p.is_power_of_two(), "P must be a power of two");
    let p = cfg.p;
    let n = cfg.model_bytes;
    let net = cfg.net;
    let mut delays = StepDelays::new(cfg.imbalance, p, cfg.seed);

    let group_size = if cfg.group_size == 0 {
        Grouping::sqrt_group_size(p)
    } else {
        cfg.group_size
    };
    let grouping = if cfg.dynamic_groups {
        Grouping::new(p, group_size.min(p))
    } else {
        Grouping::fixed(p, group_size.min(p))
    };

    // app[i]: when rank i's app finished iteration t-1 (incl. waiting for
    // the data it needs). engine[i]: when its comm engine is next free.
    let mut app = vec![0.0f64; p];
    let mut engine = vec![0.0f64; p];
    let mut ideal = vec![0.0f64; p];
    let mut iter_times = Vec::with_capacity(cfg.steps);
    let mut skew_acc = 0.0;
    let mut prev_max = 0.0f64;

    for t in 0..cfg.steps {
        let compute = delays.sample_step();
        let start_min = app.iter().cloned().fold(f64::INFINITY, f64::min);
        let start_max = app.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        skew_acc += start_max - start_min;
        for i in 0..p {
            ideal[i] += compute[i];
        }
        // Arrival of each app at the communication call site.
        let mut arrival: Vec<f64> = (0..p).map(|i| app[i] + compute[i]).collect();

        match cfg.algo {
            Algorithm::AllreduceSgd => {
                sync_allreduce_step(&mut app, &arrival, net.allreduce(n, p));
            }
            Algorithm::LocalSgd => {
                let h = cfg.local_sgd_h.max(1);
                if (t as u64 + 1) % h == 0 {
                    sync_allreduce_step(&mut app, &arrival, net.allreduce(n, p));
                } else {
                    app.copy_from_slice(&arrival);
                }
            }
            Algorithm::DPsgd => {
                // Paper §II-B: "processes advance synchronously with a
                // single global clock" — every iteration starts when the
                // slowest rank arrives; communication is only the two
                // neighbor exchanges.
                let cost = 2.0 * net.exchange(n, 3);
                let start = arrival.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                for a in app.iter_mut() {
                    *a = start + cost;
                }
            }
            Algorithm::Sgp => {
                // SGP is likewise synchronous per iteration (Table I:
                // staleness "none"); k directed pushes per step.
                let k = cfg.sgp_neighbors.max(1);
                let _ = log2_exact(p); // graph validity
                let cost = k as f64 * net.exchange(n, k + 1);
                let start = arrival.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                for a in app.iter_mut() {
                    *a = start + cost;
                }
            }
            Algorithm::AdPsgd => {
                // Fully asynchronous: communication overlaps compute; the
                // only residual cost is the atomic pairwise blend (payload
                // serialization at the receiving host, not overlappable).
                let blend = n as f64 * net.gamma;
                for i in 0..p {
                    app[i] = arrival[i] + blend;
                }
            }
            Algorithm::Wagma | Algorithm::EagerSgd => {
                let s = if cfg.algo == Algorithm::EagerSgd { p } else { group_size };
                let is_sync = cfg.tau != 0 && (t as u64 + 1) % cfg.tau == 0;
                if is_sync {
                    let cost = net.allreduce(n, p);
                    let start = arrival.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    for i in 0..p {
                        app[i] = start + cost;
                        engine[i] = app[i];
                    }
                } else {
                    wait_avoiding_group_step(
                        &mut app,
                        &mut engine,
                        &mut arrival,
                        &grouping,
                        s,
                        t as u64,
                        n,
                        &net,
                        p,
                    );
                }
            }
        }
        let cur_max = app.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        iter_times.push(cur_max - prev_max);
        prev_max = cur_max;
    }

    SimResult {
        algo: cfg.algo.name().to_string(),
        p,
        steps: cfg.steps,
        makespan: prev_max,
        ideal_makespan: ideal.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        iter_times,
        mean_skew: skew_acc / cfg.steps as f64,
    }
}

/// Synchronous allreduce: everyone starts when the slowest arrives.
fn sync_allreduce_step(app: &mut [f64], arrival: &[f64], cost: f64) {
    let start = arrival.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    for a in app.iter_mut() {
        *a = start + cost;
    }
}

/// One wait-avoiding group allreduce iteration (the paper's §III
/// semantics at the timing level):
///
/// * the first app arrival activates the collective; activation reaches
///   every engine after the binomial-tree latency;
/// * an engine joins at `max(engine_free, min(own app arrival, activation))`
///   — i.e. a busy app does NOT delay its engine (passive, stale
///   contribution), which is exactly the wait-avoidance;
/// * `log2(S)` butterfly phases relax pairwise with the dynamic grouping's
///   partners;
/// * the app continues at `max(own arrival, own engine completion)` — for
///   stragglers the collective is already done when they arrive.
#[allow(clippy::too_many_arguments)]
fn wait_avoiding_group_step(
    app: &mut [f64],
    engine: &mut [f64],
    arrival: &mut [f64],
    grouping: &Grouping,
    s: usize,
    t: u64,
    n: usize,
    net: &NetworkModel,
    p: usize,
) {
    let activator = arrival.iter().cloned().fold(f64::INFINITY, f64::min);
    let act = activator + net.activation(p);
    // Engine join times.
    let mut times: Vec<f64> = (0..p)
        .map(|i| engine[i].max(arrival[i].min(act)))
        .collect();
    // Butterfly phases within the group (partners via dynamic grouping; for
    // eager-SGD s == p and the grouping covers the full hypercube rotation,
    // so use plain recursive doubling masks in that case).
    let phases = log2_exact(s.min(p));
    let cost = net.exchange(n, s.min(p));
    for r in 0..phases {
        let prev = times.clone();
        for i in 0..p {
            let partner = if s >= p {
                i ^ (1usize << r)
            } else {
                grouping.partner(i, t, r)
            };
            times[i] = prev[i].max(prev[partner]) + cost;
        }
    }
    for i in 0..p {
        engine[i] = times[i];
        app[i] = arrival[i].max(times[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ImbalanceModel;

    fn base(algo: Algorithm, p: usize) -> SimConfig {
        SimConfig { algo, p, steps: 100, seed: 7, ..Default::default() }
    }

    #[test]
    fn balanced_workload_all_algos_near_ideal_plus_comm() {
        // With zero imbalance, every algorithm's makespan = ideal + comm.
        for algo in Algorithm::all() {
            let cfg = SimConfig {
                imbalance: ImbalanceModel::Balanced { base: 0.4, jitter: 0.0 },
                ..base(algo, 16)
            };
            let r = simulate(&cfg);
            assert!(
                r.makespan >= r.ideal_makespan,
                "{}: makespan below ideal",
                algo.name()
            );
            assert!(
                r.makespan < r.ideal_makespan * 1.6,
                "{}: overhead too large: {} vs {}",
                algo.name(),
                r.makespan,
                r.ideal_makespan
            );
        }
    }

    #[test]
    fn wagma_beats_synchronous_under_stragglers() {
        // Fig. 4 protocol: WAGMA must outperform Allreduce/local/D-PSGD/SGP,
        // and lose only to AD-PSGD.
        let p = 64;
        let thr = |algo: Algorithm| {
            let r = simulate(&SimConfig { imbalance: ImbalanceModel::fig4(), ..base(algo, p) });
            r.throughput(128)
        };
        let wagma = thr(Algorithm::Wagma);
        let allreduce = thr(Algorithm::AllreduceSgd);
        let local = thr(Algorithm::LocalSgd);
        let dpsgd = thr(Algorithm::DPsgd);
        let sgp = thr(Algorithm::Sgp);
        let adpsgd = thr(Algorithm::AdPsgd);
        let eager = thr(Algorithm::EagerSgd);
        assert!(wagma > allreduce, "wagma {wagma} vs allreduce {allreduce}");
        assert!(wagma > local, "wagma {wagma} vs local {local}");
        assert!(wagma > dpsgd, "wagma {wagma} vs dpsgd {dpsgd}");
        assert!(wagma > sgp, "wagma {wagma} vs sgp {sgp}");
        assert!(wagma > eager * 0.99, "wagma {wagma} vs eager {eager}");
        assert!(adpsgd > wagma, "adpsgd {adpsgd} vs wagma {wagma}");
    }

    #[test]
    fn speedup_grows_with_scale() {
        // Paper: WAGMA's advantage over Allreduce-SGD grows with node
        // count (1.25x at 64 → 1.37x at 256 measured). Our α-β-contention
        // model reproduces the growth through P=64 and saturates at larger
        // P (documented in EXPERIMENTS.md): assert growth in the 4→64
        // region and no collapse afterwards.
        let ratio = |p: usize| {
            let w = simulate(&SimConfig {
                imbalance: ImbalanceModel::fig4(),
                ..base(Algorithm::Wagma, p)
            });
            let a = simulate(&SimConfig {
                imbalance: ImbalanceModel::fig4(),
                ..base(Algorithm::AllreduceSgd, p)
            });
            w.throughput(128) / a.throughput(128)
        };
        let r4 = ratio(4);
        let r16 = ratio(16);
        let r64 = ratio(64);
        let r256 = ratio(256);
        assert!(r16 > r4, "speedup must grow 4→16: {r4} -> {r16}");
        assert!(r64 > r16 * 0.98, "speedup must not shrink 16→64: {r16} -> {r64}");
        assert!(r64 > 1.2, "64-node speedup {r64}");
        assert!(r256 > r64 * 0.9, "no collapse at 256: {r64} -> {r256}");
    }

    #[test]
    fn straggler_skew_absorbed_by_wagma_but_not_allreduce() {
        let mk = |algo| SimConfig { imbalance: ImbalanceModel::fig9(), ..base(algo, 32) };
        let w = simulate(&mk(Algorithm::Wagma));
        let a = simulate(&mk(Algorithm::AllreduceSgd));
        // Allreduce's apps all enter each iteration together (skew 0);
        // WAGMA lets fast ranks run ahead between syncs.
        assert!(a.mean_skew < 1e-9, "allreduce skew {}", a.mean_skew);
        assert!(w.mean_skew > 0.1, "wagma skew {}", w.mean_skew);
        // But WAGMA's makespan is still smaller.
        assert!(w.makespan < a.makespan);
    }

    #[test]
    fn tau_controls_barrier_frequency() {
        // Smaller τ = more global barriers = slower under imbalance.
        let mk = |tau| SimConfig {
            imbalance: ImbalanceModel::fig9(),
            tau,
            ..base(Algorithm::Wagma, 32)
        };
        let t2 = simulate(&mk(2)).makespan;
        let t10 = simulate(&mk(10)).makespan;
        let t0 = simulate(&mk(0)).makespan; // never sync
        assert!(t10 < t2, "tau=10 {t10} vs tau=2 {t2}");
        assert!(t0 <= t10 * 1.01, "tau=0 {t0} vs tau=10 {t10}");
    }

    #[test]
    fn group_size_tradeoff() {
        // Larger groups cost more per iteration (ablation ❸: S=P drops
        // throughput 1.24x in the paper).
        let mk = |s| SimConfig {
            imbalance: ImbalanceModel::fig4(),
            group_size: s,
            ..base(Algorithm::Wagma, 64)
        };
        let s8 = simulate(&mk(8)).throughput(128);
        let s64 = simulate(&mk(64)).throughput(128);
        assert!(s8 > s64, "S=8 {s8} vs S=64 {s64}");
        let drop = s8 / s64;
        assert!(drop > 1.05 && drop < 2.0, "throughput drop {drop}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = simulate(&base(Algorithm::Wagma, 16));
        let b = simulate(&base(Algorithm::Wagma, 16));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.iter_times, b.iter_times);
    }

    #[test]
    fn scales_to_1024_ranks() {
        let cfg = SimConfig {
            imbalance: ImbalanceModel::fig9(),
            model_bytes: 8_476_421 * 4,
            steps: 50,
            ..base(Algorithm::Wagma, 1024)
        };
        let r = simulate(&cfg);
        assert!(r.makespan.is_finite() && r.makespan > 0.0);
        assert_eq!(r.iter_times.len(), 50);
    }
}
