//! α-β network cost model.
//!
//! A point-to-point message of `n` bytes costs `alpha + n * beta` seconds
//! (Hockney). Defaults are calibrated to a Cray-Aries-class interconnect
//! (the paper's testbed): ~1.5 µs MPI latency, ~10 GB/s effective
//! per-link bandwidth.

/// Hockney α-β model with a first-order contention term.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer time (seconds/byte).
    pub beta: f64,
    /// Per-byte reduction compute (seconds/byte) — the γ term for
    /// elementwise sums on the host.
    pub gamma: f64,
    /// Contention factor: effective per-byte cost inside a collective with
    /// `k` participants is `beta * (1 + contention * log2(k))`, modelling
    /// the bandwidth degradation of concurrent bulk flows on a shared
    /// dragonfly fabric (paper §III: "growing process counts will reduce
    /// the parallel efficiency"). This is what makes *group* collectives
    /// (small k) cheaper per byte than global ones, beyond phase count.
    pub contention: f64,
    /// Per-byte (de)compression compute (seconds/byte) — the δ term. A
    /// compressed exchange pays `delta` once per **raw** byte on each side
    /// (encode reads the raw payload, decode writes it back), so shrinking
    /// the wire volume is only worth it when
    /// `wire·β_eff + 2·raw·δ < raw·β_eff` — the tradeoff
    /// [`crate::sched::FusionPlan::mgwfbp_compressed`] and the simulator
    /// price explicitly.
    pub delta: f64,
}

/// Ceiling of log2(p) — the butterfly/recursive-doubling phase count for
/// any `p >= 2` (non-powers-of-two pay a full extra phase, as in MPI's
/// pre/post-processed recursive doubling).
fn ceil_log2(p: usize) -> u32 {
    debug_assert!(p >= 1);
    usize::BITS - (p - 1).leading_zeros()
}

impl NetworkModel {
    /// Aries-like defaults (Piz Daint): α = 1.5 µs, 10 GB/s, ~8 GB/s
    /// reduction rate, mild contention growth, ~20 GB/s single-core
    /// codec throughput (top-k selection / int8 pack measured on Xeon-class
    /// hosts lands in the 15–30 GB/s band).
    pub fn aries() -> NetworkModel {
        NetworkModel {
            alpha: 1.5e-6,
            beta: 1.0 / 10e9,
            gamma: 1.0 / 8e9,
            contention: 0.12,
            delta: 1.0 / 20e9,
        }
    }

    fn beta_eff(&self, participants: usize) -> f64 {
        let k = participants.max(1) as f64;
        self.beta * (1.0 + self.contention * k.log2())
    }

    /// Cost of one point-to-point message of `bytes` (no collective
    /// contention).
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 * self.beta
    }

    /// Cost of one butterfly exchange phase on `bytes` (sendrecv + local
    /// reduction) inside a collective of `participants` ranks.
    pub fn exchange(&self, bytes: usize, participants: usize) -> f64 {
        self.alpha + bytes as f64 * (self.beta_eff(participants) + self.gamma)
    }

    /// One compressed butterfly exchange phase: `wire_bytes` travel and
    /// are reduced, and each side pays the δ codec term on the **raw**
    /// payload (encode our contribution + decode the partner's).
    pub fn exchange_compressed(
        &self,
        raw_bytes: usize,
        wire_bytes: usize,
        participants: usize,
    ) -> f64 {
        self.exchange(wire_bytes, participants) + 2.0 * self.delta * raw_bytes as f64
    }

    /// Recursive-doubling allreduce cost for `bytes` over `p` ranks,
    /// assuming synchronized arrival: `⌈log2(P)⌉ * exchange(N)`.
    /// Non-powers-of-two pay the extra fold-in phase (the old
    /// `trailing_zeros` form under-counted — one phase for p = 6).
    pub fn allreduce_rd(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        ceil_log2(p) as f64 * self.exchange(bytes, p)
    }

    /// Ring allreduce cost: `2 (P-1)` steps of `N/P` bytes.
    pub fn allreduce_ring(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let chunk = bytes as f64 / p as f64;
        2.0 * (p - 1) as f64 * (self.alpha + chunk * (self.beta_eff(p) + self.gamma))
    }

    /// Compressed ring allreduce: `2 (P-1)` steps whose segments travel at
    /// `wire/P` bytes while the codec runs over the raw `N/P` segment on
    /// both sides of every step (encode before send, decode-sum/adopt on
    /// receive) — the engine's compressed τ-sync schedule.
    pub fn allreduce_ring_compressed(
        &self,
        raw_bytes: usize,
        wire_bytes: usize,
        p: usize,
    ) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let raw_seg = raw_bytes as f64 / p as f64;
        let wire_seg = wire_bytes as f64 / p as f64;
        2.0 * (p - 1) as f64
            * (self.alpha + wire_seg * (self.beta_eff(p) + self.gamma) + 2.0 * self.delta * raw_seg)
    }

    /// Best-of allreduce (what a tuned MPI would pick).
    pub fn allreduce(&self, bytes: usize, p: usize) -> f64 {
        self.allreduce_rd(bytes, p).min(self.allreduce_ring(bytes, p))
    }

    /// Best-of compressed allreduce: recursive doubling on wire-sized full
    /// payloads vs the compressed ring, both carrying the δ codec term.
    pub fn allreduce_compressed(&self, raw_bytes: usize, wire_bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let rd = ceil_log2(p) as f64 * self.exchange_compressed(raw_bytes, wire_bytes, p);
        rd.min(self.allreduce_ring_compressed(raw_bytes, wire_bytes, p))
    }

    /// Binomial-tree activation latency to depth `⌈log2(P)⌉`.
    pub fn activation(&self, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        ceil_log2(p) as f64 * self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_scale_sanely() {
        let net = NetworkModel::aries();
        // 100 MB allreduce over 64 ranks: ring must beat recursive doubling.
        let bytes = 100 << 20;
        assert!(net.allreduce_ring(bytes, 64) < net.allreduce_rd(bytes, 64));
        // Tiny payload: recursive doubling wins (latency-bound).
        assert!(net.allreduce_rd(64, 64) < net.allreduce_ring(64, 64));
        // Costs grow with P (recursive doubling) and with size.
        assert!(net.allreduce_rd(1 << 20, 256) > net.allreduce_rd(1 << 20, 16));
        assert!(net.p2p(1 << 20) > net.p2p(1 << 10));
        assert_eq!(net.allreduce(123, 1), 0.0);
    }

    /// Regression (ISSUE 3 satellite): `trailing_zeros` gave p = 6 a
    /// single phase; recursive doubling needs ⌈log2(p)⌉ = 3.
    #[test]
    fn rd_phase_count_for_non_power_of_two() {
        let net = NetworkModel::aries();
        let bytes = 1 << 20;
        let per_phase = net.exchange(bytes, 6);
        assert!((net.allreduce_rd(bytes, 6) - 3.0 * per_phase).abs() < 1e-12);
        // Monotone in p across the power-of-two boundary.
        assert!(net.allreduce_rd(bytes, 6) >= net.allreduce_rd(bytes, 4));
        assert!(net.allreduce_rd(bytes, 6) <= net.allreduce_rd(bytes, 8) + 1e-12);
        // Powers of two unchanged: log2 phases exactly.
        assert!((net.allreduce_rd(bytes, 8) - 3.0 * net.exchange(bytes, 8)).abs() < 1e-12);
        assert_eq!(net.allreduce_rd(bytes, 1), 0.0);
        // p = 2 is one phase.
        assert!((net.allreduce_rd(bytes, 2) - net.exchange(bytes, 2)).abs() < 1e-12);
    }

    #[test]
    fn compressed_costs_trade_codec_for_bandwidth() {
        let net = NetworkModel::aries();
        // Bucket-sized payload at a 5x wire reduction: the δ term is paid
        // but the bandwidth saving dominates.
        let raw = 8 << 20;
        let wire = raw / 5;
        assert!(net.exchange_compressed(raw, wire, 8) < net.exchange(raw, 8));
        assert!(net.allreduce_ring_compressed(raw, wire, 64) < net.allreduce_ring(raw, 64));
        assert!(net.allreduce_compressed(raw, wire, 64) < net.allreduce(raw, 64));
        // Degenerate wire == raw: compression only adds the codec cost.
        let t = net.exchange_compressed(raw, raw, 8);
        assert!((t - (net.exchange(raw, 8) + 2.0 * net.delta * raw as f64)).abs() < 1e-12);
        // Tiny payload: latency-bound either way, compressed never wins by
        // much and never goes negative.
        assert!(net.exchange_compressed(64, 16, 8) > 0.0);
        assert_eq!(net.allreduce_compressed(1024, 256, 1), 0.0);
    }

    #[test]
    fn aries_magnitudes() {
        // ResNet-50 (102 MB) allreduce on 64 nodes should land in the
        // tens-of-milliseconds range, matching published measurements.
        let net = NetworkModel::aries();
        let t = net.allreduce(102 << 20, 64);
        assert!(t > 0.01 && t < 0.2, "allreduce time {t}");
        // Activation is microseconds even at 1024 ranks.
        assert!(net.activation(1024) < 1e-4);
    }
}
