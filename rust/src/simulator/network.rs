//! α-β network cost model.
//!
//! A point-to-point message of `n` bytes costs `alpha + n * beta` seconds
//! (Hockney). Defaults are calibrated to a Cray-Aries-class interconnect
//! (the paper's testbed): ~1.5 µs MPI latency, ~10 GB/s effective
//! per-link bandwidth.

/// Hockney α-β model with a first-order contention term.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer time (seconds/byte).
    pub beta: f64,
    /// Per-byte reduction compute (seconds/byte) — the γ term for
    /// elementwise sums on the host.
    pub gamma: f64,
    /// Contention factor: effective per-byte cost inside a collective with
    /// `k` participants is `beta * (1 + contention * log2(k))`, modelling
    /// the bandwidth degradation of concurrent bulk flows on a shared
    /// dragonfly fabric (paper §III: "growing process counts will reduce
    /// the parallel efficiency"). This is what makes *group* collectives
    /// (small k) cheaper per byte than global ones, beyond phase count.
    pub contention: f64,
}

impl NetworkModel {
    /// Aries-like defaults (Piz Daint): α = 1.5 µs, 10 GB/s, ~8 GB/s
    /// reduction rate, mild contention growth.
    pub fn aries() -> NetworkModel {
        NetworkModel { alpha: 1.5e-6, beta: 1.0 / 10e9, gamma: 1.0 / 8e9, contention: 0.12 }
    }

    fn beta_eff(&self, participants: usize) -> f64 {
        let k = participants.max(1) as f64;
        self.beta * (1.0 + self.contention * k.log2())
    }

    /// Cost of one point-to-point message of `bytes` (no collective
    /// contention).
    pub fn p2p(&self, bytes: usize) -> f64 {
        self.alpha + bytes as f64 * self.beta
    }

    /// Cost of one butterfly exchange phase on `bytes` (sendrecv + local
    /// reduction) inside a collective of `participants` ranks.
    pub fn exchange(&self, bytes: usize, participants: usize) -> f64 {
        self.alpha + bytes as f64 * (self.beta_eff(participants) + self.gamma)
    }

    /// Recursive-doubling allreduce cost for `bytes` over `p` ranks,
    /// assuming synchronized arrival: `log2(P) * exchange(N)`.
    pub fn allreduce_rd(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        (p.trailing_zeros() as f64) * self.exchange(bytes, p)
    }

    /// Ring allreduce cost: `2 (P-1)` steps of `N/P` bytes.
    pub fn allreduce_ring(&self, bytes: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let chunk = bytes as f64 / p as f64;
        2.0 * (p - 1) as f64 * (self.alpha + chunk * (self.beta_eff(p) + self.gamma))
    }

    /// Best-of allreduce (what a tuned MPI would pick).
    pub fn allreduce(&self, bytes: usize, p: usize) -> f64 {
        self.allreduce_rd(bytes, p).min(self.allreduce_ring(bytes, p))
    }

    /// Binomial-tree activation latency to depth `log2(P)`.
    pub fn activation(&self, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        p.trailing_zeros() as f64 * self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_scale_sanely() {
        let net = NetworkModel::aries();
        // 100 MB allreduce over 64 ranks: ring must beat recursive doubling.
        let bytes = 100 << 20;
        assert!(net.allreduce_ring(bytes, 64) < net.allreduce_rd(bytes, 64));
        // Tiny payload: recursive doubling wins (latency-bound).
        assert!(net.allreduce_rd(64, 64) < net.allreduce_ring(64, 64));
        // Costs grow with P (recursive doubling) and with size.
        assert!(net.allreduce_rd(1 << 20, 256) > net.allreduce_rd(1 << 20, 16));
        assert!(net.p2p(1 << 20) > net.p2p(1 << 10));
        assert_eq!(net.allreduce(123, 1), 0.0);
    }

    #[test]
    fn aries_magnitudes() {
        // ResNet-50 (102 MB) allreduce on 64 nodes should land in the
        // tens-of-milliseconds range, matching published measurements.
        let net = NetworkModel::aries();
        let t = net.allreduce(102 << 20, 64);
        assert!(t > 0.01 && t < 0.2, "allreduce time {t}");
        // Activation is microseconds even at 1024 ranks.
        assert!(net.activation(1024) < 1e-4);
    }
}
