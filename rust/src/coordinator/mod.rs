//! Coordinator façade — the paper's L3 contribution gathered behind one
//! import path.
//!
//! Training a model with wait-avoiding group averaging touches four
//! subsystems: the collective engine (wait-avoiding group allreduce,
//! §III-A), the dynamic grouping strategy (Algorithm 1), the optimizer
//! runner (Algorithm 2 and the baselines), and — since the fusion PR — the
//! scheduling layer that plans bucketed, overlap-friendly exchanges. This
//! module re-exports the scheduler-facing coordination API so embedders
//! can write `use wagma::coordinator::*;` without learning the internal
//! module layout.

pub use crate::collectives::engine::{
    ActivationMode, CollectiveEngine, EngineConfig, EngineStats, GroupResult, StalenessStats,
};
pub use crate::comm::{BufferPool, Chunk, PoolStats, SharedBuf};
pub use crate::optim::{run_training, Algorithm, EngineFactory, TrainConfig};
pub use crate::sched::{
    schedule_iteration, FusionConfig, FusionMode, FusionPlan, LayerProfile, Timeline,
};
pub use crate::topology::{BinomialTree, Grouping};

#[cfg(test)]
mod tests {
    use super::*;

    /// The façade exposes a coherent, compilable API surface.
    #[test]
    fn facade_reexports_are_usable() {
        let cfg = TrainConfig::default();
        assert_eq!(cfg.algo, Algorithm::Wagma);
        assert_eq!(Grouping::sqrt_group_size(64), 8);
        let profile = LayerProfile::for_model_bytes(1 << 20);
        let fusion = FusionConfig { layered: true, mode: FusionMode::Threshold, ..Default::default() };
        let plan = FusionPlan::threshold(&profile, fusion.threshold_bytes);
        plan.validate(&profile).unwrap();
        let costs: Vec<f64> = plan.buckets.iter().map(|_| 0.001).collect();
        let tl: Timeline = schedule_iteration(&plan, 0.1, &costs, 0.0);
        assert!(tl.makespan >= tl.compute_end);
        assert_eq!(ActivationMode::Solo, ActivationMode::Solo);
    }
}
