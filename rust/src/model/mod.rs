//! Model-side data structures: typed batch arguments for the flat ABI and
//! the per-worker optimizer state.

use anyhow::Result;

/// One data argument for an AOT executable (row-major).
#[derive(Debug, Clone, PartialEq)]
pub enum DataArg {
    F32 { shape: Vec<usize>, values: Vec<f32> },
    I32 { shape: Vec<usize>, values: Vec<i32> },
}

impl DataArg {
    pub fn f32(shape: Vec<usize>, values: Vec<f32>) -> DataArg {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        DataArg::F32 { shape, values }
    }

    pub fn i32(shape: Vec<usize>, values: Vec<i32>) -> DataArg {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        DataArg::I32 { shape, values }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            DataArg::F32 { shape, .. } | DataArg::I32 { shape, .. } => shape,
        }
    }

    /// Convert to an XLA literal of the right shape/dtype.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            DataArg::F32 { values, .. } => xla::Literal::vec1(values),
            DataArg::I32 { values, .. } => xla::Literal::vec1(values),
        };
        // Rank-1 literals pass through; higher ranks are reshaped.
        if dims.len() == 1 {
            Ok(lit)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }
}

/// A training minibatch: the data arguments in ABI order (between the
/// parameter/momentum inputs and the trailing learning rate).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Batch {
    pub args: Vec<DataArg>,
}

impl Batch {
    pub fn new(args: Vec<DataArg>) -> Batch {
        Batch { args }
    }
}

/// Per-worker training state: the flat parameter vector plus optimizer
/// (momentum) state — everything the collectives average lives here.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
}

impl WorkerState {
    pub fn new(params: Vec<f32>) -> WorkerState {
        let n = params.len();
        WorkerState { params, momentum: vec![0.0; n] }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_arg_shapes() {
        let a = DataArg::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(a.shape(), &[2, 3]);
        let b = DataArg::i32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(b.shape(), &[4]);
    }

    #[test]
    #[should_panic]
    fn data_arg_size_mismatch_panics() {
        DataArg::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn worker_state_momentum_zeroed() {
        let s = WorkerState::new(vec![1.0, 2.0]);
        assert_eq!(s.momentum, vec![0.0, 0.0]);
        assert_eq!(s.dim(), 2);
    }
}
