//! Layer-aware gradient fusion & communication-overlap scheduling.
//!
//! The seed modelled every exchange as one flat `model_bytes` blob fired
//! after compute finished. Real training overlaps communication with
//! backpropagation: gradients become available layer by layer (output
//! first), get merged into fused buckets (MG-WFBP, Shi et al.), and each
//! bucket's collective is issued as soon as its layers are ready — hiding
//! most communication under the remaining backward pass (DaSGD, Zhou et
//! al.). This subsystem models that pipeline:
//!
//! * [`profile`] — [`LayerProfile`]: per-layer parameter sizes and backprop
//!   completion fractions for the three paper workloads (ResNet-50,
//!   transformer LM, PPO policy), derived from `python/compile/model.py`
//!   shapes and pinned to the presets' exact flat payload sizes.
//! * [`fusion`] — [`FusionPlan`]: greedy size-threshold fusion and the
//!   MG-WFBP optimal merge pass over the [`crate::simulator::NetworkModel`]
//!   cost function, plus [`FusionConfig`] (the `layered` / `fusion_mode` /
//!   `fusion_threshold_bytes` knobs threaded through preset, TOML, and CLI
//!   parsing).
//! * [`overlap`] — [`schedule_iteration`]: the per-iteration timeline of
//!   (bucket ready → collective start → finish) events and its makespan.
//!
//! Consumers: the discrete-event simulator's layered mode
//! ([`crate::simulator::sim`]) embeds the same recurrence with per-rank
//! ready/engine coupling; the collective engine
//! ([`crate::collectives::engine`]) accepts chunked exchanges at the plan's
//! bucket granularity; `benches/fusion_overlap.rs` and the `fusion` figure
//! hook quantify the makespan reduction against the flat baseline.

pub mod fusion;
pub mod overlap;
pub mod profile;

pub use fusion::{Bucket, FusionConfig, FusionMode, FusionPlan};
pub use overlap::{flat_makespan, schedule_iteration, BucketEvent, Timeline};
pub use profile::{Layer, LayerProfile};
