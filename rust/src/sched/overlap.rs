//! Communication-overlap scheduling: turn a fusion plan into a
//! per-iteration timeline of bucket events.
//!
//! The model (one rank's view, DaSGD/MG-WFBP-style): backprop runs for
//! `compute_seconds`; bucket `b`'s gradients are ready at
//! `start + compute_seconds * ready_frac(b)`; the communication engine is a
//! single serial resource, so bucket `b` starts at
//! `max(ready(b), finish(b-1))` and finishes after its collective cost.
//! The iteration's makespan is `max(compute end, last bucket finish)` —
//! everything hidden under backprop is free, and only the tail
//! (post-backprop) communication is exposed.
//!
//! The multi-rank discrete-event simulator embeds the same recurrence with
//! per-rank ready/engine times ([`crate::simulator::sim`], layered mode);
//! this single-rank form is what the planner, benches, and figure hooks
//! reason with.

use crate::sched::fusion::FusionPlan;

/// One bucket's lifecycle within an iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BucketEvent {
    pub bucket: usize,
    /// Gradients complete; the bucket may start communicating.
    pub ready: f64,
    /// Collective actually starts (engine may still be busy).
    pub start: f64,
    pub finish: f64,
}

/// The scheduled iteration.
#[derive(Debug, Clone, PartialEq)]
pub struct Timeline {
    pub events: Vec<BucketEvent>,
    pub compute_end: f64,
    pub makespan: f64,
}

impl Timeline {
    /// Communication not hidden by backprop (the exposed tail).
    pub fn comm_tail(&self) -> f64 {
        self.makespan - self.compute_end
    }

    /// Total busy time of the communication engine.
    pub fn comm_busy(&self) -> f64 {
        self.events.iter().map(|e| e.finish - e.start).sum()
    }
}

/// Schedule one iteration: `costs[b]` is the collective cost of bucket `b`
/// (e.g. `net.allreduce(bytes, p)` or the group butterfly cost).
pub fn schedule_iteration(
    plan: &FusionPlan,
    compute_seconds: f64,
    costs: &[f64],
    start: f64,
) -> Timeline {
    assert_eq!(costs.len(), plan.buckets.len(), "one cost per bucket");
    let compute_end = start + compute_seconds;
    let mut events = Vec::with_capacity(plan.buckets.len());
    let mut engine_free = start;
    for (b, bucket) in plan.buckets.iter().enumerate() {
        let ready = start + compute_seconds * bucket.ready_frac;
        let begin = ready.max(engine_free);
        let finish = begin + costs[b];
        events.push(BucketEvent { bucket: b, ready, start: begin, finish });
        engine_free = finish;
    }
    let makespan = compute_end.max(engine_free);
    Timeline { events, compute_end, makespan }
}

/// The flat (unfused, unoverlapped) reference: all communication starts
/// after backprop completes.
pub fn flat_makespan(compute_seconds: f64, total_cost: f64, start: f64) -> f64 {
    start + compute_seconds + total_cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::fusion::{FusionConfig, FusionMode, FusionPlan};
    use crate::sched::profile::LayerProfile;
    use crate::simulator::NetworkModel;

    fn costs(plan: &FusionPlan, net: &NetworkModel, p: usize) -> Vec<f64> {
        plan.buckets.iter().map(|b| net.allreduce(b.bytes, p)).collect()
    }

    #[test]
    fn single_bucket_equals_flat() {
        let profile = LayerProfile::resnet50();
        let plan = FusionPlan::flat(&profile);
        let net = NetworkModel::aries();
        let c = costs(&plan, &net, 64);
        let tl = schedule_iteration(&plan, 0.4, &c, 10.0);
        assert_eq!(tl.makespan, flat_makespan(0.4, c[0], 10.0));
        assert_eq!(tl.events.len(), 1);
        assert_eq!(tl.events[0].ready, 10.4);
    }

    #[test]
    fn overlap_beats_flat_on_fig4_shape() {
        let profile = LayerProfile::resnet50();
        let net = NetworkModel::aries();
        let cfg = FusionConfig { layered: true, ..Default::default() };
        let plan = FusionPlan::build(&profile, &cfg, &net, 64, 0.4);
        let c = costs(&plan, &net, 64);
        let tl = schedule_iteration(&plan, 0.4, &c, 0.0);
        let flat = flat_makespan(0.4, net.allreduce(profile.total_bytes(), 64), 0.0);
        assert!(
            tl.makespan < flat,
            "overlap {} must beat flat {flat}",
            tl.makespan
        );
        // Most communication hides under backprop: the exposed tail is a
        // small fraction of the flat communication cost.
        assert!(tl.comm_tail() < 0.5 * net.allreduce(profile.total_bytes(), 64));
        assert!(tl.makespan >= tl.compute_end);
    }

    #[test]
    fn engine_serializes_buckets() {
        let profile = LayerProfile::synthetic(40_000_000, 10);
        let plan = FusionPlan::threshold(&profile, 4_000_000);
        let net = NetworkModel::aries();
        let c = costs(&plan, &net, 16);
        let tl = schedule_iteration(&plan, 0.1, &c, 0.0);
        for w in tl.events.windows(2) {
            assert!(w[1].start >= w[0].finish - 1e-15, "engine overlap within itself");
            assert!(w[1].ready >= w[0].ready - 1e-15, "ready order");
        }
        for e in &tl.events {
            assert!(e.start >= e.ready);
            assert!(e.finish > e.start);
        }
    }

    #[test]
    fn mgwfbp_timeline_not_worse_than_threshold() {
        let profile = LayerProfile::resnet50();
        let net = NetworkModel::aries();
        let compute = 0.4;
        let thr = FusionPlan::threshold(&profile, FusionConfig::default().threshold_bytes);
        let opt = FusionPlan::mgwfbp(&profile, &net, 64, compute);
        assert_eq!(opt.mode, FusionMode::MgWfbp);
        let thr_tl = schedule_iteration(&thr, compute, &costs(&thr, &net, 64), 0.0);
        let opt_tl = schedule_iteration(&opt, compute, &costs(&opt, &net, 64), 0.0);
        // The DP optimizes exactly this recurrence, so it can never lose.
        assert!(
            opt_tl.makespan <= thr_tl.makespan + 1e-12,
            "mgwfbp {} vs threshold {}",
            opt_tl.makespan,
            thr_tl.makespan
        );
    }
}
