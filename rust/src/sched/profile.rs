//! Per-layer model profiles for the fusion planner.
//!
//! A [`LayerProfile`] lists a model's parameter tensors **in backpropagation
//! completion order** (output layer first — its gradient is the first one
//! available during the backward pass) together with a relative compute
//! weight per layer. From the weights we derive `ready_frac[j]`: the
//! fraction of the iteration's backprop time after which layer `j`'s
//! gradient bucket may start communicating. This is the timing substrate
//! MG-WFBP-style fusion planning needs (Shi et al.: merged-gradient
//! wait-free backpropagation).
//!
//! The three paper workloads are modelled structurally from
//! `python/compile/model.py` shapes (transformer blocks, MLP classifier,
//! PPO policy/value net) and from the standard ResNet-50 bottleneck layout,
//! then rescaled so the profile's total byte count matches the preset's
//! flat `model_bytes` exactly — layered and flat simulations move the same
//! number of bytes.

/// One parameter tensor (or fused block of tensors) of the model.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    /// Gradient payload size in bytes (f32 parameters × 4).
    pub bytes: usize,
    /// Relative backprop compute weight (arbitrary units; normalized away).
    pub compute_weight: f64,
}

impl Layer {
    fn params(name: &str, params: usize) -> Layer {
        Layer { name: name.to_string(), bytes: params * 4, compute_weight: params as f64 }
    }
}

/// Layers in backprop completion order plus the derived ready fractions.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfile {
    pub model: String,
    layers: Vec<Layer>,
    /// `ready_frac[j]`: cumulative backprop-time fraction at which layer
    /// `j`'s gradient is complete. Nondecreasing; last element is 1.0.
    ready_frac: Vec<f64>,
}

impl LayerProfile {
    /// Build a profile from layers given in backprop completion order.
    pub fn new(model: &str, layers: Vec<Layer>) -> LayerProfile {
        assert!(!layers.is_empty(), "profile needs at least one layer");
        assert!(layers.iter().all(|l| l.bytes > 0), "zero-byte layer");
        let total: f64 = layers.iter().map(|l| l.compute_weight.max(1e-12)).sum();
        let mut acc = 0.0;
        let ready_frac: Vec<f64> = layers
            .iter()
            .map(|l| {
                acc += l.compute_weight.max(1e-12) / total;
                acc.min(1.0)
            })
            .collect();
        let mut p = LayerProfile { model: model.to_string(), layers, ready_frac };
        // Guard against rounding: the final gradient completes exactly when
        // backprop does.
        if let Some(last) = p.ready_frac.last_mut() {
            *last = 1.0;
        }
        p
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn bytes(&self, j: usize) -> usize {
        self.layers[j].bytes
    }

    pub fn total_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes).sum()
    }

    /// Backprop-completion fraction of layer `j` (nondecreasing in `j`).
    pub fn ready_frac(&self, j: usize) -> f64 {
        self.ready_frac[j]
    }

    /// Rescale layer sizes so `total_bytes() == total` exactly (keeps
    /// 4-byte alignment; the residual lands on the largest layer).
    pub fn scaled_to_bytes(mut self, total: usize) -> LayerProfile {
        assert!(total >= self.layers.len() * 4, "target too small for {} layers", self.layers.len());
        let total = total / 4 * 4; // f32 payloads
        let current = self.total_bytes() as f64;
        let ratio = total as f64 / current;
        for l in self.layers.iter_mut() {
            let scaled = ((l.bytes as f64 * ratio / 4.0).round() as usize).max(1) * 4;
            l.bytes = scaled;
        }
        // Fix rounding drift: add the shortfall to the largest layer, or
        // shave surplus 4-byte words off the largest layers (each layer
        // keeps at least one f32 — total >= 4 * len guarantees termination).
        let now: usize = self.total_bytes();
        if now < total {
            let largest = (0..self.layers.len())
                .max_by_key(|&j| self.layers[j].bytes)
                .unwrap();
            self.layers[largest].bytes += total - now;
        } else {
            let mut excess = now - total;
            while excess > 0 {
                let largest = (0..self.layers.len())
                    .max_by_key(|&j| self.layers[j].bytes)
                    .unwrap();
                let shave = excess.min(self.layers[largest].bytes - 4);
                debug_assert!(shave > 0, "cannot shave below one f32 per layer");
                self.layers[largest].bytes -= shave;
                excess -= shave;
            }
        }
        debug_assert_eq!(self.total_bytes(), total);
        self
    }

    /// ResNet-50 (Fig. 4 workload): stem + 16 bottleneck blocks + fc, in
    /// backprop order (fc first), rescaled to the preset's exact 25,559,081
    /// parameters.
    pub fn resnet50() -> LayerProfile {
        let mut fwd: Vec<Layer> = Vec::new();
        fwd.push(Layer::params("stem_conv7x7", 3 * 64 * 49 + 2 * 64));
        // (blocks, bottleneck width m, output channels w) per stage.
        let stages: [(usize, usize, usize); 4] =
            [(3, 64, 256), (4, 128, 512), (6, 256, 1024), (3, 512, 2048)];
        let mut in_ch = 64usize;
        for (s, &(blocks, m, w)) in stages.iter().enumerate() {
            for b in 0..blocks {
                let mut p = in_ch * m + 2 * m; // conv1 1x1
                p += 9 * m * m + 2 * m; // conv2 3x3
                p += m * w + 2 * w; // conv3 1x1
                if b == 0 {
                    p += in_ch * w + 2 * w; // downsample projection
                }
                fwd.push(Layer::params(&format!("stage{}_block{}", s + 1, b), p));
                in_ch = w;
            }
        }
        fwd.push(Layer::params("fc", 2048 * 1000 + 1000));
        fwd.reverse(); // backprop order: fc first, stem last
        LayerProfile::new("resnet50", fwd).scaled_to_bytes(25_559_081 * 4)
    }

    /// Decoder-only transformer LM (Fig. 7 workload), mirroring the block
    /// structure in `python/compile/model.py` (attention and FFN fused per
    /// block with their layer norms), rescaled to the preset's 61,362,176
    /// parameters.
    pub fn transformer() -> LayerProfile {
        let (vocab, dm, n_layers, seq) = (32_000usize, 512usize, 6usize, 128usize);
        let ff = 4 * dm;
        let mut fwd: Vec<Layer> = Vec::new();
        fwd.push(Layer::params("embedding", vocab * dm + seq * dm));
        for i in 0..n_layers {
            fwd.push(Layer::params(
                &format!("block{i}_attn"),
                2 * dm + dm * 3 * dm + 3 * dm + dm * dm + dm,
            ));
            fwd.push(Layer::params(
                &format!("block{i}_ffn"),
                2 * dm + dm * ff + ff + ff * dm + dm,
            ));
        }
        fwd.push(Layer::params("ln_f_head", 2 * dm));
        fwd.reverse(); // backprop order: head first, embedding last
        LayerProfile::new("transformer", fwd).scaled_to_bytes(61_362_176 * 4)
    }

    /// PPO policy/value net (Fig. 10 workload), mirroring
    /// `python/compile/model.py`'s policy spec (two hidden layers plus the
    /// policy and value heads), rescaled to the preset's 8,476,421
    /// parameters.
    pub fn ppo_policy() -> LayerProfile {
        let (obs, h, actions) = (32usize, 2048usize, 4usize);
        let fwd = vec![
            Layer::params("w1", obs * h + h),
            Layer::params("w2", h * h + h),
            Layer::params("heads", h * actions + actions + h + 1),
        ];
        let mut bwd = fwd;
        bwd.reverse();
        LayerProfile::new("ppo_policy", bwd).scaled_to_bytes(8_476_421 * 4)
    }

    /// Generic geometric pyramid profile for arbitrary payload sizes (used
    /// when `model_bytes` matches no paper workload): `n_layers` layers
    /// whose sizes grow toward the output, summing to `total_bytes`.
    pub fn synthetic(total_bytes: usize, n_layers: usize) -> LayerProfile {
        let n_layers = n_layers.max(1).min(total_bytes / 4).max(1);
        let growth = 1.15f64;
        let fwd: Vec<Layer> = (0..n_layers)
            .map(|j| {
                let w = growth.powi(j as i32);
                Layer { name: format!("layer{j}"), bytes: 4, compute_weight: w }
            })
            .collect();
        let mut bwd: Vec<Layer> = fwd;
        bwd.reverse();
        // Assign bytes proportional to compute weight, then rescale exact.
        let total_w: f64 = bwd.iter().map(|l| l.compute_weight).sum();
        for l in bwd.iter_mut() {
            l.bytes = (((l.compute_weight / total_w) * total_bytes as f64 / 4.0).round() as usize)
                .max(1)
                * 4;
        }
        LayerProfile::new("synthetic", bwd).scaled_to_bytes(total_bytes.max(n_layers * 4))
    }

    /// Pick the profile matching a flat payload size: the three paper
    /// workloads are recognized by their exact byte counts; anything else
    /// gets a synthetic pyramid of the same total size.
    pub fn for_model_bytes(model_bytes: usize) -> LayerProfile {
        match model_bytes {
            b if b == 25_559_081 * 4 => LayerProfile::resnet50(),
            b if b == 61_362_176 * 4 => LayerProfile::transformer(),
            b if b == 8_476_421 * 4 => LayerProfile::ppo_policy(),
            b => LayerProfile::synthetic(b, 32),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_profiles_match_preset_totals() {
        assert_eq!(LayerProfile::resnet50().total_bytes(), 25_559_081 * 4);
        assert_eq!(LayerProfile::transformer().total_bytes(), 61_362_176 * 4);
        assert_eq!(LayerProfile::ppo_policy().total_bytes(), 8_476_421 * 4);
    }

    #[test]
    fn ready_fracs_are_monotone_and_end_at_one() {
        for p in [
            LayerProfile::resnet50(),
            LayerProfile::transformer(),
            LayerProfile::ppo_policy(),
            LayerProfile::synthetic(1 << 20, 16),
        ] {
            let n = p.len();
            assert!(n >= 3, "{}: {n} layers", p.model);
            for j in 1..n {
                assert!(
                    p.ready_frac(j) >= p.ready_frac(j - 1),
                    "{}: frac not monotone at {j}",
                    p.model
                );
            }
            assert!((p.ready_frac(n - 1) - 1.0).abs() < 1e-12);
            assert!(p.ready_frac(0) > 0.0);
        }
    }

    #[test]
    fn resnet_backprop_order_puts_fc_first() {
        let p = LayerProfile::resnet50();
        assert_eq!(p.layers()[0].name, "fc");
        assert_eq!(p.layers()[p.len() - 1].name, "stem_conv7x7");
        // 1 stem + 16 blocks + 1 fc.
        assert_eq!(p.len(), 18);
    }

    #[test]
    fn scaling_is_exact_and_aligned() {
        let p = LayerProfile::synthetic(10_000_000, 24);
        assert_eq!(p.total_bytes(), 10_000_000);
        assert!(p.layers().iter().all(|l| l.bytes % 4 == 0 && l.bytes >= 4));
    }

    #[test]
    fn for_model_bytes_dispatch() {
        assert_eq!(LayerProfile::for_model_bytes(25_559_081 * 4).model, "resnet50");
        assert_eq!(LayerProfile::for_model_bytes(61_362_176 * 4).model, "transformer");
        assert_eq!(LayerProfile::for_model_bytes(8_476_421 * 4).model, "ppo_policy");
        let s = LayerProfile::for_model_bytes(123_456);
        assert_eq!(s.model, "synthetic");
        assert_eq!(s.total_bytes(), 123_456);
    }
}
