//! Gradient bucket-fusion planning.
//!
//! Given a [`LayerProfile`] (layers in backprop completion order), a plan
//! partitions the layers into contiguous **buckets**, each communicated as
//! one fused collective. Two planners:
//!
//! * [`FusionPlan::threshold`] — greedy size-threshold fusion (the
//!   Horovod/DDP default): accumulate layers until the bucket reaches
//!   `threshold_bytes`, then seal it.
//! * [`FusionPlan::mgwfbp`] — MG-WFBP-style optimal merge (Shi et al.): a
//!   dynamic program over the [`NetworkModel`] cost function that minimizes
//!   the iteration's communication finish time, merging small tensors whose
//!   startup (α) cost dominates and splitting where overlap with remaining
//!   backprop pays.
//!
//! Invariants (enforced by [`FusionPlan::validate`] and the property
//! tests): buckets partition all layers exactly once, preserve layer
//! order, and respect the size threshold (greedy mode).

use std::str::FromStr;

use crate::compress::Compression;
use crate::config::TomlDoc;
use crate::sched::profile::LayerProfile;
use crate::simulator::NetworkModel;
use crate::util::cli::Args;

/// How gradients are fused into communication buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionMode {
    /// Single bucket holding the whole model (the seed's flat payload).
    Flat,
    /// Greedy size-threshold fusion.
    Threshold,
    /// MG-WFBP optimal merge over the network cost model.
    MgWfbp,
}

impl FusionMode {
    pub fn name(&self) -> &'static str {
        match self {
            FusionMode::Flat => "flat",
            FusionMode::Threshold => "threshold",
            FusionMode::MgWfbp => "mgwfbp",
        }
    }

    pub fn all() -> [FusionMode; 3] {
        [FusionMode::Flat, FusionMode::Threshold, FusionMode::MgWfbp]
    }
}

impl FromStr for FusionMode {
    type Err = String;

    fn from_str(s: &str) -> Result<FusionMode, String> {
        match s {
            "flat" => Ok(FusionMode::Flat),
            "threshold" | "greedy" => Ok(FusionMode::Threshold),
            "mgwfbp" | "mg-wfbp" | "optimal" => Ok(FusionMode::MgWfbp),
            other => Err(format!("unknown fusion mode {other:?} (flat|threshold|mgwfbp)")),
        }
    }
}

/// Fusion knobs, threaded through preset, TOML, and CLI parsing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FusionConfig {
    /// Enable the layered (bucketed, overlap-scheduled) exchange path.
    /// `false` keeps the seed's flat single-payload behaviour.
    pub layered: bool,
    pub mode: FusionMode,
    /// Greedy threshold (also the chunk granularity for the collective
    /// engine's bucketed exchanges).
    pub threshold_bytes: usize,
}

impl Default for FusionConfig {
    fn default() -> FusionConfig {
        FusionConfig {
            layered: false,
            mode: FusionMode::Threshold,
            threshold_bytes: 8 << 20, // 8 MiB, the MG-WFBP sweet spot band
        }
    }
}

impl FusionConfig {
    /// Parse from CLI flags (`--layered`, `--fusion-mode`,
    /// `--fusion-threshold-bytes`) on top of `base`.
    pub fn from_args_with(args: &Args, base: FusionConfig) -> FusionConfig {
        let mode: FusionMode = args
            .str_or("fusion-mode", base.mode.name())
            .parse()
            .unwrap_or_else(|e: String| panic!("--fusion-mode: {e}"));
        let threshold_bytes = args.usize_or("fusion-threshold-bytes", base.threshold_bytes);
        // Same validation as the TOML path: reject rather than silently
        // rewrite (one f32 is the smallest meaningful bucket).
        if threshold_bytes < 4 {
            panic!("--fusion-threshold-bytes: must be >= 4, got {threshold_bytes}");
        }
        FusionConfig { layered: args.bool_or("layered", base.layered), mode, threshold_bytes }
    }

    pub fn from_args(args: &Args) -> FusionConfig {
        Self::from_args_with(args, FusionConfig::default())
    }

    /// Parse from a TOML document's `[fusion]` section (missing keys fall
    /// back to the defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<FusionConfig, String> {
        let d = FusionConfig::default();
        let mode: FusionMode = doc.str_or("fusion", "fusion_mode", d.mode.name()).parse()?;
        let threshold = doc.i64_or("fusion", "fusion_threshold_bytes", d.threshold_bytes as i64);
        if threshold < 4 {
            return Err(format!("fusion_threshold_bytes must be >= 4, got {threshold}"));
        }
        Ok(FusionConfig {
            layered: doc.bool_or("fusion", "layered", d.layered),
            mode,
            threshold_bytes: threshold as usize,
        })
    }

    /// Emit the `[fusion]` TOML section (round-trips through
    /// [`FusionConfig::from_toml`]).
    pub fn to_toml(&self) -> String {
        format!(
            "[fusion]\nlayered = {}\nfusion_mode = \"{}\"\nfusion_threshold_bytes = {}\n",
            self.layered,
            self.mode.name(),
            self.threshold_bytes
        )
    }

    /// Emit the equivalent CLI flags (round-trips through
    /// [`FusionConfig::from_args`]).
    pub fn to_args(&self) -> Vec<String> {
        vec![
            format!("--layered={}", self.layered),
            format!("--fusion-mode={}", self.mode.name()),
            format!("--fusion-threshold-bytes={}", self.threshold_bytes),
        ]
    }

    /// Engine chunk granularity in f32 elements (0 disables chunking).
    pub fn chunk_elems(&self) -> usize {
        if self.layered {
            (self.threshold_bytes / 4).max(1)
        } else {
            0
        }
    }
}

/// One fused communication bucket: a contiguous run of profile layers.
#[derive(Debug, Clone, PartialEq)]
pub struct Bucket {
    /// First layer index (inclusive, backprop order).
    pub first: usize,
    /// Last layer index (inclusive).
    pub last: usize,
    pub bytes: usize,
    /// Backprop-time fraction at which the whole bucket is ready
    /// (= ready fraction of its last layer).
    pub ready_frac: f64,
}

/// A complete fusion plan over a profile.
#[derive(Debug, Clone, PartialEq)]
pub struct FusionPlan {
    pub mode: FusionMode,
    pub buckets: Vec<Bucket>,
}

impl FusionPlan {
    /// Dispatch on the configured mode. `participants` and
    /// `compute_seconds` parameterize the MG-WFBP cost model (ignored by
    /// the other modes).
    pub fn build(
        profile: &LayerProfile,
        cfg: &FusionConfig,
        net: &NetworkModel,
        participants: usize,
        compute_seconds: f64,
    ) -> FusionPlan {
        Self::build_compressed(profile, cfg, net, participants, compute_seconds, Compression::None)
    }

    /// [`FusionPlan::build`] with per-bucket wire compression priced into
    /// the MG-WFBP cost model (the flat/threshold modes ignore the codec —
    /// their partitions are size-driven, not cost-driven).
    pub fn build_compressed(
        profile: &LayerProfile,
        cfg: &FusionConfig,
        net: &NetworkModel,
        participants: usize,
        compute_seconds: f64,
        compress: Compression,
    ) -> FusionPlan {
        let plan = match cfg.mode {
            FusionMode::Flat => Self::flat(profile),
            FusionMode::Threshold => Self::threshold(profile, cfg.threshold_bytes),
            FusionMode::MgWfbp => Self::mgwfbp_compressed(
                profile,
                net,
                participants,
                compute_seconds,
                compress,
            ),
        };
        debug_assert!(plan.validate(profile).is_ok());
        plan
    }

    /// Single bucket covering the whole model — numerically identical to
    /// the seed's flat payload path.
    pub fn flat(profile: &LayerProfile) -> FusionPlan {
        let n = profile.len();
        FusionPlan {
            mode: FusionMode::Flat,
            buckets: vec![Bucket {
                first: 0,
                last: n - 1,
                bytes: profile.total_bytes(),
                ready_frac: 1.0,
            }],
        }
    }

    /// Greedy size-threshold fusion: accumulate consecutive layers until
    /// the bucket reaches `threshold_bytes`, then seal it. Every sealed
    /// bucket is at least `threshold_bytes` large; only the final bucket
    /// may be smaller.
    pub fn threshold(profile: &LayerProfile, threshold_bytes: usize) -> FusionPlan {
        let threshold = threshold_bytes.max(4);
        let mut buckets = Vec::new();
        let mut first = 0usize;
        let mut acc = 0usize;
        for j in 0..profile.len() {
            acc += profile.bytes(j);
            if acc >= threshold {
                buckets.push(Bucket {
                    first,
                    last: j,
                    bytes: acc,
                    ready_frac: profile.ready_frac(j),
                });
                first = j + 1;
                acc = 0;
            }
        }
        if first < profile.len() {
            let last = profile.len() - 1;
            buckets.push(Bucket { first, last, bytes: acc, ready_frac: profile.ready_frac(last) });
        }
        FusionPlan { mode: FusionMode::Threshold, buckets }
    }

    /// MG-WFBP-style optimal merge: choose the contiguous partition that
    /// minimizes the finish time of the last collective when each bucket
    /// may start at `max(prev bucket finished, bucket gradients ready)` and
    /// costs `net.allreduce(bytes, participants)`. O(L²) dynamic program
    /// (L = layer count, ≤ a few dozen for the paper workloads).
    pub fn mgwfbp(
        profile: &LayerProfile,
        net: &NetworkModel,
        participants: usize,
        compute_seconds: f64,
    ) -> FusionPlan {
        Self::mgwfbp_compressed(profile, net, participants, compute_seconds, Compression::None)
    }

    /// MG-WFBP optimal merge with per-bucket wire compression priced in:
    /// each candidate bucket costs
    /// `net.allreduce_compressed(bytes, wire_bytes(bytes), participants)`,
    /// so the DP sees both the smaller wire volume *and* the δ codec term
    /// that compression adds per bucket — more, smaller buckets pay the
    /// codec header/startup more often, exactly the tradeoff MG-WFBP's
    /// cost-model-driven merging is meant to settle.
    pub fn mgwfbp_compressed(
        profile: &LayerProfile,
        net: &NetworkModel,
        participants: usize,
        compute_seconds: f64,
        compress: Compression,
    ) -> FusionPlan {
        let l = profile.len();
        let participants = participants.max(2);
        let compute = compute_seconds.max(0.0);
        // Prefix byte sums: bytes(i..=j) = pre[j+1] - pre[i].
        let mut pre = vec![0usize; l + 1];
        for j in 0..l {
            pre[j + 1] = pre[j] + profile.bytes(j);
        }
        // best[k]: minimal finish time covering layers 0..k (k layers);
        // cut[k]: start index of the final bucket in that optimum.
        let mut best = vec![f64::INFINITY; l + 1];
        let mut cut = vec![0usize; l + 1];
        best[0] = 0.0;
        for k in 1..=l {
            let ready = compute * profile.ready_frac(k - 1);
            for i in 0..k {
                let bytes = pre[k] - pre[i];
                let comm = if compress.is_none() {
                    net.allreduce(bytes, participants)
                } else {
                    net.allreduce_compressed(bytes, compress.wire_bytes(bytes), participants)
                };
                let finish = best[i].max(ready) + comm;
                if finish < best[k] {
                    best[k] = finish;
                    cut[k] = i;
                }
            }
        }
        // Reconstruct the partition.
        let mut bounds = Vec::new();
        let mut k = l;
        while k > 0 {
            bounds.push((cut[k], k - 1));
            k = cut[k];
        }
        bounds.reverse();
        let buckets = bounds
            .into_iter()
            .map(|(first, last)| Bucket {
                first,
                last,
                bytes: pre[last + 1] - pre[first],
                ready_frac: profile.ready_frac(last),
            })
            .collect();
        FusionPlan { mode: FusionMode::MgWfbp, buckets }
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    pub fn total_bytes(&self) -> usize {
        self.buckets.iter().map(|b| b.bytes).sum()
    }

    /// Check the partition invariants against the profile: contiguous
    /// in-order cover of all layers, exact byte accounting, nondecreasing
    /// ready fractions.
    pub fn validate(&self, profile: &LayerProfile) -> Result<(), String> {
        if self.buckets.is_empty() {
            return Err("empty plan".to_string());
        }
        let mut next = 0usize;
        let mut prev_frac = 0.0f64;
        for (k, b) in self.buckets.iter().enumerate() {
            if b.first != next {
                return Err(format!("bucket {k} starts at {} (expected {next})", b.first));
            }
            if b.last < b.first || b.last >= profile.len() {
                return Err(format!("bucket {k} range {}..={} out of bounds", b.first, b.last));
            }
            let bytes: usize = (b.first..=b.last).map(|j| profile.bytes(j)).sum();
            if bytes != b.bytes {
                return Err(format!("bucket {k} bytes {} != layer sum {bytes}", b.bytes));
            }
            if b.ready_frac + 1e-12 < prev_frac {
                return Err(format!("bucket {k} ready_frac decreases"));
            }
            prev_frac = b.ready_frac;
            next = b.last + 1;
        }
        if next != profile.len() {
            return Err(format!("plan covers {next} of {} layers", profile.len()));
        }
        if self.total_bytes() != profile.total_bytes() {
            return Err("plan byte total mismatch".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> LayerProfile {
        LayerProfile::resnet50()
    }

    #[test]
    fn flat_is_one_full_bucket() {
        let p = profile();
        let plan = FusionPlan::flat(&p);
        assert_eq!(plan.num_buckets(), 1);
        assert_eq!(plan.buckets[0].bytes, p.total_bytes());
        assert_eq!(plan.buckets[0].ready_frac, 1.0);
        plan.validate(&p).unwrap();
    }

    #[test]
    fn threshold_respects_size_and_partitions() {
        let p = profile();
        for threshold in [1usize << 20, 4 << 20, 16 << 20, 1 << 30] {
            let plan = FusionPlan::threshold(&p, threshold);
            plan.validate(&p).unwrap();
            for b in &plan.buckets[..plan.num_buckets() - 1] {
                assert!(b.bytes >= threshold, "sealed bucket below threshold");
            }
        }
        // Huge threshold degenerates to (near-)flat.
        let one = FusionPlan::threshold(&p, usize::MAX / 2);
        assert_eq!(one.num_buckets(), 1);
        // Small threshold produces many buckets.
        let many = FusionPlan::threshold(&p, 1 << 20);
        assert!(many.num_buckets() > 4, "{} buckets", many.num_buckets());
    }

    #[test]
    fn mgwfbp_merges_small_tensors_and_validates() {
        let p = profile();
        let net = NetworkModel::aries();
        let plan = FusionPlan::mgwfbp(&p, &net, 8, 0.4);
        plan.validate(&p).unwrap();
        // With a 0.4 s backprop and millisecond-scale collectives the DP
        // must exploit overlap: more than one bucket, fewer than one per
        // layer (the α term makes per-layer collectives suboptimal for the
        // small tail tensors).
        assert!(plan.num_buckets() >= 2, "{}", plan.num_buckets());
        assert!(plan.num_buckets() <= p.len());
    }

    #[test]
    fn mgwfbp_with_zero_compute_prefers_fewer_buckets() {
        // No overlap to exploit: the optimum is the pure comm minimum,
        // which for an affine cost is a single fused bucket.
        let p = profile();
        let net = NetworkModel::aries();
        let plan = FusionPlan::mgwfbp(&p, &net, 8, 0.0);
        plan.validate(&p).unwrap();
        assert_eq!(plan.num_buckets(), 1);
    }

    #[test]
    fn mgwfbp_compressed_validates_and_prices_the_codec() {
        let p = profile();
        let net = NetworkModel::aries();
        let comp = Compression::TopK { ratio: 0.1 };
        let plan = FusionPlan::mgwfbp_compressed(&p, &net, 8, 0.4, comp);
        plan.validate(&p).unwrap();
        assert!(plan.num_buckets() >= 1 && plan.num_buckets() <= p.len());
        // Compression::None delegates to the uncompressed DP exactly.
        let none = FusionPlan::mgwfbp_compressed(&p, &net, 8, 0.4, Compression::None);
        assert_eq!(none, FusionPlan::mgwfbp(&p, &net, 8, 0.4));
        // build_compressed dispatches like build for the size-driven modes.
        let cfg = FusionConfig { layered: true, ..Default::default() };
        assert_eq!(
            FusionPlan::build_compressed(&p, &cfg, &net, 8, 0.4, comp),
            FusionPlan::build(&p, &cfg, &net, 8, 0.4),
        );
    }

    #[test]
    fn config_roundtrips_toml_and_cli() {
        let cfg = FusionConfig {
            layered: true,
            mode: FusionMode::MgWfbp,
            threshold_bytes: 2 << 20,
        };
        let doc = TomlDoc::parse(&cfg.to_toml()).unwrap();
        assert_eq!(FusionConfig::from_toml(&doc).unwrap(), cfg);
        let args = Args::parse(cfg.to_args());
        assert_eq!(FusionConfig::from_args(&args), cfg);
        // Defaults survive an empty doc / empty args.
        let d = FusionConfig::default();
        assert_eq!(FusionConfig::from_toml(&TomlDoc::parse("").unwrap()).unwrap(), d);
        assert_eq!(FusionConfig::from_args(&Args::parse(Vec::new())), d);
    }

    #[test]
    fn mode_parsing() {
        assert_eq!("flat".parse::<FusionMode>().unwrap(), FusionMode::Flat);
        assert_eq!("greedy".parse::<FusionMode>().unwrap(), FusionMode::Threshold);
        assert_eq!("mg-wfbp".parse::<FusionMode>().unwrap(), FusionMode::MgWfbp);
        assert!("bogus".parse::<FusionMode>().is_err());
        for m in FusionMode::all() {
            assert_eq!(m.name().parse::<FusionMode>().unwrap(), m);
        }
    }

    #[test]
    fn chunk_elems_follows_layered_flag() {
        let mut cfg = FusionConfig::default();
        assert_eq!(cfg.chunk_elems(), 0);
        cfg.layered = true;
        assert_eq!(cfg.chunk_elems(), (8 << 20) / 4);
    }
}
