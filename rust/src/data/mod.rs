//! Synthetic workload substrates.
//!
//! The paper trains on ImageNet, WMT17 and Habitat — none available here
//! (repro band 0/5). Per the substitution rule we generate synthetic
//! datasets that exercise the *same* mechanisms (DESIGN.md §2):
//!
//! * [`corpus`] — a Zipf-distributed Markov token corpus for the LM
//!   (learnable bigram structure, natural-language-like unigram stats),
//!   with the WMT-style **bucketed sentence-length** distribution driving
//!   per-step compute imbalance (Fig. 6).
//! * [`classify`] — Gaussian cluster classification set for the
//!   image-classification analogue (Fig. 4/5).
//! * [`imbalance`] — the paper's three load-imbalance processes:
//!   320 ms delay on 2 random ranks per step (Fig. 4), bucketed lognormal
//!   (Fig. 6/7), and heavy-tailed RL episode times (Fig. 9/10).

pub mod classify;
pub mod corpus;
pub mod imbalance;

pub use classify::ClassifyDataset;
pub use corpus::TokenCorpus;
pub use imbalance::{ImbalanceModel, StepDelays};
