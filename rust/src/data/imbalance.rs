//! Load-imbalance process models — the paper's three workload regimes.
//!
//! * [`ImbalanceModel::RandomStragglers`]: the Fig. 4 protocol — at every
//!   training step, `count` uniformly-chosen ranks are delayed by a fixed
//!   amount (paper: 2 ranks, 320 ms) on top of a lightly-noised base time.
//! * [`ImbalanceModel::BucketedLognormal`]: WMT-style sentence-length
//!   buckets (Fig. 6): per step each rank samples a bucket, and compute
//!   time scales with the bucket's (lognormal) length.
//! * [`ImbalanceModel::HeavyTail`]: RL experience collection (Fig. 9):
//!   lognormal with heavy σ, clamped to the paper's observed range
//!   (median ≈ 2 s, max ≈ 43 s).
//!
//! The same model drives both the real-thread runners (as actual sleeps)
//! and the discrete-event simulator (as sampled durations).

use crate::util::rng::Xoshiro256;

/// Per-step compute-time model for one cluster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ImbalanceModel {
    /// Perfectly balanced: `base` seconds per step with mild jitter.
    Balanced { base: f64, jitter: f64 },
    /// Fig. 4: `count` random ranks get `base + delay`; rest get `base`.
    RandomStragglers { base: f64, jitter: f64, delay: f64, count: usize },
    /// Fig. 6/7: `scale * exp(N(mu, sigma))`, quantized into `buckets`
    /// (bucketing reduces but does not eliminate variance, like WMT17).
    BucketedLognormal { scale: f64, mu: f64, sigma: f64, buckets: usize },
    /// Fig. 9/10: lognormal heavy tail clamped to [min, max].
    HeavyTail { median: f64, sigma: f64, min: f64, max: f64 },
}

impl ImbalanceModel {
    /// Paper Fig. 4 configuration (ResNet-50, b=128, P100): ≈ 0.40 s/step
    /// base, 320 ms injected on 2 ranks.
    pub fn fig4() -> ImbalanceModel {
        ImbalanceModel::RandomStragglers { base: 0.40, jitter: 0.01, delay: 0.32, count: 2 }
    }

    /// Paper Fig. 6/7 configuration (Transformer, 8192-token batches).
    /// Lognormal fitted to Fig. 6's shape: median ≈ 0.55 s, long right
    /// tail to ≈ 2 s, quantized into 10 buckets.
    pub fn fig7() -> ImbalanceModel {
        ImbalanceModel::BucketedLognormal { scale: 0.55, mu: 0.0, sigma: 0.45, buckets: 10 }
    }

    /// Paper Fig. 9/10 configuration (Habitat experience collection):
    /// median < 2 s, range 1.7–43.5 s.
    pub fn fig9() -> ImbalanceModel {
        ImbalanceModel::HeavyTail { median: 1.9, sigma: 0.75, min: 1.7, max: 43.5 }
    }

    /// Mean compute time (approximate; used for throughput normalization).
    pub fn mean(&self) -> f64 {
        match *self {
            ImbalanceModel::Balanced { base, .. } => base,
            ImbalanceModel::RandomStragglers { base, .. } => base, // + count/P * delay, P-dependent
            ImbalanceModel::BucketedLognormal { scale, mu, sigma, .. } => {
                scale * (mu + sigma * sigma / 2.0).exp()
            }
            ImbalanceModel::HeavyTail { median, sigma, .. } => {
                median * (sigma * sigma / 2.0).exp()
            }
        }
    }
}

/// Per-iteration delay sampler for `P` ranks.
pub struct StepDelays {
    model: ImbalanceModel,
    p: usize,
    rng: Xoshiro256,
}

impl StepDelays {
    pub fn new(model: ImbalanceModel, p: usize, seed: u64) -> StepDelays {
        StepDelays { model, p, rng: Xoshiro256::seed_from_u64(seed) }
    }

    /// Compute times (seconds) for all `P` ranks at one training step.
    pub fn sample_step(&mut self) -> Vec<f64> {
        match self.model {
            ImbalanceModel::Balanced { base, jitter } => (0..self.p)
                .map(|_| (base + self.rng.normal(0.0, jitter)).max(0.0))
                .collect(),
            ImbalanceModel::RandomStragglers { base, jitter, delay, count } => {
                let mut times: Vec<f64> = (0..self.p)
                    .map(|_| (base + self.rng.normal(0.0, jitter)).max(0.0))
                    .collect();
                let c = count.min(self.p);
                for idx in self.rng.sample_distinct(self.p, c) {
                    times[idx] += delay;
                }
                times
            }
            ImbalanceModel::BucketedLognormal { scale, mu, sigma, buckets } => (0..self.p)
                .map(|_| {
                    let raw = self.rng.lognormal(mu, sigma);
                    // Quantize into `buckets` levels between p5 and p95 of
                    // the lognormal (bucketing à la WMT batching).
                    let lo = (mu - 1.64 * sigma).exp();
                    let hi = (mu + 1.64 * sigma).exp();
                    let clamped = raw.clamp(lo, hi);
                    let b = (((clamped - lo) / (hi - lo) * buckets as f64).floor())
                        .min(buckets as f64 - 1.0);
                    let level = lo + (b + 0.5) / buckets as f64 * (hi - lo);
                    scale * level
                })
                .collect(),
            ImbalanceModel::HeavyTail { median, sigma, min, max } => (0..self.p)
                .map(|_| (median * self.rng.lognormal(0.0, sigma)).clamp(min, max))
                .collect(),
        }
    }

    /// Draw `steps` iterations of per-rank times (steps × P).
    pub fn sample_many(&mut self, steps: usize) -> Vec<Vec<f64>> {
        (0..steps).map(|_| self.sample_step()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn fig4_two_stragglers_per_step() {
        let mut d = StepDelays::new(ImbalanceModel::fig4(), 16, 1);
        for _ in 0..50 {
            let times = d.sample_step();
            let slow = times.iter().filter(|&&t| t > 0.55).count();
            assert_eq!(slow, 2, "{times:?}");
        }
    }

    #[test]
    fn fig9_heavy_tail_stats() {
        let mut d = StepDelays::new(ImbalanceModel::fig9(), 1, 2);
        let samples: Vec<f64> = (0..20_000).map(|_| d.sample_step()[0]).collect();
        let s = Summary::of(&samples);
        assert!(s.min >= 1.7 && s.max <= 43.5);
        assert!((s.p50 - 1.9).abs() < 0.5, "median {}", s.p50);
        // Heavy tail: p99 far above median.
        assert!(s.p99 > 3.0 * s.p50, "p99 {} p50 {}", s.p99, s.p50);
    }

    #[test]
    fn bucketed_quantizes() {
        let mut d = StepDelays::new(ImbalanceModel::fig7(), 1, 3);
        let mut levels: Vec<u64> = (0..5000)
            .map(|_| (d.sample_step()[0] * 1e6) as u64)
            .collect();
        levels.sort_unstable();
        levels.dedup();
        assert!(levels.len() <= 10, "expected ≤ 10 buckets, got {}", levels.len());
        assert!(levels.len() >= 5, "expected several buckets, got {}", levels.len());
    }

    #[test]
    fn balanced_has_low_variance() {
        let mut d = StepDelays::new(ImbalanceModel::Balanced { base: 0.1, jitter: 0.001 }, 8, 4);
        let all: Vec<f64> = d.sample_many(100).into_iter().flatten().collect();
        let s = Summary::of(&all);
        assert!((s.mean - 0.1).abs() < 0.01);
        assert!(s.std < 0.01);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = StepDelays::new(ImbalanceModel::fig9(), 4, 9);
        let mut b = StepDelays::new(ImbalanceModel::fig9(), 4, 9);
        assert_eq!(a.sample_many(10), b.sample_many(10));
    }
}
