//! Synthetic token corpus for the language-modeling workload.
//!
//! Tokens are drawn from a first-order Markov chain whose rows are Zipf
//! distributions over a small successor set: the corpus has genuinely
//! learnable bigram structure (a trained LM's loss drops well below the
//! unigram entropy), with Zipf unigram statistics like natural text.
//! Deterministic per (seed, rank) so every worker sees a disjoint,
//! reproducible shard — the paper's random dataset partition.

use crate::model::{Batch, DataArg};
use crate::util::rng::{Xoshiro256, Zipf};

/// Markov token corpus.
pub struct TokenCorpus {
    vocab: usize,
    seq_len: usize,
    batch: usize,
    /// Per-token successor tables: `succ[t]` lists the candidate next
    /// tokens; picked with Zipf-distributed rank.
    succ: Vec<Vec<u32>>,
    zipf: Zipf,
    rng: Xoshiro256,
}

/// Successor candidates per token (small enough to be learnable quickly).
const SUCCESSORS: usize = 8;

impl TokenCorpus {
    /// `seed` defines the corpus structure (shared by all ranks so they
    /// learn the same language); `rank` seeds the sampling stream (so every
    /// rank sees different sentences — the data partition).
    pub fn new(vocab: usize, seq_len: usize, batch: usize, seed: u64, rank: usize) -> TokenCorpus {
        let mut structure_rng = Xoshiro256::seed_from_u64(seed);
        let succ = (0..vocab)
            .map(|_| {
                (0..SUCCESSORS)
                    .map(|_| structure_rng.usize_below(vocab) as u32)
                    .collect()
            })
            .collect();
        TokenCorpus {
            vocab,
            seq_len,
            batch,
            succ,
            zipf: Zipf::new(SUCCESSORS, 1.2),
            rng: Xoshiro256::seed_from_u64(seed ^ (rank as u64).wrapping_mul(0x5851F42D4C957F2D)),
        }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sample one sequence of `len + 1` tokens (inputs + shifted labels).
    fn sample_seq(&mut self, len: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(len + 1);
        let mut tok = self.rng.usize_below(self.vocab) as u32;
        out.push(tok);
        for _ in 0..len {
            let rank = self.zipf.sample(&mut self.rng);
            tok = self.succ[tok as usize][rank];
            out.push(tok);
        }
        out
    }

    /// Next LM minibatch: `(tokens [B, L], labels [B, L])` as a [`Batch`].
    pub fn next_batch(&mut self) -> Batch {
        let (b, l) = (self.batch, self.seq_len);
        let mut xs = Vec::with_capacity(b * l);
        let mut ys = Vec::with_capacity(b * l);
        for _ in 0..b {
            let seq = self.sample_seq(l);
            xs.extend(seq[..l].iter().map(|&t| t as i32));
            ys.extend(seq[1..=l].iter().map(|&t| t as i32));
        }
        Batch::new(vec![DataArg::i32(vec![b, l], xs), DataArg::i32(vec![b, l], ys)])
    }

    /// Bigram cross-entropy lower bound of this corpus (nats): what a
    /// perfect bigram model would achieve. Used by tests to check the LM
    /// is actually learning structure.
    pub fn bigram_entropy(&self) -> f64 {
        // The successor is Zipf(SUCCESSORS, 1.2)-distributed over the row;
        // rows may repeat tokens which only lowers true entropy, so this is
        // an upper bound on the bigram entropy.
        let s = 1.2;
        let weights: Vec<f64> = (1..=SUCCESSORS).map(|k| (k as f64).powf(-s)).collect();
        let z: f64 = weights.iter().sum();
        -weights.iter().map(|w| (w / z) * (w / z).ln()).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_ranges() {
        let mut c = TokenCorpus::new(256, 32, 8, 42, 0);
        let b = c.next_batch();
        assert_eq!(b.args.len(), 2);
        assert_eq!(b.args[0].shape(), &[8, 32]);
        match (&b.args[0], &b.args[1]) {
            (DataArg::I32 { values: x, .. }, DataArg::I32 { values: y, .. }) => {
                assert!(x.iter().all(|&t| (0..256).contains(&t)));
                assert!(y.iter().all(|&t| (0..256).contains(&t)));
                // Labels are inputs shifted by one within each row.
                assert_eq!(x[1], y[0]);
            }
            _ => panic!("wrong dtypes"),
        }
    }

    #[test]
    fn ranks_get_different_data_same_language() {
        let mut a = TokenCorpus::new(64, 16, 4, 7, 0);
        let mut b = TokenCorpus::new(64, 16, 4, 7, 1);
        assert_ne!(a.next_batch(), b.next_batch(), "shards must differ");
        // Same structure: successor tables identical.
        assert_eq!(a.succ, b.succ);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = TokenCorpus::new(64, 16, 4, 7, 3);
        let mut b = TokenCorpus::new(64, 16, 4, 7, 3);
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn labels_follow_markov_structure() {
        // Every (x -> y) transition must be in the successor table.
        let mut c = TokenCorpus::new(128, 64, 4, 11, 0);
        let b = c.next_batch();
        if let (DataArg::I32 { values: xs, .. }, DataArg::I32 { values: ys, .. }) =
            (&b.args[0], &b.args[1])
        {
            for (x, y) in xs.iter().zip(ys.iter()) {
                assert!(
                    c.succ[*x as usize].contains(&(*y as u32)),
                    "transition {x}->{y} not in table"
                );
            }
        }
    }

    #[test]
    fn bigram_entropy_below_uniform() {
        let c = TokenCorpus::new(256, 32, 8, 42, 0);
        let h = c.bigram_entropy();
        assert!(h > 0.0 && h < (256f64).ln(), "h={h}");
    }
}
