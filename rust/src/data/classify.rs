//! Gaussian-cluster classification dataset — the image-classification
//! analogue (Fig. 4/5 workloads). Each class is an isotropic Gaussian
//! around a random center; the task is exactly learnable, so accuracy
//! curves discriminate between optimizers the same way ImageNet top-1 does
//! in the paper.

use crate::model::{Batch, DataArg};
use crate::util::rng::Xoshiro256;

pub struct ClassifyDataset {
    input_dim: usize,
    classes: usize,
    batch: usize,
    centers: Vec<Vec<f32>>,
    noise: f32,
    rng: Xoshiro256,
}

impl ClassifyDataset {
    pub fn new(
        input_dim: usize,
        classes: usize,
        batch: usize,
        noise: f32,
        seed: u64,
        rank: usize,
    ) -> ClassifyDataset {
        let mut structure_rng = Xoshiro256::seed_from_u64(seed);
        let centers = (0..classes)
            .map(|_| (0..input_dim).map(|_| structure_rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        ClassifyDataset {
            input_dim,
            classes,
            batch,
            centers,
            noise,
            rng: Xoshiro256::seed_from_u64(seed ^ (rank as u64).wrapping_mul(0x9E3779B97F4A7C15)),
        }
    }

    /// Next `(x [B, D], y [B])` minibatch.
    pub fn next_batch(&mut self) -> Batch {
        let (b, d) = (self.batch, self.input_dim);
        let mut xs = Vec::with_capacity(b * d);
        let mut ys = Vec::with_capacity(b);
        for _ in 0..b {
            let c = self.rng.usize_below(self.classes);
            ys.push(c as i32);
            for j in 0..d {
                xs.push(self.centers[c][j] + self.rng.normal_f32(0.0, self.noise));
            }
        }
        Batch::new(vec![DataArg::f32(vec![b, d], xs), DataArg::i32(vec![b], ys)])
    }

    /// A fixed held-out evaluation batch (same for every rank).
    pub fn eval_batch(&self, size: usize) -> Batch {
        let mut rng = Xoshiro256::seed_from_u64(0xE7A1_u64 ^ self.classes as u64);
        let d = self.input_dim;
        let mut xs = Vec::with_capacity(size * d);
        let mut ys = Vec::with_capacity(size);
        for _ in 0..size {
            let c = rng.usize_below(self.classes);
            ys.push(c as i32);
            for j in 0..d {
                xs.push(self.centers[c][j] + rng.normal_f32(0.0, self.noise));
            }
        }
        Batch::new(vec![DataArg::f32(vec![size, d], xs), DataArg::i32(vec![size], ys)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut ds = ClassifyDataset::new(16, 4, 8, 0.3, 1, 0);
        let b = ds.next_batch();
        assert_eq!(b.args[0].shape(), &[8, 16]);
        assert_eq!(b.args[1].shape(), &[8]);
        if let DataArg::I32 { values, .. } = &b.args[1] {
            assert!(values.iter().all(|&y| (0..4).contains(&y)));
        }
    }

    #[test]
    fn eval_batch_is_deterministic() {
        let ds = ClassifyDataset::new(8, 3, 4, 0.1, 5, 0);
        assert_eq!(ds.eval_batch(32), ds.eval_batch(32));
        // And shared across ranks.
        let ds2 = ClassifyDataset::new(8, 3, 4, 0.1, 5, 7);
        assert_eq!(ds.eval_batch(32), ds2.eval_batch(32));
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-center classification on clean data should be perfect
        // with small noise.
        let mut ds = ClassifyDataset::new(32, 4, 64, 0.05, 9, 0);
        let b = ds.next_batch();
        if let (DataArg::F32 { values: xs, .. }, DataArg::I32 { values: ys, .. }) =
            (&b.args[0], &b.args[1])
        {
            for (i, &y) in ys.iter().enumerate() {
                let x = &xs[i * 32..(i + 1) * 32];
                let mut best = (f32::INFINITY, 0usize);
                for (c, center) in ds.centers.iter().enumerate() {
                    let d: f32 = x.iter().zip(center).map(|(a, b)| (a - b) * (a - b)).sum();
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                assert_eq!(best.1 as i32, y);
            }
        }
    }
}
