//! In-memory LRU cache of completed sweep cells, keyed by the canonical
//! config hash ([`super::canonical::config_hash`]).
//!
//! Entries store the already-encoded canonical config and result JSON,
//! so a cache replay serves the *same bytes* a fresh computation would
//! — bit-identity across inline / daemon / replay paths is a property
//! of storing the encoding, not re-deriving it. Hit/miss counters are
//! monotonic for the daemon's `/healthz` line and the per-sweep summary
//! record (they are how a client proves a repeated sweep computed
//! nothing). Eviction is exact LRU via a monotonic use tick; the O(n)
//! min-scan on insert is fine at the few-thousand-entry capacities the
//! daemon runs with.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// One completed cell: canonical config + result encodings.
#[derive(Debug)]
pub struct CachedCell {
    pub hash: u64,
    pub config_json: Json,
    pub result_json: Json,
}

struct Entry {
    last_used: u64,
    cell: Arc<CachedCell>,
}

struct Inner {
    cap: usize,
    tick: u64,
    map: HashMap<u64, Entry>,
}

/// Thread-safe LRU keyed by config hash.
pub struct CellCache {
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CellCache {
    /// `cap` = maximum resident cells (≥ 1).
    pub fn new(cap: usize) -> CellCache {
        CellCache {
            inner: Mutex::new(Inner { cap: cap.max(1), tick: 0, map: HashMap::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Look up a cell, bumping its recency. Counts a hit or a miss.
    pub fn get(&self, hash: u64) -> Option<Arc<CachedCell>> {
        let mut inner = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&hash) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.cell))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) a cell, evicting the least-recently-used
    /// entry when over capacity. Returns the shared handle.
    pub fn insert(&self, cell: CachedCell) -> Arc<CachedCell> {
        let mut inner = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        inner.tick += 1;
        let tick = inner.tick;
        let hash = cell.hash;
        let arc = Arc::new(cell);
        inner.map.insert(hash, Entry { last_used: tick, cell: Arc::clone(&arc) });
        while inner.map.len() > inner.cap {
            if let Some(&oldest) =
                inner.map.iter().min_by_key(|(_, e)| e.last_used).map(|(h, _)| h)
            {
                inner.map.remove(&oldest);
            } else {
                break;
            }
        }
        arc
    }

    pub fn len(&self) -> usize {
        match self.inner.lock() {
            Ok(g) => g.map.len(),
            Err(p) => p.into_inner().map.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    fn cell(hash: u64) -> CachedCell {
        CachedCell {
            hash,
            config_json: obj(vec![("seed", num(hash as f64))]),
            result_json: obj(vec![("makespan", num(1.5))]),
        }
    }

    #[test]
    fn hit_and_miss_counters_track_lookups() {
        let c = CellCache::new(8);
        assert!(c.get(1).is_none());
        c.insert(cell(1));
        assert!(c.get(1).is_some());
        assert!(c.get(2).is_none());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 2);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn evicts_least_recently_used() {
        let c = CellCache::new(2);
        c.insert(cell(1));
        c.insert(cell(2));
        assert!(c.get(1).is_some()); // 1 is now fresher than 2
        c.insert(cell(3)); // evicts 2
        assert!(c.get(2).is_none());
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn replay_serves_the_same_object() {
        let c = CellCache::new(4);
        let inserted = c.insert(cell(9));
        let replayed = c.get(9).expect("hit");
        assert!(Arc::ptr_eq(&inserted, &replayed));
        assert_eq!(
            inserted.result_json.to_string(),
            replayed.result_json.to_string()
        );
    }
}
