//! The `wagma serve` daemon: the discrete-event simulator behind a
//! long-running HTTP API with a worker pool and a cell cache.
//!
//! Routes (all served through the shared [`super::http::Router`]):
//!
//! * `POST /v1/simulate` — one canonical [`SimConfig`] JSON body, one
//!   cell back (`{"cache":"hit"|"miss","cell":{config,hash,result}}`).
//! * `POST /v1/sweep` — a grid spec (preset × algos × p × τ × group
//!   size × compression × faults); cells are sharded across the worker
//!   pool and streamed back incrementally as JSON-lines (cache hits
//!   first, computed cells in completion order), closed by one
//!   `{"summary":...}` record carrying the cache-hit/computed counters.
//! * `GET /v1/cells/<hash>` — replay one cached cell by canonical hash.
//! * `GET /v1/presets` — the experiment presets a sweep can start from.
//! * `GET /healthz` — liveness plus worker/cache/cell counters.
//! * `GET /metrics`, `GET /snapshot.json` — the telemetry exposition
//!   re-exported from the shared router: workers publish per-cell
//!   progress into a [`TelemetryRegistry`] slot each (steps = cells
//!   computed, wire bytes = modelled bytes-on-wire), so `wagma top
//!   --addr` and a Prometheus scraper work against the daemon exactly
//!   as against a training run's `--metrics-addr` listener.
//!
//! Determinism: the simulator is re-entrant and seed-deterministic, so
//! a cell is bit-identical whether computed inline, by any worker
//! thread, or replayed from the cache — the cache stores the canonical
//! encodings, and [`cell_json`] serves the same bytes on every path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::config::{preset, preset_names};
use crate::fault::FaultPlan;
use crate::optim::Algorithm;
use crate::compress::Compression;
use crate::simulator::{simulate, SimConfig, SimResult};
use crate::telemetry::{
    shared_snapshot, snapshot_json, SharedSnapshot, StragglerConfig, TelemetryHub,
    TelemetryRegistry,
};
use crate::util::json::{arr, num, obj, s, Json};

use super::cache::{CachedCell, CellCache};
use super::canonical::{
    config_hash, decode_config, encode_config, encode_result, hash_hex, parse_hash_hex,
};
use super::http::{Request, ResponseWriter, Router, Server};

/// Hard ceiling on one sweep's grid (after dedup) — a request-shape
/// guard, not a throughput limit; overlapping sweeps pay only for new
/// cells anyway.
const MAX_SWEEP_CELLS: usize = 4096;
/// How long a submitted cell may take before the request errors out.
const CELL_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(600);

struct Job {
    hash: u64,
    cfg: SimConfig,
    reply: mpsc::Sender<JobDone>,
}

struct JobDone {
    hash: u64,
    cfg: SimConfig,
    result: SimResult,
}

/// Worker-side telemetry: one registry slot per worker thread, ticked
/// into the shared latest-snapshot slot after every computed cell.
struct PoolTelemetry {
    registry: Arc<TelemetryRegistry>,
    hub: Mutex<TelemetryHub>,
    latest: SharedSnapshot,
}

impl PoolTelemetry {
    fn new(workers: usize, latest: SharedSnapshot) -> PoolTelemetry {
        let registry = Arc::new(TelemetryRegistry::new(workers));
        // One analytic window per tick; w=1 so the detector never waits
        // for consecutive windows that a request-driven daemon may not
        // produce.
        let cfg = StragglerConfig { w: 1, ..StragglerConfig::default() };
        let hub = Mutex::new(TelemetryHub::new(Arc::clone(&registry), cfg));
        PoolTelemetry { registry, hub, latest }
    }

    fn record_cell(&self, worker: usize, r: &SimResult) {
        let slot = self.registry.rank(worker);
        slot.add_step();
        let total_wire = r.wire_bytes_per_iter * r.p as f64 * r.steps as f64;
        slot.add_wire_bytes(total_wire.max(0.0) as u64);
        if let (Ok(mut hub), Ok(mut latest)) = (self.hub.lock(), self.latest.lock()) {
            *latest = Some(hub.tick());
        }
    }
}

/// Fixed worker-thread pool draining one shared job queue.
struct WorkerPool {
    tx: Mutex<Option<mpsc::Sender<Job>>>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    fn spawn(workers: usize, telemetry: Arc<PoolTelemetry>) -> std::io::Result<WorkerPool> {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&rx);
            let tel = Arc::clone(&telemetry);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("wagma-serve-worker-{w}"))
                    .spawn(move || loop {
                        // Hold the lock only across the dequeue; compute
                        // runs unlocked so workers shard the grid.
                        let job = {
                            let guard = match rx.lock() {
                                Ok(g) => g,
                                Err(p) => p.into_inner(),
                            };
                            guard.recv()
                        };
                        let Ok(job) = job else {
                            return; // queue closed: daemon shutting down
                        };
                        let result = simulate(&job.cfg);
                        tel.record_cell(w, &result);
                        // A dead reply channel just means the client hung
                        // up mid-sweep; the cell still entered telemetry.
                        let _ = job.reply.send(JobDone { hash: job.hash, cfg: job.cfg, result });
                    })?,
            );
        }
        Ok(WorkerPool { tx: Mutex::new(Some(tx)), handles: Mutex::new(handles) })
    }

    fn submit(&self, job: Job) -> Result<(), String> {
        let guard = match self.tx.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        guard
            .as_ref()
            .ok_or("worker pool is shut down")?
            .send(job)
            .map_err(|_| "worker pool is shut down".to_string())
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Ok(mut guard) = self.tx.lock() {
            guard.take(); // close the queue; workers drain and exit
        }
        if let Ok(mut handles) = self.handles.lock() {
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Shared daemon state behind the route closures. (Worker threads each
/// hold their own `Arc<PoolTelemetry>`; the state only carries what the
/// routes read.)
pub struct DaemonState {
    cache: CellCache,
    pool: WorkerPool,
    workers: usize,
    cells_computed: AtomicU64,
    sweeps: AtomicU64,
}

impl DaemonState {
    pub fn cells_computed(&self) -> u64 {
        self.cells_computed.load(Ordering::Relaxed)
    }

    pub fn cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    pub fn cache_misses(&self) -> u64 {
        self.cache.misses()
    }
}

/// The long-running serve daemon (HTTP listener + worker pool + cache).
pub struct Daemon {
    server: Server,
    state: Arc<DaemonState>,
}

impl Daemon {
    /// Bind `addr` (port 0 picks an ephemeral port) with a fixed pool
    /// of `workers` simulator threads and an LRU of `cache_cap` cells.
    pub fn start(addr: &str, workers: usize, cache_cap: usize) -> std::io::Result<Daemon> {
        let workers = workers.max(1);
        let latest = shared_snapshot();
        let telemetry = Arc::new(PoolTelemetry::new(workers, Arc::clone(&latest)));
        let pool = WorkerPool::spawn(workers, telemetry)?;
        let state = Arc::new(DaemonState {
            cache: CellCache::new(cache_cap),
            pool,
            workers,
            cells_computed: AtomicU64::new(0),
            sweeps: AtomicU64::new(0),
        });
        let router = Arc::new(build_router(Arc::clone(&state), latest));
        let server = Server::serve(addr, "wagma-serve", router)?;
        Ok(Daemon { server, state })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.server.local_addr()
    }

    pub fn requests_served(&self) -> u64 {
        self.server.requests_served()
    }

    pub fn state(&self) -> &Arc<DaemonState> {
        &self.state
    }

    /// Every route the daemon's router serves (the lint sweep walks
    /// this list so no route can dodge the exposition checks).
    pub fn served_routes(&self) -> Vec<(&'static str, &'static str)> {
        self.server.router().served_routes()
    }

    /// The router itself, for socketless [`Router::dispatch`] tests.
    pub fn router(&self) -> &Arc<Router> {
        self.server.router()
    }
}

/// Mount `/metrics` and `/snapshot.json` over a latest-snapshot slot —
/// the exact exposition routes the training-run listener serves,
/// shared here so `wagma top --addr` works against either endpoint.
pub fn add_metrics_routes(router: Router, latest: SharedSnapshot) -> Router {
    let latest_m = Arc::clone(&latest);
    router
        .get("/metrics", move |_req, resp| {
            match latest_m.lock().ok().and_then(|g| g.clone()) {
                Some(snap) => resp.full(
                    "200 OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    &crate::telemetry::render(&snap),
                ),
                None => resp.full("503 Service Unavailable", "text/plain", "no snapshot yet\n"),
            }
        })
        .get("/snapshot.json", move |_req, resp| {
            match latest.lock().ok().and_then(|g| g.clone()) {
                Some(snap) => {
                    resp.full("200 OK", "application/json", &snapshot_json(&snap).to_string())
                }
                None => resp.full("503 Service Unavailable", "application/json", "null"),
            }
        })
}

fn build_router(state: Arc<DaemonState>, latest: SharedSnapshot) -> Router {
    let router = Router::new().get("/", |_req, resp| {
        resp.full(
            "200 OK",
            "text/plain",
            "wagma serve: POST /v1/simulate  POST /v1/sweep  GET /v1/cells/<hash>  \
             GET /v1/presets  /metrics  /snapshot.json  /healthz\n",
        )
    });
    let router = add_metrics_routes(router, latest);
    let st = Arc::clone(&state);
    let router = router.get("/healthz", move |_req, resp| {
        resp.full(
            "200 OK",
            "text/plain",
            &format!(
                "ok workers={} cells_computed={} cache_hits={} cache_misses={} cache_entries={} sweeps={}\n",
                st.workers,
                st.cells_computed(),
                st.cache_hits(),
                st.cache_misses(),
                st.cache.len(),
                st.sweeps.load(Ordering::Relaxed),
            ),
        )
    });
    let router = router.get("/v1/presets", move |_req, resp| {
        let list: Vec<Json> = preset_names()
            .iter()
            .filter_map(|name| preset(name))
            .map(|p| {
                obj(vec![
                    ("name", s(p.name)),
                    ("description", s(p.description)),
                    ("node_counts", arr(p.node_counts.iter().map(|&n| num(n as f64)))),
                    ("batch", num(p.batch as f64)),
                    ("model_params", num(p.model_params as f64)),
                    ("tau", num(p.tau as f64)),
                    ("steps", num(p.steps as f64)),
                    ("algos", arr(p.algos.iter().map(|a| s(a.name())))),
                ])
            })
            .collect();
        resp.full("200 OK", "application/json", &Json::Arr(list).to_string())
    });
    let st = Arc::clone(&state);
    let router = router.post("/v1/simulate", move |req, resp| {
        let cfg = match parse_simulate_body(req) {
            Ok(cfg) => cfg,
            Err(e) => return bad_request(resp, &e),
        };
        match compute_or_replay(&st, cfg) {
            Ok((cell, hit)) => resp.full(
                "200 OK",
                "application/json",
                &obj(vec![
                    ("cache", s(if hit { "hit" } else { "miss" })),
                    ("cell", cell_json(&cell)),
                ])
                .to_string(),
            ),
            Err(e) => resp.full(
                "500 Internal Server Error",
                "application/json",
                &obj(vec![("error", s(&e))]).to_string(),
            ),
        }
    });
    let st = Arc::clone(&state);
    let router = router.get("/v1/cells/*", move |req, resp| {
        let Some(hex) = req.wildcard("/v1/cells/*") else {
            return bad_request(resp, "missing cell hash");
        };
        let hash = match parse_hash_hex(hex) {
            Ok(h) => h,
            Err(e) => return bad_request(resp, &e),
        };
        match st.cache.get(hash) {
            Some(cell) => resp.full("200 OK", "application/json", &cell_json(&cell).to_string()),
            None => resp.full(
                "404 Not Found",
                "application/json",
                &obj(vec![("error", s("unknown cell (expired from the LRU or never computed)"))])
                    .to_string(),
            ),
        }
    });
    let st = Arc::clone(&state);
    router.post("/v1/sweep", move |req, resp| handle_sweep(&st, req, resp))
}

fn bad_request(resp: &mut ResponseWriter, msg: &str) -> std::io::Result<()> {
    resp.full("400 Bad Request", "application/json", &obj(vec![("error", s(msg))]).to_string())
}

fn parse_simulate_body(req: &Request) -> Result<SimConfig, String> {
    let j = Json::parse(&req.body_str()).map_err(|e| format!("body: {e}"))?;
    let cfg = decode_config(&j)?;
    validate_config(&cfg)?;
    Ok(cfg)
}

/// The daemon's admission checks mirror the simulator's own asserts so
/// a bad request is a 400, not a worker panic.
fn validate_config(cfg: &SimConfig) -> Result<(), String> {
    if cfg.p == 0 || !cfg.p.is_power_of_two() {
        return Err(format!("p must be a power of two, got {}", cfg.p));
    }
    if cfg.steps == 0 {
        return Err("steps must be > 0".into());
    }
    if cfg.trace {
        return Err("trace: true is not served (cells are priced timings, not timelines); \
                    run `wagma simulate --trace` inline instead"
            .into());
    }
    Ok(())
}

/// The canonical cell body — identical bytes whether the cell was just
/// computed, served from `/v1/simulate`, streamed by `/v1/sweep`, or
/// replayed from `/v1/cells/<hash>`.
fn cell_json(cell: &CachedCell) -> Json {
    obj(vec![
        ("config", cell.config_json.clone()),
        ("hash", s(&hash_hex(cell.hash))),
        ("result", cell.result_json.clone()),
    ])
}

fn compute_or_replay(state: &DaemonState, cfg: SimConfig) -> Result<(Arc<CachedCell>, bool), String> {
    let hash = config_hash(&cfg);
    if let Some(cell) = state.cache.get(hash) {
        return Ok((cell, true));
    }
    let (tx, rx) = mpsc::channel();
    state.pool.submit(Job { hash, cfg, reply: tx })?;
    let done = rx
        .recv_timeout(CELL_TIMEOUT)
        .map_err(|_| "cell computation timed out or the pool died".to_string())?;
    Ok((finish_cell(state, done), false))
}

fn finish_cell(state: &DaemonState, done: JobDone) -> Arc<CachedCell> {
    state.cells_computed.fetch_add(1, Ordering::Relaxed);
    state.cache.insert(CachedCell {
        hash: done.hash,
        config_json: encode_config(&done.cfg),
        result_json: encode_result(&done.result),
    })
}

/// One axis of the sweep grid: either values from the request or a
/// default derived from the preset/base config.
fn axis_strings(j: &Json, key: &str) -> Result<Option<Vec<String>>, String> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let items = v.as_arr().ok_or_else(|| format!("{key}: expected an array"))?;
            items
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .or_else(|| x.as_f64().map(|n| format!("{n}")))
                        .ok_or_else(|| format!("{key}: entries must be strings or numbers"))
                })
                .collect::<Result<Vec<String>, String>>()
                .map(Some)
        }
    }
}

fn axis_numbers(j: &Json, key: &str) -> Result<Option<Vec<u64>>, String> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let items = v.as_arr().ok_or_else(|| format!("{key}: expected an array"))?;
            items
                .iter()
                .map(|x| {
                    let n = x.as_f64().ok_or_else(|| format!("{key}: non-number entry"))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        return Err(format!("{key}: {n} is not a non-negative integer"));
                    }
                    Ok(n as u64)
                })
                .collect::<Result<Vec<u64>, String>>()
                .map(Some)
        }
    }
}

fn parse_compression_spec(spec: &str) -> Result<Compression, String> {
    let (kind, ratio) = match spec.split_once(':') {
        Some((k, r)) => {
            let ratio: f64 =
                r.parse().map_err(|_| format!("compression `{spec}`: bad ratio `{r}`"))?;
            (k, Some(ratio))
        }
        None => (spec, None),
    };
    match kind {
        "none" => Ok(Compression::None),
        "q8" => Ok(Compression::QuantizeQ8),
        "topk" => {
            let ratio = ratio.unwrap_or(crate::compress::DEFAULT_TOPK_RATIO);
            if !(ratio > 0.0 && ratio <= 1.0) {
                return Err(format!("compression `{spec}`: ratio must be in (0, 1]"));
            }
            Ok(Compression::TopK { ratio })
        }
        other => Err(format!("compression `{spec}`: unknown kind `{other}` (none|topk|q8)")),
    }
}

/// Expand one sweep request into deduped `SimConfig` cells.
fn expand_sweep(j: &Json) -> Result<(Vec<(u64, SimConfig)>, usize), String> {
    let preset_cfg = match j.get("preset").and_then(|v| v.as_str()) {
        Some(name) => {
            Some(preset(name).ok_or_else(|| format!("unknown preset `{name}` (fig4|fig7|fig10)"))?)
        }
        None => None,
    };
    let base = SimConfig::default();
    let seed = j.get("seed").and_then(|v| v.as_f64()).map(|n| n as u64).unwrap_or(base.seed);

    let algos: Vec<Algorithm> = match axis_strings(j, "algos")? {
        Some(names) => names
            .iter()
            .map(|n| n.parse::<Algorithm>())
            .collect::<Result<Vec<Algorithm>, String>>()?,
        None => match preset_cfg {
            Some(p) => p.algos.to_vec(),
            None => vec![base.algo],
        },
    };
    let ps: Vec<usize> = match axis_numbers(j, "p")? {
        Some(v) => v.into_iter().map(|n| n as usize).collect(),
        None => match preset_cfg {
            Some(p) => p.node_counts.to_vec(),
            None => vec![base.p],
        },
    };
    let taus: Vec<u64> = match axis_numbers(j, "tau")? {
        Some(v) => v,
        None => vec![preset_cfg.map_or(base.tau, |p| p.tau)],
    };
    let groups: Vec<usize> = match axis_numbers(j, "group_size")? {
        Some(v) => v.into_iter().map(|n| n as usize).collect(),
        None => vec![0],
    };
    let compressions: Vec<(String, Compression)> = match axis_strings(j, "compression")? {
        Some(specs) => specs
            .iter()
            .map(|sp| parse_compression_spec(sp).map(|c| (sp.clone(), c)))
            .collect::<Result<Vec<(String, Compression)>, String>>()?,
        None => vec![("none".to_string(), Compression::None)],
    };
    let fault_specs: Vec<String> =
        axis_strings(j, "faults")?.unwrap_or_else(|| vec!["none".to_string()]);
    let steps_override = j.get("steps").and_then(|v| v.as_usize());
    let model_bytes_override = j.get("model_bytes").and_then(|v| v.as_usize());

    let mut cells: Vec<(u64, SimConfig)> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    let mut duplicates = 0usize;
    for &algo in &algos {
        for &p in &ps {
            let template = match preset_cfg {
                Some(pre) => pre.sim_config(algo, p, seed),
                None => SimConfig { algo, p, seed, ..SimConfig::default() },
            };
            for &tau in &taus {
                for &group_size in &groups {
                    for (_, compress) in &compressions {
                        for fspec in &fault_specs {
                            let mut cfg = template.clone();
                            cfg.tau = tau;
                            cfg.group_size = group_size;
                            cfg.compress = *compress;
                            if let Some(st) = steps_override {
                                cfg.steps = st;
                            }
                            if let Some(mb) = model_bytes_override {
                                cfg.model_bytes = mb;
                            }
                            cfg.faults =
                                FaultPlan::parse(fspec, cfg.p, cfg.steps as u64, cfg.seed)?;
                            cfg.trace = false;
                            validate_config(&cfg)?;
                            let hash = config_hash(&cfg);
                            if seen.insert(hash) {
                                cells.push((hash, cfg));
                            } else {
                                duplicates += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    if cells.is_empty() {
        return Err("sweep grid is empty".into());
    }
    if cells.len() > MAX_SWEEP_CELLS {
        return Err(format!(
            "sweep grid has {} cells; the per-request ceiling is {MAX_SWEEP_CELLS} — split the sweep \
             (overlapping cells are cached, so split sweeps pay nothing twice)",
            cells.len()
        ));
    }
    Ok((cells, duplicates))
}

fn handle_sweep(state: &DaemonState, req: &Request, resp: &mut ResponseWriter) -> std::io::Result<()> {
    let parsed = Json::parse(&req.body_str())
        .map_err(|e| format!("body: {e}"))
        .and_then(|j| expand_sweep(&j));
    let (cells, duplicates) = match parsed {
        Ok(x) => x,
        Err(e) => return bad_request(resp, &e),
    };
    state.sweeps.fetch_add(1, Ordering::Relaxed);

    resp.start_chunked("200 OK", "application/jsonl")?;
    let total = cells.len();
    let mut hits = 0usize;
    let mut computed = 0usize;
    let mut errors = 0usize;
    let (tx, rx) = mpsc::channel();
    let mut pending = 0usize;
    // Cache hits stream immediately; misses go to the pool and stream
    // in completion order — the client sees progress, not a barrier.
    for (hash, cfg) in cells {
        if let Some(cell) = state.cache.get(hash) {
            hits += 1;
            stream_cell(resp, &cell, "hit")?;
        } else if state.pool.submit(Job { hash, cfg, reply: tx.clone() }).is_ok() {
            pending += 1;
        } else {
            errors += 1;
        }
    }
    drop(tx);
    for _ in 0..pending {
        match rx.recv_timeout(CELL_TIMEOUT) {
            Ok(done) => {
                let cell = finish_cell(state, done);
                computed += 1;
                stream_cell(resp, &cell, "miss")?;
            }
            Err(_) => {
                errors += 1;
                break;
            }
        }
    }
    let summary = obj(vec![(
        "summary",
        obj(vec![
            ("cells", num(total as f64)),
            ("cache_hits", num(hits as f64)),
            ("computed", num(computed as f64)),
            ("errors", num(errors as f64)),
            ("duplicates_collapsed", num(duplicates as f64)),
            ("daemon_cache_hits_total", num(state.cache_hits() as f64)),
            ("daemon_cache_misses_total", num(state.cache_misses() as f64)),
            ("daemon_cells_computed_total", num(state.cells_computed() as f64)),
        ]),
    )]);
    resp.chunk(&format!("{}\n", summary.to_string()))?;
    resp.finish()
}

fn stream_cell(resp: &mut ResponseWriter, cell: &CachedCell, cache: &str) -> std::io::Result<()> {
    let record = obj(vec![("cache", s(cache)), ("cell", cell_json(cell))]);
    resp.chunk(&format!("{}\n", record.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::canonical::canonical_string;
    use crate::serve::http::parse_response;

    fn small_cfg() -> SimConfig {
        SimConfig { p: 4, steps: 12, model_bytes: 1 << 16, ..SimConfig::default() }
    }

    fn daemon() -> Daemon {
        Daemon::start("127.0.0.1:0", 2, 64).expect("start daemon")
    }

    fn post(d: &Daemon, path: &str, body: &str) -> (String, String) {
        let raw = http_roundtrip(d, "POST", path, body);
        let (status, _, body) = parse_response(&raw).expect("parse");
        (status, String::from_utf8_lossy(&body).to_string())
    }

    fn get(d: &Daemon, path: &str) -> (String, String) {
        let raw = http_roundtrip(d, "GET", path, "");
        let (status, _, body) = parse_response(&raw).expect("parse");
        (status, String::from_utf8_lossy(&body).to_string())
    }

    fn http_roundtrip(d: &Daemon, method: &str, path: &str, body: &str) -> Vec<u8> {
        use std::io::{Read, Write};
        let mut stream = std::net::TcpStream::connect(d.local_addr()).expect("connect");
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(60)))
            .expect("timeout");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        stream.write_all(head.as_bytes()).expect("write");
        stream.write_all(body.as_bytes()).expect("write body");
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).expect("read");
        raw
    }

    #[test]
    fn simulate_twice_hits_cache_with_identical_cell_bytes() {
        let d = daemon();
        let body = canonical_string(&small_cfg());
        let (s1, b1) = post(&d, "/v1/simulate", &body);
        let (s2, b2) = post(&d, "/v1/simulate", &body);
        assert!(s1.contains("200"), "{s1}: {b1}");
        assert!(s2.contains("200"), "{s2}: {b2}");
        let j1 = Json::parse(&b1).expect("json1");
        let j2 = Json::parse(&b2).expect("json2");
        assert_eq!(j1.get("cache").and_then(|v| v.as_str()), Some("miss"));
        assert_eq!(j2.get("cache").and_then(|v| v.as_str()), Some("hit"));
        // The cell body is bit-identical across compute and replay.
        assert_eq!(
            j1.get("cell").expect("cell").to_string(),
            j2.get("cell").expect("cell").to_string()
        );
        assert_eq!(d.state().cells_computed(), 1);
        // ...and /v1/cells/<hash> replays the very same bytes.
        let hash = j1.get("cell").and_then(|c| c.get("hash")).and_then(|v| v.as_str()).expect("hash");
        let (s3, b3) = get(&d, &format!("/v1/cells/{hash}"));
        assert!(s3.contains("200"), "{s3}");
        assert_eq!(b3, j1.get("cell").expect("cell").to_string());
    }

    #[test]
    fn simulate_rejects_bad_configs() {
        let d = daemon();
        let mut cfg = small_cfg();
        cfg.p = 3;
        let (status, body) = post(&d, "/v1/simulate", &canonical_string(&cfg));
        assert!(status.contains("400"), "{status}: {body}");
        assert!(body.contains("power of two"), "{body}");
        let mut cfg = small_cfg();
        cfg.trace = true;
        let (status, body) = post(&d, "/v1/simulate", &canonical_string(&cfg));
        assert!(status.contains("400"), "{status}: {body}");
        let (status, body) = post(&d, "/v1/simulate", "{not json");
        assert!(status.contains("400"), "{status}: {body}");
    }

    #[test]
    fn sweep_streams_cells_then_summary_and_second_pass_is_all_hits() {
        let d = daemon();
        let sweep = r#"{"preset":"fig4","algos":["wagma","allreduce"],"p":[4],"tau":[10],"steps":10,"model_bytes":65536,"compression":["none","topk:0.5"]}"#;
        let (status, body) = post(&d, "/v1/sweep", sweep);
        assert!(status.contains("200"), "{status}: {body}");
        let lines: Vec<&str> = body.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(lines.len(), 5, "4 cells + summary: {body}");
        let summary = Json::parse(lines[4]).expect("summary json");
        let sget = |k: &str| {
            summary
                .get("summary")
                .and_then(|x| x.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(-1.0)
        };
        assert_eq!(sget("cells"), 4.0);
        assert_eq!(sget("computed"), 4.0);
        assert_eq!(sget("cache_hits"), 0.0);
        // Same sweep again: nothing computed, every cell a cache hit.
        let (_, body2) = post(&d, "/v1/sweep", sweep);
        let lines2: Vec<&str> = body2.lines().filter(|l| !l.trim().is_empty()).collect();
        let summary2 = Json::parse(lines2[4]).expect("summary json");
        let sget2 = |k: &str| {
            summary2
                .get("summary")
                .and_then(|x| x.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(-1.0)
        };
        assert_eq!(sget2("computed"), 0.0, "{body2}");
        assert_eq!(sget2("cache_hits"), 4.0, "{body2}");
        assert_eq!(d.state().cells_computed(), 4);
        // Cell records are bit-identical across the two passes (stream
        // order may differ: hits stream immediately, misses in
        // completion order — compare as sorted sets).
        let mut cells1: Vec<String> = lines[..4]
            .iter()
            .map(|l| Json::parse(l).expect("cell").get("cell").expect("cell").to_string())
            .collect();
        let mut cells2: Vec<String> = lines2[..4]
            .iter()
            .map(|l| Json::parse(l).expect("cell").get("cell").expect("cell").to_string())
            .collect();
        cells1.sort();
        cells2.sort();
        assert_eq!(cells1, cells2);
    }

    #[test]
    fn presets_and_healthz_routes_answer() {
        let d = daemon();
        let (status, body) = get(&d, "/v1/presets");
        assert!(status.contains("200"), "{status}");
        for name in preset_names() {
            assert!(body.contains(name), "{body}");
        }
        let (status, body) = get(&d, "/healthz");
        assert!(status.contains("200"), "{status}");
        assert!(body.starts_with("ok workers=2 "), "{body}");
        let (status, _) = get(&d, "/v1/cells/deadbeefdeadbeef");
        assert!(status.contains("404"), "{status}");
    }

    #[test]
    fn sweep_grid_respects_faults_axis_and_rejects_unknowns() {
        let d = daemon();
        let sweep = r#"{"p":[4],"steps":8,"model_bytes":65536,"faults":["none","crash@mid"]}"#;
        let (status, body) = post(&d, "/v1/sweep", sweep);
        assert!(status.contains("200"), "{status}: {body}");
        let lines: Vec<&str> = body.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(lines.len(), 3, "2 cells + summary: {body}");
        let (status, body) = post(&d, "/v1/sweep", r#"{"preset":"fig99"}"#);
        assert!(status.contains("400"), "{status}: {body}");
        let (status, body) = post(&d, "/v1/sweep", r#"{"p":[4],"compression":["zip"]}"#);
        assert!(status.contains("400"), "{status}: {body}");
    }
}
