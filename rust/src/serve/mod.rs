//! `wagma serve` — the discrete-event simulator as a long-running,
//! sharded, caching sweep service.
//!
//! Layers, bottom-up:
//!
//! * [`http`] — the hand-rolled `std::net` mini-router factored out of
//!   the telemetry metrics listener: request parsing, method+path
//!   routes with one trailing wildcard, full and chunked responses,
//!   and a socketless [`http::Router::dispatch`] for tests.
//! * [`canonical`] — the one canonical [`crate::simulator::SimConfig`]
//!   encoding (sorted-key JSON, exact f64 text) shared by the cache
//!   key, the API wire format, and replay comparison; plus the
//!   splitmix64 [`canonical::config_hash`] over that encoding.
//! * [`cache`] — the in-memory LRU of completed cells, storing the
//!   canonical encodings so replays are bit-identical by construction.
//! * [`daemon`] — `/v1/simulate`, `/v1/sweep` (worker-pool sharding +
//!   incremental JSONL streaming), `/v1/cells/<hash>`, `/v1/presets`,
//!   `/healthz`, and the re-exported `/metrics` + `/snapshot.json`
//!   telemetry routes.
//! * [`client`] — the figure harnesses' seam: local in-process
//!   simulation by default, `--addr` routes through a daemon.

pub mod cache;
pub mod canonical;
pub mod client;
pub mod daemon;
pub mod http;

pub use cache::{CachedCell, CellCache};
pub use canonical::{
    canonical_string, config_hash, decode_config, decode_result, encode_config, encode_result,
    hash_hex,
};
pub use client::{sweep_stream, Client};
pub use daemon::{add_metrics_routes, Daemon};
pub use http::{Request, ResponseWriter, Router, Server};
