//! Shared hand-rolled HTTP mini-router (offline environment: no HTTP
//! crate), factored out of the `--metrics-addr` listener that seeded it
//! (`telemetry::prometheus::MetricsServer`).
//!
//! One [`Router`] maps `(method, path)` pairs to handlers; one
//! [`Server`] runs the nonblocking accept loop (20 ms stop-flag poll,
//! request counter, joined on drop) that the seed used. On top of the
//! seed the router adds what the serve daemon needs: `POST` with
//! `Content-Length` body reading, a trailing-wildcard path segment
//! (`/v1/cells/*`), and `Transfer-Encoding: chunked` streaming so the
//! sweep endpoint can push JSON-lines records as worker threads finish
//! cells. Handlers write through a [`ResponseWriter`] over any
//! `io::Write`, so tests can dispatch a request into a byte buffer
//! without a socket ([`Router::dispatch`]).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Request head cap (the seed's 8 KiB) and body cap (1 MiB — a sweep
/// grid spec, not a bulk upload channel).
const MAX_HEAD: usize = 8192;
const MAX_BODY: usize = 1 << 20;

/// One parsed HTTP request: method, path (query string stripped), body.
#[derive(Debug, Clone, Default)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

impl Request {
    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }

    /// The path segment matched by a trailing `/*` wildcard, if any.
    /// `/v1/cells/abc` against pattern `/v1/cells/*` yields `"abc"`.
    pub fn wildcard<'a>(&'a self, pattern: &str) -> Option<&'a str> {
        let prefix = pattern.strip_suffix('*')?;
        self.path.strip_prefix(prefix).filter(|rest| !rest.is_empty() && !rest.contains('/'))
    }
}

/// Response sink handed to handlers. Exactly one of [`full`] or
/// [`start_chunked`]+[`chunk`]...+[`finish`] per request.
///
/// [`full`]: ResponseWriter::full
/// [`start_chunked`]: ResponseWriter::start_chunked
/// [`chunk`]: ResponseWriter::chunk
/// [`finish`]: ResponseWriter::finish
pub struct ResponseWriter<'a> {
    w: &'a mut dyn Write,
    started: bool,
    chunked: bool,
}

impl<'a> ResponseWriter<'a> {
    pub fn new(w: &'a mut dyn Write) -> ResponseWriter<'a> {
        ResponseWriter { w, started: false, chunked: false }
    }

    /// The seed's `write_response`: status + Content-Type +
    /// Content-Length + `Connection: close`, then the whole body.
    pub fn full(&mut self, status: &str, content_type: &str, body: &str) -> std::io::Result<()> {
        debug_assert!(!self.started, "response already started");
        self.started = true;
        let head = format!(
            "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        self.w.write_all(head.as_bytes())?;
        self.w.write_all(body.as_bytes())
    }

    /// Begin a `Transfer-Encoding: chunked` response (the JSONL stream).
    pub fn start_chunked(&mut self, status: &str, content_type: &str) -> std::io::Result<()> {
        debug_assert!(!self.started, "response already started");
        self.started = true;
        self.chunked = true;
        let head = format!(
            "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
        );
        self.w.write_all(head.as_bytes())
    }

    /// One chunk, flushed immediately so clients see records as they
    /// are produced, not when the sweep completes.
    pub fn chunk(&mut self, data: &str) -> std::io::Result<()> {
        debug_assert!(self.chunked, "chunk() outside a chunked response");
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data.as_bytes())?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminal zero-length chunk.
    pub fn finish(&mut self) -> std::io::Result<()> {
        debug_assert!(self.chunked, "finish() outside a chunked response");
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }

    fn responded(&self) -> bool {
        self.started
    }
}

type Handler = Box<dyn Fn(&Request, &mut ResponseWriter) -> std::io::Result<()> + Send + Sync>;

struct Route {
    method: &'static str,
    pattern: &'static str,
    handler: Handler,
}

fn pattern_matches(pattern: &str, path: &str) -> bool {
    match pattern.strip_suffix('*') {
        Some(prefix) => path
            .strip_prefix(prefix)
            .is_some_and(|rest| !rest.is_empty() && !rest.contains('/')),
        None => pattern == path,
    }
}

/// Method+path router. Unknown path → 404; known path, wrong method →
/// 405 (the seed's behaviour for non-GET, now per-route).
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn get(
        self,
        pattern: &'static str,
        f: impl Fn(&Request, &mut ResponseWriter) -> std::io::Result<()> + Send + Sync + 'static,
    ) -> Router {
        self.route("GET", pattern, f)
    }

    pub fn post(
        self,
        pattern: &'static str,
        f: impl Fn(&Request, &mut ResponseWriter) -> std::io::Result<()> + Send + Sync + 'static,
    ) -> Router {
        self.route("POST", pattern, f)
    }

    pub fn route(
        mut self,
        method: &'static str,
        pattern: &'static str,
        f: impl Fn(&Request, &mut ResponseWriter) -> std::io::Result<()> + Send + Sync + 'static,
    ) -> Router {
        self.routes.push(Route { method, pattern, handler: Box::new(f) });
        self
    }

    /// Every `(method, pattern)` pair this router serves — the surface
    /// the exposition-lint sweep walks so a new route cannot dodge it.
    pub fn served_routes(&self) -> Vec<(&'static str, &'static str)> {
        self.routes.iter().map(|r| (r.method, r.pattern)).collect()
    }

    /// Route one request into `resp`.
    pub fn handle(&self, req: &Request, resp: &mut ResponseWriter) -> std::io::Result<()> {
        let mut path_known = false;
        for r in &self.routes {
            if pattern_matches(r.pattern, &req.path) {
                path_known = true;
                if r.method == req.method {
                    (r.handler)(req, resp)?;
                    if !resp.responded() {
                        return resp.full(
                            "500 Internal Server Error",
                            "text/plain",
                            "handler wrote no response\n",
                        );
                    }
                    return Ok(());
                }
            }
        }
        if path_known {
            resp.full("405 Method Not Allowed", "text/plain", "method not allowed\n")
        } else {
            resp.full("404 Not Found", "text/plain", "not found\n")
        }
    }

    /// In-process dispatch for tests and the exposition-lint sweep: run
    /// a request through the router into a buffer and return the raw
    /// HTTP response bytes.
    pub fn dispatch(&self, method: &str, path: &str, body: &[u8]) -> std::io::Result<Vec<u8>> {
        let req = Request {
            method: method.to_string(),
            path: path.to_string(),
            body: body.to_vec(),
        };
        let mut buf = Vec::new();
        {
            let mut resp = ResponseWriter::new(&mut buf);
            self.handle(&req, &mut resp)?;
        }
        Ok(buf)
    }
}

/// Split a raw HTTP response into `(status_line, content_type, body)`,
/// decoding chunked transfer encoding. Shared by the serve client and
/// the tests.
pub fn parse_response(raw: &[u8]) -> Result<(String, String, Vec<u8>), String> {
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or("malformed HTTP response (no header terminator)")?;
    let head = String::from_utf8_lossy(&raw[..split]).to_string();
    let payload = &raw[split + 4..];
    let status = head.lines().next().unwrap_or("").to_string();
    let header = |name: &str| -> Option<String> {
        head.lines().skip(1).find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.trim().eq_ignore_ascii_case(name).then(|| v.trim().to_string())
        })
    };
    let content_type = header("Content-Type").unwrap_or_default();
    let chunked = header("Transfer-Encoding").is_some_and(|v| v.eq_ignore_ascii_case("chunked"));
    let body = if chunked { decode_chunked(payload)? } else { payload.to_vec() };
    Ok((status, content_type, body))
}

pub fn decode_chunked(mut rest: &[u8]) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    loop {
        let line_end = rest
            .windows(2)
            .position(|w| w == b"\r\n")
            .ok_or("chunked body: missing size line")?;
        let size_line = std::str::from_utf8(&rest[..line_end])
            .map_err(|_| "chunked body: non-utf8 size line".to_string())?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| format!("chunked body: bad chunk size `{size_line}`"))?;
        rest = &rest[line_end + 2..];
        if size == 0 {
            return Ok(out);
        }
        if rest.len() < size + 2 {
            return Err("chunked body: truncated chunk".into());
        }
        out.extend_from_slice(&rest[..size]);
        rest = &rest[size + 2..];
    }
}

/// The accept loop from the seed: nonblocking listener polled every
/// 20 ms against a stop flag, one counted request per connection,
/// thread joined on drop.
pub struct Server {
    addr: SocketAddr,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    requests: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (port 0 picks an ephemeral port) and serve `router`
    /// from a named thread until dropped.
    pub fn serve(addr: &str, thread_name: &str, router: Arc<Router>) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let requests = Arc::new(AtomicU64::new(0));
        let (stop_t, req_t, router_t) = (Arc::clone(&stop), Arc::clone(&requests), Arc::clone(&router));
        let handle = std::thread::Builder::new()
            .name(thread_name.to_string())
            .spawn(move || loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if handle_conn(stream, &router_t).is_ok() {
                            req_t.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if stop_t.load(Ordering::Acquire) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(_) => {
                        if stop_t.load(Ordering::Acquire) {
                            return;
                        }
                    }
                }
            })?;
        Ok(Server { addr: local, router, stop, requests, handle: Some(handle) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Successfully answered requests (any route).
    pub fn requests_served(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(mut stream: TcpStream, router: &Router) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    // Read the request head (and whatever body bytes rode along).
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    let head_end = loop {
        if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break i;
        }
        if buf.len() >= MAX_HEAD {
            let mut resp = ResponseWriter::new(&mut stream);
            return resp.full("431 Request Header Fields Too Large", "text/plain", "head too large\n");
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before request head",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let (method, raw_path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let path = raw_path.split('?').next().unwrap_or("");
    let content_length = head
        .lines()
        .skip(1)
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.trim().eq_ignore_ascii_case("Content-Length").then(|| v.trim().parse::<usize>().ok())?
        })
        .unwrap_or(0);
    if content_length > MAX_BODY {
        let mut resp = ResponseWriter::new(&mut stream);
        return resp.full("413 Payload Too Large", "text/plain", "body too large\n");
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    let req = Request { method: method.to_string(), path: path.to_string(), body };
    let mut resp = ResponseWriter::new(&mut stream);
    router.handle(&req, &mut resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn text_router() -> Router {
        Router::new()
            .get("/hello", |_req, resp| resp.full("200 OK", "text/plain", "hi\n"))
            .post("/echo", |req, resp| {
                resp.full("200 OK", "text/plain", &req.body_str())
            })
            .get("/v1/cells/*", |req, resp| {
                let id = req.wildcard("/v1/cells/*").unwrap_or("?");
                resp.full("200 OK", "text/plain", &format!("cell {id}\n"))
            })
            .get("/stream", |_req, resp| {
                resp.start_chunked("200 OK", "application/jsonl")?;
                resp.chunk("{\"a\":1}\n")?;
                resp.chunk("{\"a\":2}\n")?;
                resp.finish()
            })
    }

    fn status_of(raw: &[u8]) -> String {
        parse_response(raw).expect("parse").0
    }

    #[test]
    fn routes_match_method_and_path() {
        let r = text_router();
        assert!(status_of(&r.dispatch("GET", "/hello", b"").unwrap()).contains("200"));
        assert!(status_of(&r.dispatch("POST", "/hello", b"").unwrap()).contains("405"));
        assert!(status_of(&r.dispatch("GET", "/nope", b"").unwrap()).contains("404"));
        let (_, _, body) = parse_response(&r.dispatch("POST", "/echo", b"payload").unwrap()).unwrap();
        assert_eq!(body, b"payload");
    }

    #[test]
    fn wildcard_matches_one_trailing_segment() {
        let r = text_router();
        let (_, _, body) = parse_response(&r.dispatch("GET", "/v1/cells/abc123", b"").unwrap()).unwrap();
        assert_eq!(body, b"cell abc123\n");
        // No segment or nested segments do not match.
        assert!(status_of(&r.dispatch("GET", "/v1/cells/", b"").unwrap()).contains("404"));
        assert!(status_of(&r.dispatch("GET", "/v1/cells/a/b", b"").unwrap()).contains("404"));
    }

    #[test]
    fn chunked_stream_round_trips() {
        let r = text_router();
        let raw = r.dispatch("GET", "/stream", b"").unwrap();
        let (status, ctype, body) = parse_response(&raw).expect("parse");
        assert!(status.contains("200"), "{status}");
        assert_eq!(ctype, "application/jsonl");
        assert_eq!(body, b"{\"a\":1}\n{\"a\":2}\n");
    }

    #[test]
    fn served_routes_lists_every_route() {
        let r = text_router();
        let routes = r.served_routes();
        assert!(routes.contains(&("GET", "/hello")));
        assert!(routes.contains(&("POST", "/echo")));
        assert!(routes.contains(&("GET", "/v1/cells/*")));
        assert_eq!(routes.len(), 4);
    }

    #[test]
    fn server_serves_over_tcp_with_post_body() {
        let server =
            Server::serve("127.0.0.1:0", "wagma-http-test", Arc::new(text_router())).expect("bind");
        let addr = server.local_addr().to_string();
        let mut stream = TcpStream::connect(&addr).expect("connect");
        let body = b"over the wire";
        let req = format!(
            "POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        stream.write_all(req.as_bytes()).expect("write head");
        stream.write_all(body).expect("write body");
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).expect("read");
        let (status, _, got) = parse_response(&raw).expect("parse");
        assert!(status.contains("200"), "{status}");
        assert_eq!(got, body);
        // The counter increments just after the connection closes; give
        // the accept thread a moment rather than racing it.
        for _ in 0..100 {
            if server.requests_served() == 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(server.requests_served(), 1);
    }
}
