//! Client side of the serve API.
//!
//! [`Client`] is the seam the figure harnesses run through: in local
//! mode `simulate` calls the simulator in-process (the historical
//! behaviour, bit-for-bit); in remote mode it POSTs the canonical
//! config to a `wagma serve` daemon and decodes the canonical result.
//! Because both paths round-trip through the same canonical codec is
//! *not* needed for identity — the local path never encodes at all —
//! identity instead falls out of the simulator being deterministic and
//! the codec being exact (f64s print as shortest round-trip strings).
//!
//! [`sweep_stream`] consumes `POST /v1/sweep`'s chunked JSON-lines
//! incrementally: each record invokes the callback as soon as its line
//! is complete on the wire, so callers observe streaming (and can log
//! progress) rather than a single end-of-sweep buffer.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::simulator::{simulate, SimConfig, SimResult};
use crate::util::json::Json;

use super::canonical::{canonical_string, decode_result};
use super::http::parse_response;

const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);
const IO_TIMEOUT: Duration = Duration::from_secs(600);

/// Where simulation requests go: in-process, or a serve daemon.
#[derive(Debug, Clone)]
pub struct Client {
    addr: Option<String>,
}

impl Client {
    /// In-process simulation — the default, and the fallback when no
    /// `--addr` is given.
    pub fn local() -> Client {
        Client { addr: None }
    }

    /// Route every `simulate` through the daemon at `addr`.
    pub fn remote(addr: &str) -> Client {
        Client { addr: Some(addr.to_string()) }
    }

    /// `--addr` plumbing: `Some(addr)` → remote, `None` → local.
    pub fn from_addr(addr: Option<&str>) -> Client {
        match addr {
            Some(a) => Client::remote(a),
            None => Client::local(),
        }
    }

    pub fn is_remote(&self) -> bool {
        self.addr.is_some()
    }

    /// Run one cell. Remote mode POSTs `/v1/simulate`; the daemon's
    /// cache makes repeated figure sweeps over the same grid free.
    pub fn simulate(&self, cfg: &SimConfig) -> Result<SimResult> {
        let Some(addr) = &self.addr else {
            return Ok(simulate(cfg));
        };
        let (status, body) = post(addr, "/v1/simulate", &canonical_string(cfg))
            .with_context(|| format!("POST /v1/simulate to {addr}"))?;
        if !status.contains("200") {
            bail!("daemon {addr} answered {status}: {}", String::from_utf8_lossy(&body));
        }
        let j = Json::parse(&String::from_utf8_lossy(&body))
            .map_err(|e| anyhow!("daemon response: {e}"))?;
        let result = j
            .get("cell")
            .and_then(|c| c.get("result"))
            .ok_or_else(|| anyhow!("daemon response missing cell.result"))?;
        decode_result(result).map_err(|e| anyhow!("decode result: {e}"))
    }
}

fn connect(addr: &str) -> Result<TcpStream> {
    let sockaddr = addr
        .parse::<std::net::SocketAddr>()
        .or_else(|_| {
            use std::net::ToSocketAddrs;
            addr.to_socket_addrs()
                .map_err(|e| anyhow!("resolve {addr}: {e}"))?
                .next()
                .ok_or_else(|| anyhow!("resolve {addr}: no addresses"))
        })
        .with_context(|| format!("bad address {addr}"))?;
    let stream = TcpStream::connect_timeout(&sockaddr, CONNECT_TIMEOUT)
        .with_context(|| format!("connect to {addr}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).context("set read timeout")?;
    stream.set_write_timeout(Some(IO_TIMEOUT)).context("set write timeout")?;
    Ok(stream)
}

fn write_request(stream: &mut TcpStream, method: &str, path: &str, body: &str) -> Result<()> {
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: wagma\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes()).context("write request head")?;
    stream.write_all(body.as_bytes()).context("write request body")?;
    Ok(())
}

/// Buffered POST: returns (status line, body bytes). Chunked responses
/// are decoded whole — use [`sweep_stream`] to observe records early.
pub fn post(addr: &str, path: &str, body: &str) -> Result<(String, Vec<u8>)> {
    let mut stream = connect(addr)?;
    write_request(&mut stream, "POST", path, body)?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("read response")?;
    let (status, _ctype, body) = parse_response(&raw).map_err(|e| anyhow!("{e}"))?;
    Ok((status, body))
}

/// Buffered GET.
pub fn get(addr: &str, path: &str) -> Result<(String, Vec<u8>)> {
    let mut stream = connect(addr)?;
    write_request(&mut stream, "GET", path, "")?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("read response")?;
    let (status, _ctype, body) = parse_response(&raw).map_err(|e| anyhow!("{e}"))?;
    Ok((status, body))
}

/// Drive `POST /v1/sweep` and surface each JSONL record *as it lands
/// on the wire* — cache hits arrive before the first computed cell
/// finishes, which is the observable proof the stream is incremental.
/// Returns the final `{"summary":...}` record.
pub fn sweep_stream(
    addr: &str,
    request_body: &str,
    mut on_record: impl FnMut(&Json),
) -> Result<Json> {
    let mut stream = connect(addr)?;
    write_request(&mut stream, "POST", "/v1/sweep", request_body)?;
    let mut reader = BufReader::new(stream);

    // Status line + headers.
    let mut line = String::new();
    reader.read_line(&mut line).context("read status line")?;
    let status = line.trim().to_string();
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("read header")?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        let lower = h.to_ascii_lowercase();
        if lower.starts_with("transfer-encoding:") && lower.contains("chunked") {
            chunked = true;
        }
    }
    if !status.contains("200") {
        let mut rest = Vec::new();
        let _ = reader.read_to_end(&mut rest);
        let body = if chunked {
            super::http::decode_chunked(&rest).unwrap_or(rest)
        } else {
            rest
        };
        bail!("sweep to {addr} answered {status}: {}", String::from_utf8_lossy(&body));
    }
    if !chunked {
        bail!("sweep response was not chunked — daemon too old?");
    }

    // Chunk loop: records are newline-terminated JSON objects; a chunk
    // boundary need not align with a record boundary, so buffer.
    let mut pending = String::new();
    let mut summary: Option<Json> = None;
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line).context("read chunk size")?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| anyhow!("bad chunk size line {size_line:?}"))?;
        let mut chunk = vec![0u8; size + 2]; // payload + trailing \r\n
        reader.read_exact(&mut chunk).context("read chunk")?;
        if size == 0 {
            break;
        }
        pending.push_str(&String::from_utf8_lossy(&chunk[..size]));
        while let Some(nl) = pending.find('\n') {
            let record_line: String = pending.drain(..=nl).collect();
            let record_line = record_line.trim();
            if record_line.is_empty() {
                continue;
            }
            let record =
                Json::parse(record_line).map_err(|e| anyhow!("bad sweep record: {e}"))?;
            if record.get("summary").is_some() {
                summary = Some(record);
            } else {
                on_record(&record);
            }
        }
    }
    summary.ok_or_else(|| anyhow!("sweep stream ended without a summary record"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::daemon::Daemon;

    fn cfg() -> SimConfig {
        SimConfig { p: 4, steps: 10, model_bytes: 1 << 16, ..SimConfig::default() }
    }

    #[test]
    fn local_client_matches_direct_simulate_bitwise() {
        let c = Client::local();
        let direct = simulate(&cfg());
        let via = c.simulate(&cfg()).expect("local simulate");
        assert_eq!(
            crate::serve::canonical::encode_result(&direct).to_string(),
            crate::serve::canonical::encode_result(&via).to_string()
        );
    }

    #[test]
    fn remote_client_round_trips_the_result_bitwise() {
        let d = Daemon::start("127.0.0.1:0", 2, 16).expect("daemon");
        let addr = d.local_addr().to_string();
        let c = Client::remote(&addr);
        let remote = c.simulate(&cfg()).expect("remote simulate");
        let inline = simulate(&cfg());
        assert_eq!(
            crate::serve::canonical::encode_result(&remote).to_string(),
            crate::serve::canonical::encode_result(&inline).to_string()
        );
    }

    #[test]
    fn sweep_stream_yields_records_then_summary() {
        let d = Daemon::start("127.0.0.1:0", 2, 16).expect("daemon");
        let addr = d.local_addr().to_string();
        let body = r#"{"p":[4],"algos":["wagma","local"],"steps":8,"model_bytes":65536}"#;
        let mut seen = 0usize;
        let summary = sweep_stream(&addr, body, |rec| {
            assert!(rec.get("cell").is_some());
            seen += 1;
        })
        .expect("sweep");
        assert_eq!(seen, 2);
        let cells = summary
            .get("summary")
            .and_then(|x| x.get("cells"))
            .and_then(|v| v.as_f64());
        assert_eq!(cells, Some(2.0));
    }
}
