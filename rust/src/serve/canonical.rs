//! The one canonical [`SimConfig`] encoding: JSON with sorted keys,
//! shared by the cache key, the HTTP API, and round-trip tests.
//!
//! [`Json`] objects are `BTreeMap`-backed, so [`Json::to_string`] emits
//! keys in sorted order no matter how a request spelled them — parsing
//! any field ordering and re-encoding yields the identical byte string.
//! That string is the canonical form; [`config_hash`] is a splitmix64
//! chain over it (the same mixer the fault plans use for per-link
//! hashes). Floats round-trip exactly: Rust's `f64` `Display` prints
//! the shortest string that parses back to the same bits, which is what
//! makes daemon-computed and cache-replayed cells bit-identical to
//! inline ones.
//!
//! Decoding is strict — every field must be present with the right type
//! — so a canonical string is total: two configs hash equal iff they
//! are equal. (`Json` numbers are f64-backed, so integer fields above
//! 2^53 are not representable; seeds and sizes in practice are far
//! below that.)

use crate::compress::Compression;
use crate::data::ImbalanceModel;
use crate::fault::{Crash, FaultPlan, LinkFaults, Stall};
use crate::optim::Algorithm;
use crate::sched::{FusionConfig, FusionMode};
use crate::simulator::{NetworkModel, SimConfig, SimResult};
use crate::util::json::{arr, num, obj, s, Json};

/// Canonical JSON string of a config: sorted keys, shortest-round-trip
/// floats. This exact string is hashed for the cache key.
pub fn canonical_string(cfg: &SimConfig) -> String {
    encode_config(cfg).to_string()
}

/// 64-bit hash of the canonical string (splitmix64 chain over bytes).
pub fn config_hash(cfg: &SimConfig) -> u64 {
    hash_bytes(canonical_string(cfg).as_bytes())
}

/// Lower-hex form used in `/v1/cells/<hash>` URLs and cell records.
pub fn hash_hex(h: u64) -> String {
    format!("{h:016x}")
}

pub fn parse_hash_hex(text: &str) -> Result<u64, String> {
    u64::from_str_radix(text, 16).map_err(|_| format!("bad cell hash `{text}`"))
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h = 0x5157_4147_4d41_0001u64; // "WAGMA" tag: domain-separates this hash family.
    for chunk in bytes.chunks(8) {
        let mut word = [0u8; 8];
        word[..chunk.len()].copy_from_slice(chunk);
        h = splitmix64(h ^ u64::from_le_bytes(word));
    }
    splitmix64(h ^ bytes.len() as u64)
}

/// Encode one config as a [`Json`] object (sorted keys by construction).
pub fn encode_config(cfg: &SimConfig) -> Json {
    obj(vec![
        ("algo", s(cfg.algo.name())),
        ("p", num(cfg.p as f64)),
        ("steps", num(cfg.steps as f64)),
        ("model_bytes", num(cfg.model_bytes as f64)),
        ("tau", num(cfg.tau as f64)),
        ("group_size", num(cfg.group_size as f64)),
        ("dynamic_groups", Json::Bool(cfg.dynamic_groups)),
        ("local_sgd_h", num(cfg.local_sgd_h as f64)),
        ("sgp_neighbors", num(cfg.sgp_neighbors as f64)),
        ("imbalance", encode_imbalance(&cfg.imbalance)),
        ("net", encode_net(&cfg.net)),
        ("seed", num(cfg.seed as f64)),
        ("fusion", encode_fusion(&cfg.fusion)),
        ("compress", encode_compress(&cfg.compress)),
        ("trace", Json::Bool(cfg.trace)),
        ("faults", encode_faults(&cfg.faults)),
    ])
}

/// Strict decode: every field required, unknown enum kinds rejected.
pub fn decode_config(j: &Json) -> Result<SimConfig, String> {
    let algo: Algorithm = req_str(j, "algo")?.parse()?;
    Ok(SimConfig {
        algo,
        p: req_usize(j, "p")?,
        steps: req_usize(j, "steps")?,
        model_bytes: req_usize(j, "model_bytes")?,
        tau: req_u64(j, "tau")?,
        group_size: req_usize(j, "group_size")?,
        dynamic_groups: req_bool(j, "dynamic_groups")?,
        local_sgd_h: req_u64(j, "local_sgd_h")?,
        sgp_neighbors: req_usize(j, "sgp_neighbors")?,
        imbalance: decode_imbalance(req(j, "imbalance")?)?,
        net: decode_net(req(j, "net")?)?,
        seed: req_u64(j, "seed")?,
        fusion: decode_fusion(req(j, "fusion")?)?,
        compress: decode_compress(req(j, "compress")?)?,
        trace: req_bool(j, "trace")?,
        faults: decode_faults(req(j, "faults")?)?,
    })
}

/// Encode a result for the wire and the cell cache. The trace event
/// list is intentionally excluded: cells are priced timings, not
/// timelines (the daemon rejects `trace: true` configs).
pub fn encode_result(r: &SimResult) -> Json {
    obj(vec![
        ("algo", s(&r.algo)),
        ("p", num(r.p as f64)),
        ("steps", num(r.steps as f64)),
        ("makespan", num(r.makespan)),
        ("ideal_makespan", num(r.ideal_makespan)),
        ("iter_times", arr(r.iter_times.iter().map(|&t| num(t)))),
        ("mean_skew", num(r.mean_skew)),
        ("wire_bytes_per_iter", num(r.wire_bytes_per_iter)),
    ])
}

pub fn decode_result(j: &Json) -> Result<SimResult, String> {
    let iter_times = req(j, "iter_times")?
        .as_arr()
        .ok_or("result.iter_times: not an array")?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "result.iter_times: non-number entry".to_string()))
        .collect::<Result<Vec<f64>, String>>()?;
    Ok(SimResult {
        algo: req_str(j, "algo")?.to_string(),
        p: req_usize(j, "p")?,
        steps: req_usize(j, "steps")?,
        makespan: req_f64(j, "makespan")?,
        ideal_makespan: req_f64(j, "ideal_makespan")?,
        iter_times,
        mean_skew: req_f64(j, "mean_skew")?,
        wire_bytes_per_iter: req_f64(j, "wire_bytes_per_iter")?,
        trace: Vec::new(),
    })
}

fn encode_imbalance(m: &ImbalanceModel) -> Json {
    match *m {
        ImbalanceModel::Balanced { base, jitter } => obj(vec![
            ("kind", s("balanced")),
            ("base", num(base)),
            ("jitter", num(jitter)),
        ]),
        ImbalanceModel::RandomStragglers { base, jitter, delay, count } => obj(vec![
            ("kind", s("random_stragglers")),
            ("base", num(base)),
            ("jitter", num(jitter)),
            ("delay", num(delay)),
            ("count", num(count as f64)),
        ]),
        ImbalanceModel::BucketedLognormal { scale, mu, sigma, buckets } => obj(vec![
            ("kind", s("bucketed_lognormal")),
            ("scale", num(scale)),
            ("mu", num(mu)),
            ("sigma", num(sigma)),
            ("buckets", num(buckets as f64)),
        ]),
        ImbalanceModel::HeavyTail { median, sigma, min, max } => obj(vec![
            ("kind", s("heavy_tail")),
            ("median", num(median)),
            ("sigma", num(sigma)),
            ("min", num(min)),
            ("max", num(max)),
        ]),
    }
}

fn decode_imbalance(j: &Json) -> Result<ImbalanceModel, String> {
    match req_str(j, "kind")? {
        "balanced" => Ok(ImbalanceModel::Balanced {
            base: req_f64(j, "base")?,
            jitter: req_f64(j, "jitter")?,
        }),
        "random_stragglers" => Ok(ImbalanceModel::RandomStragglers {
            base: req_f64(j, "base")?,
            jitter: req_f64(j, "jitter")?,
            delay: req_f64(j, "delay")?,
            count: req_usize(j, "count")?,
        }),
        "bucketed_lognormal" => Ok(ImbalanceModel::BucketedLognormal {
            scale: req_f64(j, "scale")?,
            mu: req_f64(j, "mu")?,
            sigma: req_f64(j, "sigma")?,
            buckets: req_usize(j, "buckets")?,
        }),
        "heavy_tail" => Ok(ImbalanceModel::HeavyTail {
            median: req_f64(j, "median")?,
            sigma: req_f64(j, "sigma")?,
            min: req_f64(j, "min")?,
            max: req_f64(j, "max")?,
        }),
        other => Err(format!("imbalance.kind: unknown `{other}`")),
    }
}

fn encode_net(n: &NetworkModel) -> Json {
    obj(vec![
        ("alpha", num(n.alpha)),
        ("beta", num(n.beta)),
        ("gamma", num(n.gamma)),
        ("contention", num(n.contention)),
        ("delta", num(n.delta)),
    ])
}

fn decode_net(j: &Json) -> Result<NetworkModel, String> {
    Ok(NetworkModel {
        alpha: req_f64(j, "alpha")?,
        beta: req_f64(j, "beta")?,
        gamma: req_f64(j, "gamma")?,
        contention: req_f64(j, "contention")?,
        delta: req_f64(j, "delta")?,
    })
}

fn encode_fusion(f: &FusionConfig) -> Json {
    obj(vec![
        ("layered", Json::Bool(f.layered)),
        ("mode", s(f.mode.name())),
        ("threshold_bytes", num(f.threshold_bytes as f64)),
    ])
}

fn decode_fusion(j: &Json) -> Result<FusionConfig, String> {
    let mode: FusionMode = req_str(j, "mode")?.parse()?;
    Ok(FusionConfig {
        layered: req_bool(j, "layered")?,
        mode,
        threshold_bytes: req_usize(j, "threshold_bytes")?,
    })
}

fn encode_compress(c: &Compression) -> Json {
    match *c {
        Compression::TopK { ratio } => obj(vec![("kind", s("topk")), ("ratio", num(ratio))]),
        _ => obj(vec![("kind", s(c.name()))]),
    }
}

fn decode_compress(j: &Json) -> Result<Compression, String> {
    match req_str(j, "kind")? {
        "none" => Ok(Compression::None),
        "q8" => Ok(Compression::QuantizeQ8),
        "topk" => {
            let ratio = req_f64(j, "ratio")?;
            if !(ratio > 0.0 && ratio <= 1.0) {
                return Err(format!("compress.ratio must be in (0, 1], got {ratio}"));
            }
            Ok(Compression::TopK { ratio })
        }
        other => Err(format!("compress.kind: unknown `{other}` (none|topk|q8)")),
    }
}

fn encode_faults(f: &FaultPlan) -> Json {
    obj(vec![
        ("seed", num(f.seed as f64)),
        (
            "crashes",
            arr(f.crashes.iter().map(|c| {
                obj(vec![("rank", num(c.rank as f64)), ("at_iter", num(c.at_iter as f64))])
            })),
        ),
        (
            "stalls",
            arr(f.stalls.iter().map(|st| {
                obj(vec![
                    ("rank", num(st.rank as f64)),
                    ("from", num(st.from as f64)),
                    ("to", num(st.to as f64)),
                    ("seconds", num(st.seconds)),
                ])
            })),
        ),
        ("skew", arr(f.skew.iter().map(|&x| num(x)))),
        ("jitter_s", num(f.link.jitter_s)),
        ("drop_prob", num(f.link.drop_prob)),
        ("deadline_s", num(f.deadline_s)),
    ])
}

fn decode_faults(j: &Json) -> Result<FaultPlan, String> {
    let crashes = req(j, "crashes")?
        .as_arr()
        .ok_or("faults.crashes: not an array")?
        .iter()
        .map(|c| {
            Ok(Crash { rank: req_usize(c, "rank")?, at_iter: req_u64(c, "at_iter")? })
        })
        .collect::<Result<Vec<Crash>, String>>()?;
    let stalls = req(j, "stalls")?
        .as_arr()
        .ok_or("faults.stalls: not an array")?
        .iter()
        .map(|st| {
            Ok(Stall {
                rank: req_usize(st, "rank")?,
                from: req_u64(st, "from")?,
                to: req_u64(st, "to")?,
                seconds: req_f64(st, "seconds")?,
            })
        })
        .collect::<Result<Vec<Stall>, String>>()?;
    let skew = req(j, "skew")?
        .as_arr()
        .ok_or("faults.skew: not an array")?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "faults.skew: non-number entry".to_string()))
        .collect::<Result<Vec<f64>, String>>()?;
    Ok(FaultPlan {
        seed: req_u64(j, "seed")?,
        crashes,
        stalls,
        skew,
        link: LinkFaults { jitter_s: req_f64(j, "jitter_s")?, drop_prob: req_f64(j, "drop_prob")? },
        deadline_s: req_f64(j, "deadline_s")?,
    })
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| format!("missing field `{key}`"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64, String> {
    req(j, key)?.as_f64().ok_or_else(|| format!("field `{key}`: not a number"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, String> {
    req(j, key)?.as_usize().ok_or_else(|| format!("field `{key}`: not a non-negative integer"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64, String> {
    let v = req_f64(j, key)?;
    if v < 0.0 || v.fract() != 0.0 {
        return Err(format!("field `{key}`: not a non-negative integer"));
    }
    Ok(v as u64)
}

fn req_bool(j: &Json, key: &str) -> Result<bool, String> {
    req(j, key)?.as_bool().ok_or_else(|| format!("field `{key}`: not a boolean"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, String> {
    req(j, key)?.as_str().ok_or_else(|| format!("field `{key}`: not a string"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::DEFAULT_DEADLINE_S;

    /// A config exercising every nested structure: faults (crashes,
    /// stalls, skew, link), top-k compression, layered fusion, and a
    /// non-default imbalance model.
    pub(crate) fn busy_config() -> SimConfig {
        SimConfig {
            algo: Algorithm::Wagma,
            p: 16,
            steps: 40,
            model_bytes: 1 << 20,
            tau: 8,
            group_size: 4,
            dynamic_groups: true,
            local_sgd_h: 2,
            sgp_neighbors: 3,
            imbalance: ImbalanceModel::HeavyTail { median: 1.9, sigma: 0.75, min: 1.7, max: 43.5 },
            net: NetworkModel::aries(),
            seed: 7,
            fusion: FusionConfig { layered: true, mode: FusionMode::MgWfbp, threshold_bytes: 4096 },
            compress: Compression::TopK { ratio: 0.25 },
            trace: false,
            faults: FaultPlan {
                seed: 11,
                crashes: vec![Crash { rank: 5, at_iter: 20 }],
                stalls: vec![Stall { rank: 2, from: 3, to: 9, seconds: 0.125 }],
                skew: vec![1.0, 1.5, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
                link: LinkFaults { jitter_s: 0.002, drop_prob: 0.01 },
                deadline_s: DEFAULT_DEADLINE_S,
            },
        }
    }

    #[test]
    fn round_trips_every_field() {
        let cfg = busy_config();
        let decoded = decode_config(&encode_config(&cfg)).expect("decode");
        assert_eq!(decoded, cfg);
        // Defaults round-trip too (empty fault plan, no compression).
        let plain = SimConfig::default();
        assert_eq!(decode_config(&encode_config(&plain)).expect("decode"), plain);
    }

    #[test]
    fn canonical_string_is_field_order_independent() {
        let cfg = busy_config();
        let canonical = canonical_string(&cfg);
        // Parse and re-serialize: the BTreeMap normalizes key order.
        let reparsed = Json::parse(&canonical).expect("parse").to_string();
        assert_eq!(reparsed, canonical);
        // A hostile field ordering — top-level keys reversed by hand —
        // still decodes to the same config and the same hash.
        let Json::Obj(map) = Json::parse(&canonical).expect("parse") else { panic!("not an object") };
        let mut scrambled = String::from("{");
        for (i, (k, v)) in map.iter().rev().enumerate() {
            if i > 0 {
                scrambled.push(',');
            }
            scrambled.push_str(&format!("\"{k}\":{}", v.to_string()));
        }
        scrambled.push('}');
        assert_ne!(scrambled, canonical, "scramble should reorder keys");
        let from_scrambled = decode_config(&Json::parse(&scrambled).expect("parse")).expect("decode");
        assert_eq!(from_scrambled, cfg);
        assert_eq!(config_hash(&from_scrambled), config_hash(&cfg));
        assert_eq!(canonical_string(&from_scrambled), canonical);
    }

    #[test]
    fn hash_separates_configs_and_hex_round_trips() {
        let a = busy_config();
        let mut b = a.clone();
        b.seed += 1;
        assert_ne!(config_hash(&a), config_hash(&b));
        let mut c = a.clone();
        c.compress = Compression::TopK { ratio: 0.250001 };
        assert_ne!(config_hash(&a), config_hash(&c));
        let h = config_hash(&a);
        assert_eq!(parse_hash_hex(&hash_hex(h)).expect("hex"), h);
    }

    #[test]
    fn strict_decode_rejects_missing_and_unknown() {
        let mut j = encode_config(&SimConfig::default());
        if let Json::Obj(map) = &mut j {
            map.remove("tau");
        }
        assert!(decode_config(&j).unwrap_err().contains("tau"));
        let bad = Json::parse(r#"{"kind":"warp_drive"}"#).expect("parse");
        assert!(decode_imbalance(&bad).unwrap_err().contains("warp_drive"));
    }

    #[test]
    fn result_codec_round_trips_bitwise() {
        let r = crate::simulator::simulate(&SimConfig {
            p: 4,
            steps: 10,
            ..SimConfig::default()
        });
        let encoded = encode_result(&r);
        let decoded = decode_result(&encoded).expect("decode");
        // Bit-identity through the text form: f64 Display is shortest
        // round-trip, so encode(decode(encode(r))) == encode(r).
        assert_eq!(encode_result(&decoded).to_string(), encoded.to_string());
        assert_eq!(decoded.makespan.to_bits(), r.makespan.to_bits());
        assert_eq!(decoded.iter_times.len(), r.iter_times.len());
        for (a, b) in decoded.iter_times.iter().zip(&r.iter_times) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
