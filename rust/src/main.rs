//! `wagma` — the WAGMA-SGD launcher.
//!
//! Subcommands:
//!   figure <fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|ablation|fusion|compress|elastic|all>
//!          [--out results] [--quick] [--force] [--addr HOST:PORT]
//!        Regenerate the paper's figures (simulator sweeps, real training
//!        convergence runs, distribution plots) plus the fusion/overlap
//!        makespan study, the compression ratio × τ × group-size sweep,
//!        and the elastic-membership fault study (crash × skew × jitter;
//!        WAGMA vs Allreduce-SGD vs PairAveraging). Existing CSV outputs
//!        are never overwritten unless --force is passed. --addr routes
//!        the simulator-backed figures' cells through a running `wagma
//!        serve` daemon (bit-identical output; repeated sweeps hit its
//!        cell cache); without it cells run in-process as always.
//!   train  --model <name> --algo <name> --p N --steps N [--lr F] [--tau N]
//!          [--group-size N] [--static-groups] [--eval-every N] [--out results]
//!          [--compression none|topk|q8] [--topk-ratio F] [--trace FILE]
//!          [--telemetry FILE] [--metrics-addr HOST:PORT] [--top]
//!        Real multi-worker training through the PJRT artifacts. With
//!        compression on, WAGMA/eager workers carry an error-feedback
//!        residual and the engine sends per-bucket encoded payloads.
//!        --trace exports the merged per-rank event timeline as a Chrome
//!        trace-event JSON (open in chrome://tracing or ui.perfetto.dev)
//!        and prints the wait-time attribution. --telemetry streams
//!        sampler snapshots as JSON lines; --metrics-addr serves live
//!        Prometheus exposition (plus /snapshot.json for `wagma top
//!        --addr`); --top redraws the dashboard on stderr each window.
//!   simulate --algo <name> --p N [--steps N] [--params N] [--tau N]
//!            [--imbalance fig4|fig7|fig9|balanced] [--group-size N]
//!            [--layered] [--fusion-mode flat|threshold|mgwfbp]
//!            [--fusion-threshold-bytes N] [--compression none|topk|q8]
//!            [--topk-ratio F] [--config file.toml] [--trace FILE]
//!            [--telemetry FILE]
//!        One discrete-event simulation run at any scale. --layered turns
//!        on bucketed, overlap-scheduled exchanges; --compression prices
//!        per-bucket wire compression (δ codec term included) and reports
//!        modelled bytes-on-wire; --config loads the [fusion] and
//!        [compress] TOML sections (CLI flags override them). --trace
//!        emits the analytic timeline in the same Chrome-trace schema as
//!        the measured paths (and prints the attribution), so simulated
//!        and measured runs diff component by component. --telemetry
//!        writes one analytic telemetry snapshot (same JSON schema as the
//!        live sampler) built from the simulated timeline.
//!   bench  [--preset fig4|fig7|fig10|all] [--quick] [--out DIR] [--seed N]
//!          [--compression none|topk|q8] [--topk-ratio F] [--trace FILE]
//!          [--check-baseline FILE] [--check-compress-baseline FILE]
//!          [--check-trace-baseline FILE] [--calibrate]
//!          [--faults none|crash@mid|crash@N] [--check-faults-baseline FILE]
//!          [--telemetry FILE] [--metrics-addr HOST:PORT] [--top]
//!          [--serve-grace SECS] [--check-telemetry-baseline FILE]
//!          [--check-critpath-baseline FILE]
//!        Measured (wall-clock) overlap harness: real compute threads
//!        against streamed chunk exchanges on the collective engine (with
//!        and without per-bucket compression — default compressed arm is
//!        top-k 0.1), plus the simulator's layered-vs-flat comparison.
//!        Writes BENCH_engine.json to --out (now including per-preset
//!        trace accounting + wait histograms). --check-baseline fails
//!        (exit 1) if bytes-copied-per-iteration regresses >10% against
//!        the checked-in baseline; --check-compress-baseline does the same
//!        for compressed bytes-on-wire; --check-trace-baseline gates the
//!        recorded span/bytes-on-wire accounting (the CI perf smoke job
//!        runs all three). --trace writes one Chrome trace with a process
//!        per preset. --calibrate instead runs serial collectives across
//!        payload sizes and least-squares fits NetworkModel α/β, plus a
//!        q8-compressed rung that measures the δ codec term.
//!        --telemetry/--metrics-addr/--top attach the live-telemetry
//!        sampler to each preset's layered arm; --serve-grace keeps the
//!        metrics endpoint up after the run until one scrape lands (CI);
//!        --check-telemetry-baseline gates the deterministic snapshot
//!        counters (steps, wire bytes) within ±10% of the checked-in
//!        baseline.
//!        --faults instead runs the fault-injection smoke: each preset's
//!        layered schedule with a plan-declared fail-stop, written to
//!        BENCH_faults.json; --check-faults-baseline gates the
//!        membership-structural counters (skipped phases, degraded
//!        iters, survivor steps) against a checked-in baseline.
//!        --check-critpath-baseline gates the deterministic critical-path
//!        counters of the analytic arms (the race-free P=1 arm's on-path
//!        span count, on-path wire bytes, and compute share) and the
//!        bit-exact partition invariant of both analytic arms.
//!   trace  [--preset fig4|fig7|fig10] [--out DIR] [--seed N]
//!          [--compression none|topk|q8] [--topk-ratio F]
//!        Observability deep-dive for one preset: a quick-shaped measured
//!        run on real engine threads plus the matching traced simulation.
//!        Writes trace_measured_<preset>.json and trace_sim_<preset>.json
//!        (Chrome trace-event format), prints each run's wait-time
//!        attribution (wait-for-peer / codec / transfer / other), and the
//!        sim-vs-measured decomposition diff.
//!   critpath [--preset fig4|fig7|fig10] [--out DIR] [--seed N] [--top K]
//!            [--compression none|topk|q8] [--topk-ratio F]
//!            [--trace FILE]... | [--explain OLD.json NEW.json]
//!        Cross-rank causal critical path. Default mode runs one
//!        quick-shaped measured run and its mirrored simulation (same
//!        shapes as `wagma trace`), stitches each trace into the causal
//!        DAG, prints the top-K on-path segments plus the per-class /
//!        per-rank share table, writes a Chrome-trace overlay per run
//!        marking the on-path spans (`on_path` arg — searchable in
//!        Perfetto), and writes CRITPATH.json (a `runs` array consumable
//!        by --explain). --trace FILE (repeatable) instead loads
//!        already-recorded Chrome traces. --explain OLD.json NEW.json
//!        diffs two critpath-bearing reports (bench outputs, CRITPATH.json
//!        files, or bare critpath blocks) and names the component that
//!        moved — CI perf gates invoke this on failure so a red job
//!        states *why*.
//!   serve  --addr HOST:PORT [--workers N] [--cache N]
//!          | --smoke [--addr HOST:PORT] [--out DIR]
//!            [--check-serve-baseline FILE]
//!        The simulator as a long-running sweep service. Daemon mode
//!        binds HOST:PORT and serves: POST /v1/simulate (one canonical
//!        SimConfig JSON, one cell back), POST /v1/sweep (a preset × p ×
//!        τ × group-size × compression × faults grid sharded across
//!        --workers simulator threads, streamed incrementally as JSON
//!        lines with a closing summary record), GET /v1/cells/<hash>
//!        (replay one cached cell), GET /v1/presets, /healthz, plus the
//!        shared /metrics + /snapshot.json telemetry routes (so `wagma
//!        top --addr` and Prometheus scrape the daemon like a training
//!        run). Completed cells live in an in-memory LRU (--cache
//!        entries) keyed by the canonical config hash: repeated or
//!        overlapping sweeps only pay for new cells, and a replayed cell
//!        is bit-identical to a fresh one. --smoke instead drives the
//!        serve acceptance check: a small sweep submitted twice (second
//!        pass must be all cache hits), every streamed cell compared
//!        bit-for-bit against an inline simulate and a /v1/cells replay,
//!        the JSONL stream written to --out; --check-serve-baseline
//!        gates the structural counters via the checked-in baseline
//!        (CI's serve-smoke job). --smoke without --addr starts its own
//!        in-process daemon on an ephemeral port.
//!   top    (--addr HOST:PORT | --file FILE) [--interval-ms N] [--once]
//!        Live TTY dashboard over a running instrumented `train`/`bench`
//!        or a `wagma serve` daemon: --addr polls /snapshot.json from a
//!        --metrics-addr endpoint or the daemon; --file follows a
//!        --telemetry JSON-lines file. --once renders a single frame and
//!        exits (scriptable health checks).
//!   list
//!        Show available models, algorithms, presets.

use std::sync::Arc;

use wagma::config::preset_names;
use wagma::data::ImbalanceModel;
use wagma::figures;
use wagma::optim::engine::EngineFactory;
use wagma::optim::pjrt_engine::{PjrtEngine, RlEngine};
use wagma::config::TomlDoc;
use wagma::optim::{run_training, Algorithm, TrainConfig};
use wagma::runtime::{Manifest, ModelRuntime};
use wagma::compress::Compression;
use wagma::sched::FusionConfig;
use wagma::simulator::{simulate, SimConfig};
use wagma::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("figure") => cmd_figure(&args),
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("bench") => cmd_bench(&args),
        Some("trace") => cmd_trace(&args),
        Some("critpath") => cmd_critpath(&args),
        Some("serve") => cmd_serve(&args),
        Some("top") => cmd_top(&args),
        Some("list") => cmd_list(),
        _ => {
            eprintln!(
                "usage: wagma <figure|train|simulate|bench|trace|critpath|serve|top|list> [flags]  (see src/main.rs docs)"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all")
        .to_string();
    let out = args.str_or("out", "results");
    let quick = args.has("quick");
    let force = args.has("force");
    // --addr routes simulator cells through a running `wagma serve`
    // daemon (cache-warm sweeps are free); default is in-process.
    let client = wagma::serve::Client::from_addr(args.get("addr"));
    std::fs::create_dir_all(&out)?;
    let run = |name: &str| -> anyhow::Result<()> {
        match name {
            "fig1" | "fig2" | "fig3" => {
                figures::fig_protocol_demos();
                Ok(())
            }
            "fig4" | "fig7" | "fig10" => figures::fig_throughput(name, &out, quick, force, &client),
            "fig6" | "fig9" => figures::fig_distribution(name, &out, force),
            "fusion" => figures::fig_fusion(&out, quick, force, &client),
            "compress" => figures::fig_compression(&out, quick, force, &client),
            "elastic" => figures::fig_elastic(&out, quick, force, &client),
            "fig5" => figures::fig5(&out, quick, force),
            "fig8" => figures::fig8(&out, quick, force),
            "fig11" => figures::fig11(&out, quick, force),
            "ablation" => figures::ablation(&out, quick, force),
            other => anyhow::bail!("unknown figure {other}"),
        }
    };
    if which == "all" {
        for name in [
            "fig1", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "ablation",
            "fusion", "compress", "elastic",
        ] {
            run(name)?;
            println!();
        }
        Ok(())
    } else {
        run(&which)
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let model: &'static str = Box::leak(args.str_or("model", "mlp_tiny").into_boxed_str());
    let artifacts: &'static str =
        Box::leak(args.str_or("artifacts", "artifacts").into_boxed_str());
    let algo: Algorithm = args
        .str_or("algo", "wagma")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let p = args.usize_or("p", 4);
    let steps = args.u64_or("steps", 100);

    let rt = ModelRuntime::load(artifacts, model)?;
    let init = rt.init_params()?;
    let is_rl = rt.meta.kind == "policy";
    let samples_per_step = rt.meta.batch;
    drop(rt);

    let seed = args.u64_or("seed", 42);
    let factory: EngineFactory = Arc::new(move |rank| {
        if is_rl {
            Box::new(RlEngine::new(artifacts, model, rank, seed).expect("load RL engine"))
        } else {
            Box::new(PjrtEngine::new(artifacts, model, rank, seed).expect("load engine"))
        }
    });
    // Live telemetry: the registry is always attached (atomics only —
    // engine accounting is bit-identical with it on); the sampler thread
    // and HTTP endpoint only spin up when a sink asks for them.
    use wagma::telemetry::{
        drop_warning, shared_snapshot, JsonLinesSink, MetricsServer, Sampler, SamplerConfig, Sink,
        TelemetryRegistry, TopSink,
    };
    let registry = Arc::new(TelemetryRegistry::new(p));
    let mut sinks: Vec<Box<dyn Sink>> = Vec::new();
    if let Some(path) = args.get("telemetry") {
        sinks.push(Box::new(JsonLinesSink::create(path)?));
    }
    if args.has("top") {
        sinks.push(Box::new(TopSink::default()));
    }
    let latest = shared_snapshot();
    let server = match args.get("metrics-addr") {
        Some(addr) => {
            let srv = MetricsServer::serve(addr, Arc::clone(&latest))?;
            println!("serving telemetry on http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    let want_sampler = !sinks.is_empty() || server.is_some();
    let sampler = if want_sampler {
        Some(Sampler::spawn(
            Arc::clone(&registry),
            SamplerConfig::default(),
            sinks,
            Arc::clone(&latest),
        ))
    } else {
        None
    };

    let cfg = TrainConfig {
        algo,
        p,
        steps,
        lr: args.f64_or("lr", 0.05) as f32,
        tau: args.u64_or("tau", 10),
        group_size: args.usize_or("group-size", 0),
        dynamic_groups: !args.has("static-groups"),
        local_sgd_h: args.u64_or("local-h", 1),
        sgp_neighbors: args.usize_or("sgp-neighbors", 2),
        seed,
        eval_every: args.u64_or("eval-every", (steps / 10).max(1)),
        fusion: FusionConfig::from_args(args),
        compress: Compression::from_args(args),
        init,
        telemetry: Some(Arc::clone(&registry)),
    };
    println!(
        "training {model} with {} on P={p} (S={}, tau={}, compression={}) for {steps} steps ...",
        algo.name(),
        cfg.resolved_group_size(),
        cfg.tau,
        cfg.compress.name(),
    );
    let r = run_training(&cfg, factory);
    println!(
        "done in {:.1}s — throughput {:.0} samples/s, mean staleness {:.2}, divergence {:.2e}",
        r.wall_seconds,
        r.throughput(samples_per_step),
        r.mean_staleness(),
        r.model_divergence()
    );
    for (t, v) in r.eval_curve() {
        println!("  step {t:>6}  metric {v:.4}");
    }
    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out)?;
        let path = std::path::Path::new(out).join(format!("train_{}_{model}.json", algo.name()));
        std::fs::write(&path, r.to_json().to_string())?;
        println!("wrote {path:?}");
    }
    if let Some(path) = args.get("trace") {
        use wagma::simulator::NetworkModel;
        use wagma::trace::{attribute, to_chrome};
        let events = r.trace_events();
        std::fs::write(path, to_chrome(&events, &format!("train {model} {}", algo.name())).to_string())?;
        println!("wrote Chrome trace {path:?} ({} events)", events.len());
        print!("{}", attribute(&events, &NetworkModel::aries()).report(&format!("train {}", algo.name())));
    }
    let mut sampler_overruns = 0u64;
    if let Some(sampler) = sampler {
        let rep = sampler.stop();
        sampler_overruns = rep.overruns;
        if let Some(path) = args.get("telemetry") {
            println!("wrote telemetry {path:?} ({} windows)", rep.windows);
        }
    }
    drop(server);
    if let Some(w) = drop_warning(registry.dropped_trace_events(), sampler_overruns) {
        eprintln!("{w}");
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let algo: Algorithm = args
        .str_or("algo", "wagma")
        .parse()
        .map_err(|e: String| anyhow::anyhow!(e))?;
    let imbalance = match args.str_or("imbalance", "fig4").as_str() {
        "fig4" => ImbalanceModel::fig4(),
        "fig7" => ImbalanceModel::fig7(),
        "fig9" => ImbalanceModel::fig9(),
        "balanced" => ImbalanceModel::Balanced { base: 0.4, jitter: 0.01 },
        other => anyhow::bail!("unknown imbalance model {other}"),
    };
    // Fusion/compression knobs: optional TOML `[fusion]`/`[compress]`
    // sections as the base, CLI flags (--layered, --fusion-mode,
    // --fusion-threshold-bytes, --compression, --topk-ratio) override.
    let (fusion_base, compress_base) = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            let doc = TomlDoc::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            (
                FusionConfig::from_toml(&doc).map_err(|e| anyhow::anyhow!("{path}: {e}"))?,
                Compression::from_toml(&doc).map_err(|e| anyhow::anyhow!("{path}: {e}"))?,
            )
        }
        None => (FusionConfig::default(), Compression::None),
    };
    let fusion = FusionConfig::from_args_with(args, fusion_base);
    let compress = Compression::from_args_with(args, compress_base);
    let cfg = SimConfig {
        algo,
        p: args.usize_or("p", 64),
        steps: args.usize_or("steps", 200),
        model_bytes: args.usize_or("params", 25_559_081) * 4,
        tau: args.u64_or("tau", 10),
        group_size: args.usize_or("group-size", 0),
        dynamic_groups: !args.has("static-groups"),
        local_sgd_h: args.u64_or("local-h", 1),
        sgp_neighbors: args.usize_or("sgp-neighbors", 2),
        imbalance,
        seed: args.u64_or("seed", 42),
        fusion,
        compress,
        // The analytic telemetry snapshot is built from the trace
        // timeline, so --telemetry forces tracing on.
        trace: args.get("trace").is_some() || args.get("telemetry").is_some(),
        ..Default::default()
    };
    let b = args.usize_or("batch", 128);
    let r = simulate(&cfg);
    let su = r.iter_time_summary();
    println!("algorithm      : {}", r.algo);
    if cfg.layered_active() {
        println!(
            "fusion         : layered, mode {}, threshold {} B",
            cfg.fusion.mode.name(),
            cfg.fusion.threshold_bytes
        );
    } else if cfg.fusion.layered {
        println!(
            "fusion         : --layered ignored ({}'s exchanges are not bucket-scheduled collectives)",
            r.algo
        );
    }
    if !cfg.compress.is_none() {
        let codec = match cfg.compress {
            Compression::TopK { ratio } => format!("topk (ratio {ratio})"),
            other => other.name().to_string(),
        };
        println!("compression    : {codec}, wire {:.0} B/iter per rank", r.wire_bytes_per_iter);
    }
    println!("ranks          : {}", r.p);
    println!("makespan       : {:.2} s  (ideal {:.2} s)", r.makespan, r.ideal_makespan);
    println!(
        "throughput     : {:.0} samples/s  (ideal {:.0}, efficiency {:.1}%)",
        r.throughput(b),
        r.ideal_throughput(b),
        100.0 * r.throughput(b) / r.ideal_throughput(b)
    );
    println!("iter time      : p50 {:.3} s  p95 {:.3} s  max {:.3} s", su.p50, su.p95, su.max);
    println!("mean skew      : {:.3} s", r.mean_skew);
    if let Some(path) = args.get("trace") {
        use wagma::trace::{attribute, to_chrome};
        std::fs::write(path, to_chrome(&r.trace, &format!("simulate {}", r.algo)).to_string())?;
        println!("wrote Chrome trace {path:?} ({} events)", r.trace.len());
        print!("{}", attribute(&r.trace, &cfg.net).report(&format!("simulated {}", r.algo)));
    }
    if let Some(path) = args.get("telemetry") {
        use wagma::telemetry::{snapshot_from_events, snapshot_json};
        let snap = snapshot_from_events(cfg.p, &r.trace);
        let mut line = snapshot_json(&snap).to_string();
        line.push('\n');
        std::fs::write(path, line)?;
        println!(
            "wrote analytic telemetry snapshot {path:?} ({} ranks, {} total steps, {} wire B)",
            snap.p,
            snap.total_steps(),
            snap.total_wire_bytes()
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    use wagma::bench::calibrate::{calibrate, calibration_json};
    use wagma::bench::measured_overlap::bench_preset_traced;
    use wagma::util::json::{num, obj, s, Json};

    let quick = args.has("quick");
    let out_dir = args.str_or("out", ".");
    let seed = args.u64_or("seed", 42);

    if args.has("calibrate") {
        // Satellite of the compression PR / follow-up of PR 2: fit α/β
        // from serial engine collectives across a payload ladder, and δ
        // from a q8-compressed rung of the same ladder.
        println!(
            "Calibrating NetworkModel α/β/δ ({} ladder)...",
            if quick { "quick" } else { "full" }
        );
        let cal = calibrate(quick, seed);
        for sm in &cal.samples {
            println!("  dense {:>12.0} B  wait mean {:>10.3} µs", sm.bytes, sm.seconds * 1e6);
        }
        for sm in &cal.compressed {
            println!(
                "  q8    {:>12.0} B  ({:>10.0} B wire)  wait mean {:>10.3} µs",
                sm.raw_bytes,
                sm.wire_bytes,
                sm.seconds * 1e6
            );
        }
        let model = &cal.model;
        println!(
            "suggested NetworkModel {{ alpha: {:.3e}, beta: {:.3e}, gamma: {:.3e}, contention: {}, delta: {:.3e} }}",
            model.alpha, model.beta, model.gamma, model.contention, model.delta
        );
        println!(
            "(α = {:.2} µs, β = 1/{:.1} GB/s, δ = {:.3e} s/B measured from the q8 rung; γ/contention keep the Aries defaults)",
            model.alpha * 1e6,
            1.0 / model.beta / 1e9,
            model.delta
        );
        std::fs::create_dir_all(&out_dir)?;
        let path = std::path::Path::new(&out_dir).join("CALIBRATION.json");
        std::fs::write(&path, calibration_json(&cal).to_string())?;
        println!("wrote {path:?}");
        return Ok(());
    }

    if let Some(spec) = args.get("faults") {
        // Robustness smoke: the measured layered schedule per preset with
        // a plan-declared fail-stop, gated on membership-structural
        // counters (skipped phases / degraded iters / survivor steps).
        use wagma::bench::measured_overlap::bench_fault_preset;
        let which = args.str_or("preset", "all");
        let names: Vec<String> = if which == "all" {
            vec!["fig4".into(), "fig7".into(), "fig10".into()]
        } else {
            vec![which]
        };
        for n in &names {
            if !preset_names().contains(&n.as_str()) {
                anyhow::bail!("unknown bench preset {n:?} (fig4|fig7|fig10|all)");
            }
        }
        println!("Fault-injection bench ({}, faults {spec}):", if quick { "quick" } else { "full" });
        let mut cases: Vec<Json> = Vec::with_capacity(names.len());
        for n in &names {
            cases.push(bench_fault_preset(n, quick, seed, spec)?);
        }
        let report = obj(vec![
            ("generated_by", s("wagma bench --faults")),
            ("source", s("wall-clock")),
            ("quick", Json::Bool(quick)),
            ("seed", num(seed as f64)),
            ("spec", s(spec)),
            ("presets", Json::Arr(cases)),
        ]);
        std::fs::create_dir_all(&out_dir)?;
        let path = std::path::Path::new(&out_dir).join("BENCH_faults.json");
        std::fs::write(&path, report.to_string())?;
        println!("wrote {path:?}");
        if let Some(baseline_path) = args.get("check-faults-baseline") {
            check_faults_baseline(&report, baseline_path)?;
        }
        return Ok(());
    }

    // Compressed arm: top-k 0.1 unless overridden (`--compression none`
    // drops the arm entirely).
    let comp = Compression::from_args_with(args, Compression::TopK { ratio: 0.1 });
    let which = args.str_or("preset", "all");
    let names: Vec<String> = if which == "all" {
        vec!["fig4".into(), "fig7".into(), "fig10".into()]
    } else {
        vec![which]
    };
    for n in &names {
        if !preset_names().contains(&n.as_str()) {
            anyhow::bail!("unknown bench preset {n:?} (fig4|fig7|fig10|all)");
        }
    }

    // Live telemetry over the bench: one JSON-lines file and one metrics
    // endpoint span the whole run; each preset gets its own registry +
    // sampler (world size can differ per preset), attached to the
    // preset's layered arm.
    use wagma::bench::measured_overlap::{bench_preset_instrumented, preset_case};
    use wagma::telemetry::{
        drop_warning, shared_snapshot, JsonLinesSink, MetricsServer, Sampler, SamplerConfig, Sink,
        TelemetryRegistry, TopSink,
    };
    let jsonl = match args.get("telemetry") {
        Some(path) => Some(JsonLinesSink::create(path)?),
        None => None,
    };
    let latest = shared_snapshot();
    let server = match args.get("metrics-addr") {
        Some(addr) => {
            let srv = MetricsServer::serve(addr, Arc::clone(&latest))?;
            println!("serving telemetry on http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    let telemetry_on = jsonl.is_some() || server.is_some() || args.has("top");
    let mut sampler_overruns = 0u64;

    println!("Measured-overlap bench ({}):", if quick { "quick" } else { "full" });
    let mut cases: Vec<Json> = Vec::with_capacity(names.len());
    let mut traces: Vec<(String, Vec<wagma::trace::TraceEvent>)> = Vec::with_capacity(names.len());
    for n in &names {
        let (json, trace) = if telemetry_on {
            let registry = Arc::new(TelemetryRegistry::new(preset_case(n, quick).p));
            let mut sinks: Vec<Box<dyn Sink>> = Vec::new();
            if let Some(sink) = &jsonl {
                sinks.push(Box::new(sink.clone()));
            }
            if args.has("top") {
                sinks.push(Box::new(TopSink::default()));
            }
            let sampler = Sampler::spawn(
                Arc::clone(&registry),
                SamplerConfig::default(),
                sinks,
                Arc::clone(&latest),
            );
            let out = bench_preset_instrumented(n, quick, seed, comp, Some(Arc::clone(&registry)));
            let rep = sampler.stop();
            sampler_overruns += rep.overruns;
            out
        } else {
            bench_preset_traced(n, quick, seed, comp)
        };
        cases.push(json);
        traces.push((n.clone(), trace));
    }
    let report = obj(vec![
        ("generated_by", s("wagma bench")),
        ("source", s("wall-clock")),
        ("quick", Json::Bool(quick)),
        ("seed", num(seed as f64)),
        ("compression", s(comp.name())),
        // Only meaningful for top-k; Null lets the ratio shape check in
        // the compress gate skip for other codecs.
        (
            "topk_ratio",
            match comp {
                Compression::TopK { ratio } => num(ratio),
                _ => Json::Null,
            },
        ),
        ("presets", Json::Arr(cases)),
    ]);
    std::fs::create_dir_all(&out_dir)?;
    let path = std::path::Path::new(&out_dir).join("BENCH_engine.json");
    std::fs::write(&path, report.to_string())?;
    println!("wrote {path:?}");

    if let Some(path) = args.get("trace") {
        use wagma::simulator::NetworkModel;
        use wagma::trace::{attribute, to_chrome_multi};
        let procs: Vec<(&str, &[wagma::trace::TraceEvent])> =
            traces.iter().map(|(n, t)| (n.as_str(), t.as_slice())).collect();
        std::fs::write(path, to_chrome_multi(&procs).to_string())?;
        let total: usize = traces.iter().map(|(_, t)| t.len()).sum();
        println!("wrote Chrome trace {path:?} ({total} events, one process per preset)");
        for (n, t) in &traces {
            print!("{}", attribute(t, &NetworkModel::aries()).report(n));
        }
    }

    if let Some(baseline_path) = args.get("check-baseline") {
        check_bench_baseline(&report, baseline_path)?;
    }
    if let Some(baseline_path) = args.get("check-compress-baseline") {
        check_compress_baseline(&report, baseline_path)?;
    }
    if let Some(baseline_path) = args.get("check-trace-baseline") {
        check_trace_baseline(&report, baseline_path)?;
    }
    if let Some(baseline_path) = args.get("check-telemetry-baseline") {
        check_telemetry_baseline(&report, baseline_path)?;
    }
    if let Some(baseline_path) = args.get("check-critpath-baseline") {
        check_critpath_baseline(&report, baseline_path)?;
    }

    // Critical-path shares are a whole-run property, so live windows
    // publish none; attach the last preset's layered-run shares to the
    // final snapshot now, so scrapes landing in the --serve-grace window
    // serve `wagma_critpath_share{class,rank}` and the closing JSONL line
    // carries the `critpath` array.
    if telemetry_on {
        if let Some((_, trace)) = traces.last() {
            let shares =
                wagma::telemetry::critpath_shares(&wagma::trace::critical_path_events(trace));
            let enriched = match latest.lock() {
                Ok(mut guard) => guard.as_mut().map(|snap| {
                    snap.critpath = shares;
                    snap.clone()
                }),
                Err(_) => None,
            };
            if let (Some(sink), Some(snap)) = (&jsonl, enriched.as_ref()) {
                let _ = sink.clone().publish(snap);
            }
        }
    }

    // --serve-grace N: hold the metrics endpoint open after the
    // measurements finish until at least one request lands (or the grace
    // window runs out), so an external scraper racing a quick bench run
    // still gets its sample.
    if let Some(srv) = &server {
        let grace = args.u64_or("serve-grace", 0);
        if grace > 0 && srv.requests_served() == 0 {
            println!(
                "holding metrics endpoint http://{}/metrics for up to {grace}s (waiting for a scrape)...",
                srv.local_addr()
            );
            let t0 = std::time::Instant::now();
            while t0.elapsed().as_secs() < grace && srv.requests_served() == 0 {
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
        }
    }
    drop(server);

    // Non-silent observability-loss warning (dropped ring events come
    // from the per-preset trace accounting, so this fires with or
    // without the telemetry sinks attached).
    let dropped_events: u64 = report
        .get("presets")
        .and_then(|p| p.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter_map(|c| c.get("trace").and_then(|t| t.get("dropped_events")).and_then(|v| v.as_f64()))
        .sum::<f64>() as u64;
    if let Some(w) = drop_warning(dropped_events, sampler_overruns) {
        eprintln!("{w}");
    }
    Ok(())
}

/// The one regeneration recipe every `BENCH_engine.json`-sourced gate
/// shares (bytes-copied / compress / trace / telemetry / critpath): run
/// the quick bench and copy the named per-preset block into the baseline.
const REGEN_BENCH: &str = "cargo run --release -p wagma -- bench --quick --out /tmp/wagma-bench, \
then copy each preset's block from /tmp/wagma-bench/BENCH_engine.json into the baseline";

/// Shared scaffolding for every `--check-*-baseline` gate: load and
/// parse the baseline file, enforce the quick-shape match, collect the
/// gate-specific failures, and on ANY failure — unreadable file, shape
/// mismatch, or counter drift — print both the baseline file path and
/// the exact command that regenerates it, so a red gate is actionable
/// without digging through CI configs.
fn run_baseline_gate(
    label: &str,
    regen: &str,
    report: &wagma::util::json::Json,
    baseline_path: &str,
    check: impl FnOnce(&wagma::util::json::Json, &mut Vec<String>) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    use wagma::util::json::Json;
    let hint = format!("baseline file: {baseline_path}\n  regenerate:    {regen}");
    let fail = |msg: String| anyhow::anyhow!("{msg}\n  {hint}");
    let text = std::fs::read_to_string(baseline_path)
        .map_err(|e| fail(format!("{label} gate: cannot read {baseline_path}: {e}")))?;
    let baseline =
        Json::parse(&text).map_err(|e| fail(format!("{label} gate: {baseline_path}: {e}")))?;
    // Gated counters usually scale with the bench shape (P, steps), so
    // refuse to compare a full run against a quick baseline (and vice
    // versa). Baselines whose counters are shape-independent (critpath:
    // analytic arms with pinned P and step cap) omit `shape.quick`.
    let base_quick = baseline.get("shape").and_then(|s| s.get("quick")).and_then(|v| v.as_bool());
    let run_quick = report.get("quick").and_then(|v| v.as_bool()).unwrap_or(false);
    if let Some(bq) = base_quick {
        if bq != run_quick {
            return Err(fail(format!(
                "{label} baseline shape mismatch: {baseline_path} records a {} run but this is a {} run",
                if bq { "--quick" } else { "full" },
                if run_quick { "--quick" } else { "full" },
            )));
        }
    }
    let mut failures = Vec::new();
    check(&baseline, &mut failures).map_err(|e| fail(e.to_string()))?;
    if failures.is_empty() {
        Ok(())
    } else {
        Err(fail(format!("{label} regression:\n{}", failures.join("\n"))))
    }
}

/// Gate the deterministic telemetry counters of each preset's layered arm
/// (`steps`, `wire_bytes`) against a checked-in baseline, symmetric ±10%.
/// Both counters are code-structural — steps is the schedule shape, wire
/// bytes the schedule × wire format — so drift in *either* direction
/// means the measured schedule changed, not noise.
fn check_telemetry_baseline(
    report: &wagma::util::json::Json,
    baseline_path: &str,
) -> anyhow::Result<()> {
    run_baseline_gate("telemetry counter", REGEN_BENCH, report, baseline_path, |baseline, failures| {
        const FIELDS: [&str; 2] = ["steps", "wire_bytes"];
        let cases = report.get("presets").and_then(|p| p.as_arr()).unwrap_or(&[]);
        for case in cases {
            let name = case.get("preset").and_then(|v| v.as_str()).unwrap_or("?");
            let Some(base) = baseline.get(name) else {
                // A missing entry must not silently disable the gate.
                failures.push(format!(
                    "{name}: no telemetry baseline entry in {baseline_path} — add one"
                ));
                continue;
            };
            let mut ok = true;
            for field in FIELDS {
                let measured = case
                    .get("telemetry")
                    .and_then(|t| t.get(field))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::INFINITY);
                let Some(b) = base.get(field).and_then(|v| v.as_f64()) else {
                    failures.push(format!(
                        "{name}.{field}: missing from {baseline_path} (measured {measured:.0})"
                    ));
                    ok = false;
                    continue;
                };
                if (measured - b).abs() > b * 0.10 {
                    failures.push(format!(
                        "{name}.{field}: {measured:.0} deviates >10% from baseline {b:.0}"
                    ));
                    ok = false;
                }
            }
            if ok {
                println!("telemetry baseline OK for {name} (steps + wire bytes within ±10%)");
            }
        }
        Ok(())
    })
}

/// Gate the deterministic critical-path counters of the analytic arms in
/// each preset's `critpath` block. The race-free P=1 arm's on-path span
/// count, on-path wire bytes, and compute share are schedule-deterministic
/// (the acceptance pin: 24 back-to-back compute spans, zero wire bytes on
/// path, compute share 1); both analytic arms must also satisfy the
/// bit-exact partition invariant. The measured layered arm is wall-clock
/// and is *not* gated — `wagma critpath --explain` diffs it instead.
fn check_critpath_baseline(
    report: &wagma::util::json::Json,
    baseline_path: &str,
) -> anyhow::Result<()> {
    let regen = format!(
        "{REGEN_BENCH} (the critpath.p1 arm's onpath_spans / onpath_wire_bytes / \
         class_share.compute×1e6 as *_ppm)"
    );
    run_baseline_gate("critpath counter", &regen, report, baseline_path, |baseline, failures| {
        let cases = report.get("presets").and_then(|p| p.as_arr()).unwrap_or(&[]);
        for case in cases {
            let name = case.get("preset").and_then(|v| v.as_str()).unwrap_or("?");
            let Some(crit) = case.get("critpath") else {
                failures.push(format!(
                    "{name}: no critpath block in the bench report (regenerate with a \
                     critpath-aware build)"
                ));
                continue;
            };
            // Invariant, baseline-independent: both analytic arms must
            // partition their makespan bit-exactly.
            for arm in ["sim", "p1"] {
                let exact = crit
                    .get(arm)
                    .and_then(|a| a.get("partition_exact"))
                    .and_then(|v| v.as_bool());
                if exact != Some(true) {
                    failures.push(format!(
                        "{name}.critpath.{arm}: partition_exact is not true — class shares no \
                         longer tile the makespan"
                    ));
                }
            }
            let Some(base) = baseline.get(name) else {
                // A missing entry must not silently disable the gate.
                failures.push(format!(
                    "{name}: no critpath baseline entry in {baseline_path} — add one"
                ));
                continue;
            };
            let p1 = crit.get("p1");
            let measured = |key: &str| -> f64 {
                match key {
                    "p1_compute_share_ppm" => p1
                        .and_then(|a| a.get("class_share"))
                        .and_then(|cs| cs.get("compute"))
                        .and_then(|v| v.as_f64())
                        .map_or(f64::INFINITY, |share| (share * 1e6).round()),
                    "p1_onpath_spans" => p1
                        .and_then(|a| a.get("onpath_spans"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(f64::INFINITY),
                    _ => p1
                        .and_then(|a| a.get("onpath_wire_bytes"))
                        .and_then(|v| v.as_f64())
                        .unwrap_or(f64::INFINITY),
                }
            };
            let mut ok = true;
            for field in ["p1_onpath_spans", "p1_onpath_wire_bytes", "p1_compute_share_ppm"] {
                let m = measured(field);
                let Some(b) = base.get(field).and_then(|v| v.as_f64()) else {
                    failures.push(format!(
                        "{name}.{field}: missing from {baseline_path} (measured {m:.0})"
                    ));
                    ok = false;
                    continue;
                };
                // ±10%, except a zero baseline (wire bytes on the P=1
                // path) demands exact zero.
                if (m - b).abs() > b * 0.10 {
                    failures.push(format!(
                        "{name}.{field}: {m:.0} deviates >10% from baseline {b:.0}"
                    ));
                    ok = false;
                }
            }
            if ok {
                println!(
                    "critpath baseline OK for {name} (P=1 arm deterministic counters within \
                     ±10%, partitions bit-exact)"
                );
            }
        }
        Ok(())
    })
}

/// `wagma trace` — observability deep-dive for one preset: one traced
/// measured run (quick shape, real engine threads) and the matching
/// traced simulation, exported in the same Chrome-trace schema, plus the
/// wait-time attribution of each and their component-by-component diff.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use wagma::bench::measured_overlap::{
        compute_matrix, preset_case, run_measured, MeasuredConfig,
    };
    use wagma::config::preset;
    use wagma::trace::{attribute, render_diff, to_chrome, validate_schema};

    let name = args.str_or("preset", "fig4");
    let Some(pre) = preset(&name) else {
        anyhow::bail!("unknown preset {name:?} (fig4|fig7|fig10)");
    };
    let out_dir = args.str_or("out", ".");
    let seed = args.u64_or("seed", 42);
    let comp = Compression::from_args_with(args, Compression::None);
    std::fs::create_dir_all(&out_dir)?;

    // Measured arm: the quick-shaped layered schedule on real threads
    // (same shape the bench harness uses, so numbers line up).
    let case = preset_case(&name, true);
    println!(
        "tracing measured run: {name} P{} dim {} steps {} (layered, compression {})",
        case.p,
        case.dim,
        case.steps,
        comp.name()
    );
    let measured = run_measured(&MeasuredConfig {
        p: case.p,
        group_size: case.group_size,
        tau: case.tau,
        dim: case.dim,
        steps: case.steps,
        chunk_elems: case.chunk_elems,
        compression: comp,
        compute: compute_matrix(&case, false, seed),
        faults: wagma::fault::FaultPlan::none(),
    });
    if let Some(w) = wagma::telemetry::drop_warning(measured.dropped_trace_events, 0) {
        eprintln!("{w}");
    }

    // Simulated arm: the same shape on the analytic timeline. One schema,
    // two producers — that is what makes the diff below meaningful.
    let mut fusion = pre.fusion;
    fusion.layered = true;
    let sim_cfg = SimConfig {
        algo: Algorithm::Wagma,
        p: case.p,
        steps: case.steps as usize,
        model_bytes: case.dim * 4,
        tau: case.tau,
        group_size: case.group_size,
        dynamic_groups: true,
        imbalance: pre.imbalance,
        seed,
        fusion,
        compress: comp,
        trace: true,
        ..Default::default()
    };
    let sim = simulate(&sim_cfg);

    let m_att = attribute(&measured.trace, &sim_cfg.net);
    let s_att = attribute(&sim.trace, &sim_cfg.net);
    print!("{}", m_att.report(&format!("measured {name}")));
    print!("{}", s_att.report(&format!("simulated {name}")));
    print!("{}", render_diff(&m_att, &s_att));

    for (tag, events) in [("measured", &measured.trace), ("sim", &sim.trace)] {
        let doc = to_chrome(events, &format!("{tag} {name}"));
        validate_schema(&doc).map_err(|e| anyhow::anyhow!("{tag} trace schema: {e}"))?;
        let path = std::path::Path::new(&out_dir).join(format!("trace_{tag}_{name}.json"));
        std::fs::write(&path, doc.to_string())?;
        println!("wrote {path:?} ({} events)", events.len());
    }
    Ok(())
}

/// `wagma critpath` — cross-rank causal critical path. Default mode runs
/// one quick-shaped measured run plus its mirrored simulation (the same
/// two shapes `wagma trace` produces), stitches each trace into the
/// causal DAG, prints the top-K on-path segments and the per-class /
/// per-rank share table, writes a Chrome-trace overlay per run marking
/// the on-path spans, and writes CRITPATH.json (a `runs` array
/// consumable by `--explain`). `--trace FILE` (repeatable) loads
/// already-recorded Chrome traces instead; `--explain OLD.json NEW.json`
/// diffs two critpath-bearing reports and names the moved component.
fn cmd_critpath(args: &Args) -> anyhow::Result<()> {
    use wagma::trace::{
        critical_path, explain, from_chrome, to_chrome_overlay, validate_schema, CausalGraph,
    };
    use wagma::util::json::{num, obj, s, Json};

    // Explainer mode: `wagma critpath --explain OLD.json NEW.json` (the
    // second file lands in the positionals — see util::cli).
    if let Some(old_path) = args.get("explain") {
        let Some(new_path) = args.positional.get(1) else {
            anyhow::bail!("usage: wagma critpath --explain OLD.json NEW.json");
        };
        let load = |path: &str| -> anyhow::Result<Json> {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
            Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
        };
        let old = load(old_path)?;
        let new = load(new_path)?;
        let verdict = explain(&old, &new).map_err(|e| anyhow::anyhow!(e))?;
        print!("{verdict}");
        return Ok(());
    }

    let out_dir = args.str_or("out", ".");
    let k = args.usize_or("top", 10);
    std::fs::create_dir_all(&out_dir)?;
    let mut runs: Vec<Json> = Vec::new();

    // Offline mode: attribute already-recorded Chrome trace file(s).
    let trace_files = args.get_all("trace");
    if !trace_files.is_empty() {
        for path in trace_files {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
            let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let events = from_chrome(&doc).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let g = CausalGraph::build(&events);
            let cp = critical_path(&g);
            print!("{}", cp.render(path, k));
            let marks = cp.onpath_marks(&g, &events);
            let overlay = to_chrome_overlay(&events, &marks, &format!("critpath {path}"));
            let stem = std::path::Path::new(path)
                .file_stem()
                .and_then(|x| x.to_str())
                .unwrap_or("trace");
            let opath =
                std::path::Path::new(&out_dir).join(format!("critpath_overlay_{stem}.json"));
            std::fs::write(&opath, overlay.to_string())?;
            println!("wrote on-path overlay {opath:?}");
            // Label by file stem so two CRITPATH.json from the same trace
            // names pair up under --explain.
            runs.push(obj(vec![("label", s(stem)), ("critpath", cp.to_json())]));
        }
        let report =
            obj(vec![("generated_by", s("wagma critpath")), ("runs", Json::Arr(runs))]);
        let rpath = std::path::Path::new(&out_dir).join("CRITPATH.json");
        std::fs::write(&rpath, report.to_string())?;
        println!("wrote {rpath:?}");
        return Ok(());
    }

    // Default: one measured quick-shape run + its mirrored simulation,
    // the same shapes as `wagma trace`, so the two decompositions (and
    // two builds' CRITPATH.json files under --explain) line up.
    use wagma::bench::measured_overlap::{
        compute_matrix, preset_case, run_measured, MeasuredConfig,
    };
    use wagma::config::preset;

    let name = args.str_or("preset", "fig4");
    let Some(pre) = preset(&name) else {
        anyhow::bail!("unknown preset {name:?} (fig4|fig7|fig10)");
    };
    let seed = args.u64_or("seed", 42);
    let comp = Compression::from_args_with(args, Compression::None);
    let case = preset_case(&name, true);
    println!(
        "critical path for {name}: measured P{} dim {} steps {} (layered, compression {}) + mirrored simulation",
        case.p,
        case.dim,
        case.steps,
        comp.name()
    );
    let measured = run_measured(&MeasuredConfig {
        p: case.p,
        group_size: case.group_size,
        tau: case.tau,
        dim: case.dim,
        steps: case.steps,
        chunk_elems: case.chunk_elems,
        compression: comp,
        compute: compute_matrix(&case, false, seed),
        faults: wagma::fault::FaultPlan::none(),
    });
    if let Some(w) = wagma::telemetry::drop_warning(measured.dropped_trace_events, 0) {
        eprintln!("{w}");
    }
    let mut fusion = pre.fusion;
    fusion.layered = true;
    let sim_cfg = SimConfig {
        algo: Algorithm::Wagma,
        p: case.p,
        steps: case.steps as usize,
        model_bytes: case.dim * 4,
        tau: case.tau,
        group_size: case.group_size,
        dynamic_groups: true,
        imbalance: pre.imbalance,
        seed,
        fusion,
        compress: comp,
        trace: true,
        ..Default::default()
    };
    let sim = simulate(&sim_cfg);

    for (label, events) in [("measured", &measured.trace), ("sim", &sim.trace)] {
        let g = CausalGraph::build(events);
        let cp = critical_path(&g);
        print!("{}", cp.render(&format!("{label} {name}"), k));
        let marks = cp.onpath_marks(&g, events);
        let doc = to_chrome_overlay(events, &marks, &format!("{label} {name}"));
        validate_schema(&doc).map_err(|e| anyhow::anyhow!("{label} overlay schema: {e}"))?;
        let path =
            std::path::Path::new(&out_dir).join(format!("critpath_overlay_{label}_{name}.json"));
        std::fs::write(&path, doc.to_string())?;
        println!(
            "wrote on-path overlay {path:?} ({} events, {} on path)",
            events.len(),
            marks.iter().filter(|&&m| m).count()
        );
        runs.push(obj(vec![("label", s(label)), ("critpath", cp.to_json())]));
    }
    let report = obj(vec![
        ("generated_by", s("wagma critpath")),
        ("preset", s(&name)),
        ("seed", num(seed as f64)),
        ("runs", Json::Arr(runs)),
    ]);
    let rpath = std::path::Path::new(&out_dir).join("CRITPATH.json");
    std::fs::write(&rpath, report.to_string())?;
    println!("wrote {rpath:?} (feed two of these to `wagma critpath --explain OLD NEW`)");
    Ok(())
}

/// Trace-accounting gate: fail if any preset's recorded span counts or
/// bytes-on-wire drift >10% above the checked-in baseline. The gated
/// fields are code-structural (schedule shape × wire format) — the same
/// determinism argument as `sent_bytes` — so in practice they reproduce
/// exactly; the 10% headroom mirrors the other gates.
fn check_trace_baseline(report: &wagma::util::json::Json, baseline_path: &str) -> anyhow::Result<()> {
    run_baseline_gate("trace accounting", REGEN_BENCH, report, baseline_path, |baseline, failures| {
        const FIELDS: [&str; 4] =
            ["phase_spans", "tau_sync_spans", "phase_wire_bytes", "sync_wire_bytes"];
        let cases = report.get("presets").and_then(|p| p.as_arr()).unwrap_or(&[]);
        for case in cases {
            let name = case.get("preset").and_then(|v| v.as_str()).unwrap_or("?");
            let Some(base) = baseline.get(name) else {
                // A missing entry must not silently disable the gate.
                failures
                    .push(format!("{name}: no trace baseline entry in {baseline_path} — add one"));
                continue;
            };
            let mut ok = true;
            for field in FIELDS {
                let measured = case
                    .get("trace")
                    .and_then(|t| t.get(field))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::INFINITY);
                let Some(b) = base.get(field).and_then(|v| v.as_f64()) else {
                    failures.push(format!(
                        "{name}.{field}: missing from {baseline_path} (measured {measured:.0})"
                    ));
                    ok = false;
                    continue;
                };
                let limit = b * 1.10;
                if measured > limit {
                    failures.push(format!(
                        "{name}.{field}: {measured:.0} exceeds baseline {b:.0} (+10% limit {limit:.0})"
                    ));
                    ok = false;
                }
            }
            if ok {
                println!("trace baseline OK for {name} (spans + wire bytes within limits)");
            }
        }
        Ok(())
    })
}

/// Perf-regression gate for the compression subsystem: fail if any
/// preset's compressed bytes-on-wire per iteration exceeds the checked-in
/// baseline by >10%. (`sent_bytes` counts data chunks whose number and
/// encoded size are code-structural, so the gate is deterministic.)
fn check_compress_baseline(
    report: &wagma::util::json::Json,
    baseline_path: &str,
) -> anyhow::Result<()> {
    run_baseline_gate(
        "compressed bytes-on-wire",
        REGEN_BENCH,
        report,
        baseline_path,
        |baseline, failures| {
            if let (Some(bk), Some(rk)) = (
                baseline.get("shape").and_then(|s| s.get("compression")).and_then(|v| v.as_str()),
                report.get("compression").and_then(|v| v.as_str()),
            ) {
                if bk != rk {
                    anyhow::bail!(
                        "compress baseline codec mismatch: baseline {bk:?} vs run {rk:?} — rerun with matching --compression"
                    );
                }
            }
            if let (Some(br), Some(rr)) = (
                baseline.get("shape").and_then(|s| s.get("topk_ratio")).and_then(|v| v.as_f64()),
                report.get("topk_ratio").and_then(|v| v.as_f64()),
            ) {
                // A different keep ratio changes the expected wire volume
                // itself: comparing across ratios would mask regressions
                // (smaller ratio) or report spurious ones (larger), so
                // refuse like the other shape mismatches.
                if (br - rr).abs() > 1e-9 {
                    anyhow::bail!(
                        "compress baseline ratio mismatch: baseline topk_ratio {br} vs run {rr} — rerun with matching --topk-ratio"
                    );
                }
            }
            let cases = report.get("presets").and_then(|p| p.as_arr()).unwrap_or(&[]);
            for case in cases {
                let name = case.get("preset").and_then(|v| v.as_str()).unwrap_or("?");
                let measured = case
                    .get("measured_compressed")
                    .and_then(|m| m.get("sent_bytes_per_iter"))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::INFINITY);
                let Some(base) = baseline
                    .get(name)
                    .and_then(|b| b.get("sent_bytes_per_iter"))
                    .and_then(|v| v.as_f64())
                else {
                    failures.push(format!(
                        "{name}: no compress baseline entry in {baseline_path} — add one (measured {measured:.0} B/iter)"
                    ));
                    continue;
                };
                let limit = base * 1.10;
                if measured > limit {
                    failures.push(format!(
                        "{name}: compressed wire {measured:.0} B/iter exceeds baseline {base:.0} (+10% limit {limit:.0})"
                    ));
                } else {
                    println!(
                        "compress baseline OK for {name}: {measured:.0} B/iter (baseline {base:.0})"
                    );
                    if measured < base * 0.9 {
                        println!("  (improved >10% — consider refreshing the baseline)");
                    }
                }
            }
            Ok(())
        },
    )
}

/// Perf-regression gate: fail if any preset's measured
/// bytes-copied-per-iteration exceeds the checked-in baseline by >10%.
/// (The copy counter is deterministic — code-structural, not timing — so
/// this check is stable in CI.)
fn check_bench_baseline(report: &wagma::util::json::Json, baseline_path: &str) -> anyhow::Result<()> {
    run_baseline_gate("bytes-copied", REGEN_BENCH, report, baseline_path, |baseline, failures| {
        let cases = report.get("presets").and_then(|p| p.as_arr()).unwrap_or(&[]);
        for case in cases {
            let name = case.get("preset").and_then(|v| v.as_str()).unwrap_or("?");
            let measured = case
                .get("measured_layered")
                .and_then(|m| m.get("copied_bytes_per_iter"))
                .and_then(|v| v.as_f64())
                .unwrap_or(f64::INFINITY);
            let Some(base) = baseline
                .get(name)
                .and_then(|b| b.get("copied_bytes_per_iter"))
                .and_then(|v| v.as_f64())
            else {
                // A missing entry must not silently disable the gate.
                failures.push(format!(
                    "{name}: no baseline entry in {baseline_path} — add one (measured {measured:.0} B/iter)"
                ));
                continue;
            };
            let limit = base * 1.10;
            if measured > limit {
                failures.push(format!(
                    "{name}: copied {measured:.0} B/iter exceeds baseline {base:.0} (+10% limit {limit:.0})"
                ));
            } else {
                println!("baseline OK for {name}: {measured:.0} B/iter (baseline {base:.0})");
                if measured < base * 0.9 {
                    println!("  (improved >10% — consider refreshing the baseline)");
                }
            }
        }
        Ok(())
    })
}

/// Gate `wagma bench --faults` against a checked-in baseline. The gated
/// counters are membership-structural for plan-declared crashes (see
/// `bench_fault_preset`): `survivor_steps` is exact; `skipped_phases` and
/// `degraded_iters` have a hard lower bound (the plan's deterministic
/// skips must all happen) plus 1.5x slack upward, since scheduling noise
/// on a loaded CI box can only *add* suspect-skips, never remove
/// plan-mandated ones.
fn check_faults_baseline(report: &wagma::util::json::Json, baseline_path: &str) -> anyhow::Result<()> {
    const REGEN_FAULTS: &str =
        "cargo run --release -p wagma -- bench --quick --faults crash@mid --out /tmp/wagma-faults, \
         then copy each preset's counters from /tmp/wagma-faults/BENCH_faults.json into the baseline";
    run_baseline_gate("fault-smoke", REGEN_FAULTS, report, baseline_path, |baseline, failures| {
        let base_spec =
            baseline.get("shape").and_then(|s| s.get("spec")).and_then(|v| v.as_str());
        let run_spec = report.get("spec").and_then(|v| v.as_str()).unwrap_or("");
        if let Some(bs) = base_spec {
            if bs != run_spec {
                anyhow::bail!(
                    "baseline fault-spec mismatch: {baseline_path} records {bs:?} but this run used {run_spec:?}"
                );
            }
        }
        let cases = report.get("presets").and_then(|p| p.as_arr()).unwrap_or(&[]);
        for case in cases {
            let name = case.get("preset").and_then(|v| v.as_str()).unwrap_or("?");
            let counter =
                |key: &str| -> f64 { case.get(key).and_then(|v| v.as_f64()).unwrap_or(f64::NAN) };
            let Some(base) = baseline.get(name) else {
                // A missing entry must not silently disable the gate.
                failures.push(format!(
                    "{name}: no baseline entry in {baseline_path} — add one (measured skipped_phases {} degraded_iters {} survivor_steps {})",
                    counter("skipped_phases"),
                    counter("degraded_iters"),
                    counter("survivor_steps"),
                ));
                continue;
            };
            let mut case_failures = Vec::new();
            for key in ["skipped_phases", "degraded_iters"] {
                let measured = counter(key);
                let Some(b) = base.get(key).and_then(|v| v.as_f64()) else {
                    case_failures.push(format!("{name}: baseline entry lacks {key}"));
                    continue;
                };
                if measured.is_nan() || measured < b {
                    case_failures.push(format!(
                        "{name}: {key} {measured} below plan-mandated minimum {b} — degraded paths not taken"
                    ));
                } else if measured > b * 1.5 {
                    case_failures.push(format!(
                        "{name}: {key} {measured} exceeds baseline {b} by more than 1.5x — spurious suspects"
                    ));
                }
            }
            let measured = counter("survivor_steps");
            match base.get("survivor_steps").and_then(|v| v.as_f64()) {
                Some(b) if measured == b => {}
                Some(b) => case_failures.push(format!(
                    "{name}: survivor_steps {measured} != expected {b} (exact: crash iteration is plan-declared)"
                )),
                None => case_failures.push(format!("{name}: baseline entry lacks survivor_steps")),
            }
            if case_failures.is_empty() {
                println!(
                    "fault baseline OK for {name}: skipped_phases {} degraded_iters {} survivor_steps {}",
                    counter("skipped_phases"),
                    counter("degraded_iters"),
                    counter("survivor_steps"),
                );
            }
            failures.extend(case_failures);
        }
        Ok(())
    })
}

/// `wagma top` — live TTY dashboard over a running instrumented
/// `train`/`bench` (or a finished one's telemetry file). Two sources:
/// `--addr` polls `/snapshot.json` from a `--metrics-addr` endpoint;
/// `--file` follows a `--telemetry` JSON-lines file (last line wins).
/// How to regenerate `rust/benches/baseline_serve.json`: run the smoke
/// and copy the structural blocks from the written report.
const REGEN_SERVE: &str = "cargo run --release -p wagma -- serve --smoke --out /tmp/wagma-serve, \
then copy the `sweep` and `identity` blocks from /tmp/wagma-serve/SERVE_report.json";

/// The serve-smoke sweep: small, deterministic, and wide enough to cross
/// every canonical-codec branch that matters (two algorithms, a top-k
/// compressed arm, a seeded crash plan). 2 × 2 × 2 = 8 unique cells.
const SERVE_SMOKE_SWEEP: &str = r#"{"preset":"fig4","algos":["wagma","allreduce_sgd"],"p":[4],"tau":[10],"steps":12,"compression":["none","topk:0.25"],"faults":["none","crash@mid"]}"#;

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.has("smoke") {
        return cmd_serve_smoke(args);
    }
    let Some(addr) = args.get("addr") else {
        anyhow::bail!("wagma serve needs --addr HOST:PORT (or --smoke; see src/main.rs docs)");
    };
    let workers = args.usize_or(
        "workers",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );
    let cache = args.usize_or("cache", 4096);
    let daemon = wagma::serve::Daemon::start(addr, workers, cache)?;
    println!(
        "wagma serve listening on {} ({workers} workers, cache {cache} cells)",
        daemon.local_addr()
    );
    println!(
        "routes: POST /v1/simulate  POST /v1/sweep  GET /v1/cells/<hash>  GET /v1/presets  \
         /metrics  /snapshot.json  /healthz"
    );
    // The daemon runs on its own threads; this thread just keeps the
    // process alive until the operator kills it.
    loop {
        std::thread::park();
    }
}

/// The serve acceptance check, end to end over real HTTP: submit the
/// smoke sweep twice (the second pass must compute nothing), compare
/// every streamed cell bit-for-bit against an inline simulation and a
/// `/v1/cells/<hash>` cache replay, and verify the daemon publishes
/// telemetry snapshots. Writes the pass-1 JSONL stream and a structural
/// report under --out; --check-serve-baseline gates the report against
/// the checked-in baseline.
fn cmd_serve_smoke(args: &Args) -> anyhow::Result<()> {
    use std::io::Write as _;
    use wagma::serve::{canonical, client, sweep_stream, Daemon};
    use wagma::util::json::{num, obj, s as jstr, Json};

    let out = args.str_or("out", "results");
    std::fs::create_dir_all(&out)?;
    // Drive a daemon the caller started (--addr, the CI path) or our
    // own in-process one on an ephemeral port.
    let _own: Option<Daemon>;
    let addr = match args.get("addr") {
        Some(a) => {
            _own = None;
            a.to_string()
        }
        None => {
            let d = Daemon::start("127.0.0.1:0", 2, 256)?;
            let a = d.local_addr().to_string();
            _own = Some(d);
            a
        }
    };
    println!("== serve smoke against {addr} ==");

    // Pass 1: stream the sweep, persist the JSONL exactly as received.
    let jsonl_path = std::path::Path::new(&out).join("serve_sweep.jsonl");
    let mut jsonl = std::fs::File::create(&jsonl_path)?;
    let mut records: Vec<Json> = Vec::new();
    let summary1 = sweep_stream(&addr, SERVE_SMOKE_SWEEP, |rec| {
        let _ = writeln!(jsonl, "{}", rec.to_string());
        records.push(rec.clone());
    })?;
    writeln!(jsonl, "{}", summary1.to_string())?;
    let sfield = |sm: &Json, k: &str| {
        sm.get("summary").and_then(|x| x.get(k)).and_then(|v| v.as_f64()).unwrap_or(-1.0)
    };
    println!(
        "pass 1: {} cells streamed ({} computed, {} cache hits) -> {}",
        sfield(&summary1, "cells"),
        sfield(&summary1, "computed"),
        sfield(&summary1, "cache_hits"),
        jsonl_path.display()
    );

    // Pass 2: the same sweep must compute nothing — the cache-hit
    // counters are the proof each cell was computed exactly once.
    let summary2 = sweep_stream(&addr, SERVE_SMOKE_SWEEP, |_| {})?;
    println!(
        "pass 2: {} cells streamed ({} computed, {} cache hits)",
        sfield(&summary2, "cells"),
        sfield(&summary2, "computed"),
        sfield(&summary2, "cache_hits"),
    );

    // Bit-identity: every streamed cell vs an inline simulation of its
    // own config, and vs the daemon's cache-replay route.
    let mut inline_match = true;
    let mut replay_match = true;
    for rec in &records {
        let cell = rec.get("cell").ok_or_else(|| anyhow::anyhow!("record without cell"))?;
        let cfg_json =
            cell.get("config").ok_or_else(|| anyhow::anyhow!("cell without config"))?;
        let cfg = canonical::decode_config(cfg_json).map_err(|e| anyhow::anyhow!(e))?;
        let inline = canonical::encode_result(&simulate(&cfg)).to_string();
        let streamed =
            cell.get("result").ok_or_else(|| anyhow::anyhow!("cell without result"))?.to_string();
        if inline != streamed {
            inline_match = false;
            eprintln!("inline mismatch for cell {:?}", cell.get("hash"));
        }
        let hash = cell
            .get("hash")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("cell without hash"))?;
        let (status, body) = client::get(&addr, &format!("/v1/cells/{hash}"))?;
        if !status.contains("200") || String::from_utf8_lossy(&body) != cell.to_string() {
            replay_match = false;
            eprintln!("replay mismatch for cell {hash} ({status})");
        }
    }
    println!("cell identity: inline_match={inline_match} replay_match={replay_match}");

    // The daemon publishes worker telemetry like a training run.
    let telemetry_ok = wagma::telemetry::fetch_snapshot(&addr).is_ok();
    println!("telemetry snapshot after sweep: {telemetry_ok}");

    let report = obj(vec![
        ("quick", Json::Bool(true)),
        ("addr", jstr(&addr)),
        (
            "sweep",
            obj(vec![
                ("cells", num(sfield(&summary1, "cells"))),
                ("pass1_computed", num(sfield(&summary1, "computed"))),
                ("pass1_cache_hits", num(sfield(&summary1, "cache_hits"))),
                ("pass2_computed", num(sfield(&summary2, "computed"))),
                ("pass2_cache_hits", num(sfield(&summary2, "cache_hits"))),
                ("streamed_records", num(records.len() as f64)),
            ]),
        ),
        (
            "identity",
            obj(vec![
                ("inline_match", Json::Bool(inline_match)),
                ("replay_match", Json::Bool(replay_match)),
                ("telemetry_snapshot", Json::Bool(telemetry_ok)),
            ]),
        ),
    ]);
    let report_path = std::path::Path::new(&out).join("SERVE_report.json");
    std::fs::write(&report_path, report.to_string() + "\n")?;
    println!("report -> {}", report_path.display());

    // The smoke is self-checking even without a baseline file.
    let cells = sfield(&summary1, "cells");
    anyhow::ensure!(cells > 0.0, "sweep streamed no cells");
    anyhow::ensure!(
        records.len() as f64 == cells,
        "streamed {} records but summary says {cells} cells",
        records.len()
    );
    anyhow::ensure!(
        sfield(&summary2, "computed") == 0.0 && sfield(&summary2, "cache_hits") == cells,
        "second pass recomputed cells: computed={} hits={} (want 0/{cells})",
        sfield(&summary2, "computed"),
        sfield(&summary2, "cache_hits"),
    );
    anyhow::ensure!(inline_match, "streamed cells diverge from inline simulation");
    anyhow::ensure!(replay_match, "cache-replayed cells diverge from streamed cells");
    anyhow::ensure!(telemetry_ok, "daemon served no telemetry snapshot after a sweep");

    if let Some(baseline) = args.get("check-serve-baseline") {
        check_serve_baseline(&report, baseline)?;
        println!("serve baseline gate OK ({baseline})");
    }
    println!("serve smoke OK");
    Ok(())
}

/// Gate the smoke report's structural counters against the checked-in
/// baseline, exact equality: every field is grid arithmetic or a
/// determinism invariant, so any drift means the serve contract changed.
fn check_serve_baseline(
    report: &wagma::util::json::Json,
    baseline_path: &str,
) -> anyhow::Result<()> {
    run_baseline_gate("serve", REGEN_SERVE, report, baseline_path, |baseline, failures| {
        for field in
            ["cells", "pass1_computed", "pass1_cache_hits", "pass2_computed", "pass2_cache_hits"]
        {
            let want = baseline.get("sweep").and_then(|x| x.get(field)).and_then(|v| v.as_f64());
            let got = report.get("sweep").and_then(|x| x.get(field)).and_then(|v| v.as_f64());
            let Some(want) = want else {
                failures.push(format!("sweep.{field}: missing from {baseline_path} — add it"));
                continue;
            };
            if got != Some(want) {
                failures.push(format!("sweep.{field}: measured {got:?}, baseline {want}"));
            }
        }
        for field in ["inline_match", "replay_match", "telemetry_snapshot"] {
            let got =
                report.get("identity").and_then(|x| x.get(field)).and_then(|v| v.as_bool());
            if got != Some(true) {
                failures.push(format!("identity.{field}: {got:?}, must be true"));
            }
        }
        Ok(())
    })
}

fn cmd_top(args: &Args) -> anyhow::Result<()> {
    use wagma::telemetry::{fetch_snapshot, render_top, snapshot_from_json};
    use wagma::util::json::Json;

    let once = args.has("once");
    let interval = std::time::Duration::from_millis(args.u64_or("interval-ms", 1000));
    let width = args.usize_or("width", 100);

    if let Some(addr) = args.get("addr") {
        let mut frames = 0u64;
        let mut failures = 0u32;
        loop {
            match fetch_snapshot(addr) {
                Ok(snap) => {
                    failures = 0;
                    if frames > 0 {
                        print!("\x1b[H\x1b[J");
                    }
                    print!("{}", render_top(&snap, width));
                    frames += 1;
                    if once {
                        return Ok(());
                    }
                }
                Err(e) => {
                    failures += 1;
                    // A 503 just means the sampler hasn't closed its first
                    // window yet; keep polling unless asked for one frame
                    // or the endpoint stays unreachable.
                    if once || failures >= 10 {
                        anyhow::bail!("no snapshot from {addr}: {e}");
                    }
                    eprintln!("waiting for {addr}: {e}");
                }
            }
            std::thread::sleep(interval);
        }
    }

    if let Some(path) = args.get("file") {
        let render_last = |frames: u64| -> anyhow::Result<bool> {
            let text = std::fs::read_to_string(path)?;
            let Some(line) = text.lines().filter(|l| !l.trim().is_empty()).last() else {
                return Ok(false);
            };
            let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let snap = snapshot_from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            if frames > 0 {
                print!("\x1b[H\x1b[J");
            }
            print!("{}", render_top(&snap, width));
            Ok(true)
        };
        if once {
            if !render_last(0)? {
                anyhow::bail!("{path}: no telemetry snapshots yet");
            }
            return Ok(());
        }
        let mut frames = 0u64;
        loop {
            if render_last(frames)? {
                frames += 1;
            }
            std::thread::sleep(interval);
        }
    }

    anyhow::bail!("wagma top needs --addr HOST:PORT or --file FILE")
}

fn cmd_list() -> anyhow::Result<()> {
    println!("algorithms:");
    for a in Algorithm::all() {
        println!("  {}", a.name());
    }
    println!("\nfigure presets: {:?}", preset_names());
    println!("\nfigures: fig1..fig11, ablation (wagma figure <id>)");
    match Manifest::load("artifacts/manifest.json") {
        Ok(m) => {
            println!("\nmodels (artifacts/):");
            for (name, meta) in &m.models {
                println!(
                    "  {:<12} kind={:<10} params={:>10} batch={}",
                    name, meta.kind, meta.param_count, meta.batch
                );
            }
        }
        Err(_) => println!("\nmodels: none built — run `make artifacts`"),
    }
    Ok(())
}
