//! Tiny command-line argument parser (offline environment: no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Typed getters parse on access and report helpful errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.entry(body.to_string()).or_default().push(v);
                } else {
                    out.flags.entry(body.to_string()).or_default().push(String::new());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.parse_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.parse_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.parse_or(key, default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("") | Some("true") | Some("1") => true,
            Some("false") | Some("0") => false,
            Some(other) => panic!("--{key}: expected boolean, got {other:?}"),
        }
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key}: cannot parse {v:?}: {e}")),
        }
    }

    /// Comma-separated list value, e.g. `--algos wagma,local_sgd`.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["train", "extra", "--steps", "100", "--algo=wagma", "--verbose"]);
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.str_or("algo", "x"), "wagma");
        assert!(a.bool_or("verbose", false));
        assert!(!a.bool_or("quiet", false));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse(&["--algos", "wagma, sgp ,dpsgd"]);
        assert_eq!(a.list_or("algos", &[]), vec!["wagma", "sgp", "dpsgd"]);
        assert_eq!(a.list_or("missing", &["a"]), vec!["a"]);
        assert_eq!(a.f64_or("lr", 0.1), 0.1);
    }

    #[test]
    fn repeated_flags_last_wins() {
        let a = parse(&["--p", "4", "--p", "8"]);
        assert_eq!(a.usize_or("p", 0), 8);
        assert_eq!(a.get_all("p"), vec!["4", "8"]);
    }
}
