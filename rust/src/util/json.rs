//! Minimal JSON parser and emitter.
//!
//! The offline build environment has no `serde_json`, so we implement the
//! subset of JSON the project needs: parsing the artifact manifests emitted
//! by `python/compile/aot.py`, and emitting experiment/benchmark result
//! files. This is a complete, standards-conforming parser for the JSON we
//! produce (objects, arrays, strings with escapes, numbers, booleans, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for JSON objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at offset {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at offset {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at offset {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other.map(|b| b as char))),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 code point.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end]).map_err(|e| e.to_string())?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        txt.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {txt}: {e}"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("hi\nthere"));
        assert_eq!(v.get("e"), Some(&Json::Null));
        // Reparse the emitted string and compare.
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", num(3.0)), ("y", s("z")), ("a", arr([num(1.0)]))]);
        assert_eq!(v.to_string(), r#"{"a":[1],"x":3,"y":"z"}"#);
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(num(1024.0).to_string(), "1024");
        assert_eq!(num(0.5).to_string(), "0.5");
    }
}
