//! Minimal property-based testing harness (offline: no `proptest` crate).
//!
//! [`check`] runs a property against many pseudo-random cases drawn from a
//! deterministic seed sequence; on failure it reports the failing seed so the
//! case can be replayed, and performs a simple "shrink" by retrying the
//! property with smaller size hints.

use super::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to generators (e.g. max vector length).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Source of randomness plus a size hint, handed to each property case.
pub struct Gen<'a> {
    pub rng: &'a mut Xoshiro256,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.usize_below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_f32(0.0, 1.0)).collect()
    }

    /// Random power of two in [lo, hi] (both must be powers of two).
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_e = lo.trailing_zeros();
        let hi_e = hi.trailing_zeros();
        1usize << self.usize_in(lo_e as usize, hi_e as usize)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `prop` against `cfg.cases` random cases. Panics with the failing
/// case's seed and size on the first failure (after size-shrinking retries).
pub fn check_with<F>(cfg: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // Ramp sizes up over the run so early failures are small.
        let size = 1 + (cfg.max_size * (case + 1)) / cfg.cases;
        let mut rng = Xoshiro256::seed_from_u64(case_seed);
        let mut g = Gen { rng: &mut rng, size };
        if let Err(msg) = prop(&mut g) {
            // Shrink attempt: replay with progressively smaller size hints
            // to find a smaller failing size for the report.
            let mut min_fail = size;
            for s in (1..size).rev() {
                let mut rng = Xoshiro256::seed_from_u64(case_seed);
                let mut g = Gen { rng: &mut rng, size: s };
                if prop(&mut g).is_err() {
                    min_fail = s;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, size {size}, \
                 min failing size {min_fail}): {msg}"
            );
        }
    }
}

/// Run with the default configuration.
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_with(Config::default(), name, prop);
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check_with(Config { cases: 50, ..Default::default() }, "count", |g| {
            n += 1;
            let len = g.usize_in(0, 8);
            let v = g.vec_f32(len);
            prop_assert!(v.len() <= 8);
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", |g| {
            let _ = g.bool();
            Err("nope".into())
        });
    }

    #[test]
    fn pow2_generator() {
        check("pow2", |g| {
            let p = g.pow2_in(2, 64);
            prop_assert!(p.is_power_of_two() && (2..=64).contains(&p), "p={p}");
            Ok(())
        });
    }
}
