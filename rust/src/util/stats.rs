//! Summary statistics used by the metrics recorders and bench harness.
//!
//! The percentile implementation lives in the trace layer's histogram
//! registry ([`crate::trace::hist`]) and is re-exported here, so every
//! percentile in the tree — bench summaries, trace histograms, staleness
//! aggregates — shares one tested helper.

pub use crate::trace::hist::percentile_sorted;

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        Summary {
            n: s.len(),
            mean: w.mean(),
            std: w.std(),
            min: s[0],
            p25: percentile_sorted(&s, 0.25),
            p50: percentile_sorted(&s, 0.50),
            p75: percentile_sorted(&s, 0.75),
            p95: percentile_sorted(&s, 0.95),
            p99: percentile_sorted(&s, 0.99),
            max: *s.last().unwrap(),
        }
    }
}

/// Simple fixed-width text histogram (for Fig. 6 / Fig. 9 style runtime
/// distribution output in the terminal).
pub fn ascii_histogram(xs: &[f64], bins: usize, width: usize) -> String {
    assert!(bins >= 1);
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (max - min).max(1e-12);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x - min) / span) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let peak = *counts.iter().max().unwrap() as f64;
    let mut out = String::new();
    for (i, &c) in counts.iter().enumerate() {
        let lo = min + span * i as f64 / bins as f64;
        let hi = min + span * (i + 1) as f64 / bins as f64;
        let bar = "#".repeat(((c as f64 / peak) * width as f64).round() as usize);
        out.push_str(&format!("{lo:10.3} – {hi:10.3} | {bar} {c}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile_sorted(&s, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 1.0) - 100.0).abs() < 1e-12);
        assert!((percentile_sorted(&s, 0.5) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_sane() {
        let xs: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 1000);
        assert!((s.mean - 4.5).abs() < 1e-9);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 9.0);
        assert!(s.p50 >= 4.0 && s.p50 <= 5.0);
    }

    #[test]
    fn histogram_counts_everything() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let h = ascii_histogram(&xs, 10, 40);
        assert_eq!(h.lines().count(), 10);
        // Each decade bin holds 10 samples.
        assert!(h.lines().all(|l| l.trim_end().ends_with("10")));
    }
}
