//! Shared utility substrates: deterministic RNG + distributions, minimal
//! JSON, summary statistics, CLI parsing, and a small property-testing
//! harness. These replace the third-party crates (`rand`, `serde_json`,
//! `clap`, `proptest`, `criterion`) that are unavailable in the offline
//! build environment.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Elementwise in-place `dst += src`. The innermost loop of every model
/// averaging collective; kept here so all call-sites share one optimized
/// implementation (auto-vectorizes under `-O`; chunked to help LLVM).
#[inline]
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d += *s;
    }
}

/// Elementwise `dst = a + b` in one pass (no intermediate copy). The
/// reduction primitive of the zero-copy collective engine: `a` and `b` are
/// shared (possibly in-flight) buffers that must not be mutated, `dst` is
/// a pooled output buffer. Plain indexed loop so LLVM autovectorizes.
#[inline]
pub fn sum_into(dst: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(dst.len(), a.len());
    assert_eq!(dst.len(), b.len());
    for i in 0..dst.len() {
        dst[i] = a[i] + b[i];
    }
}

/// Elementwise in-place `dst = (dst + src) * scale`.
#[inline]
pub fn add_scale(dst: &mut [f32], src: &[f32], scale: f32) {
    assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d = (*d + *s) * scale;
    }
}

/// Elementwise in-place `dst *= scale`.
#[inline]
pub fn scale(dst: &mut [f32], scale: f32) {
    for d in dst.iter_mut() {
        *d *= scale;
    }
}

/// Elementwise `dst -= lr * src` (SGD update step).
#[inline]
pub fn axpy_neg(dst: &mut [f32], src: &[f32], lr: f32) {
    assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d -= lr * *s;
    }
}

/// L2 norm of a vector.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Maximum absolute elementwise difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_ops() {
        let mut d = vec![1.0f32, 2.0, 3.0];
        add_assign(&mut d, &[1.0, 1.0, 1.0]);
        assert_eq!(d, vec![2.0, 3.0, 4.0]);
        let mut out = vec![0.0f32; 3];
        sum_into(&mut out, &d, &[1.0, 2.0, 3.0]);
        assert_eq!(out, vec![3.0, 5.0, 7.0]);
        add_scale(&mut d, &[0.0, 1.0, 2.0], 0.5);
        assert_eq!(d, vec![1.0, 2.0, 3.0]);
        scale(&mut d, 2.0);
        assert_eq!(d, vec![2.0, 4.0, 6.0]);
        axpy_neg(&mut d, &[1.0, 1.0, 1.0], 2.0);
        assert_eq!(d, vec![0.0, 2.0, 4.0]);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }
}
