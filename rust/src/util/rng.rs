//! Deterministic pseudo-random number generation and distribution sampling.
//!
//! The build environment is offline, so instead of the `rand`/`rand_distr`
//! crates we ship a small, well-tested PRNG stack:
//!
//! * [`SplitMix64`] — seed expander (Steele et al., used to seed xoshiro).
//! * [`Xoshiro256`] — xoshiro256++ by Blackman & Vigna, the general-purpose
//!   generator used throughout the library (fast, 256-bit state, passes
//!   BigCrush).
//! * Distribution samplers used by the workload models: uniform, normal
//!   (Box–Muller), lognormal, exponential, Pareto, and Zipf (for synthetic
//!   token corpora).
//!
//! All experiment code takes explicit seeds so every figure is reproducible
//! bit-for-bit.

/// SplitMix64 PRNG. Primarily used to expand a 64-bit seed into the
/// 256-bit state of [`Xoshiro256`].
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ PRNG (Blackman & Vigna, 2019).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Create a generator from a 64-bit seed (expanded with SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// branch-free enough for workload modelling).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE); // (0,1]
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        mean + std * r * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        -u.ln() / lambda
    }

    /// Pareto with scale x_m and shape alpha (heavy-tailed RL episodes).
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        x_m / u.powf(1.0 / alpha)
    }

    /// Gaussian-distributed f32 (for synthetic features / init noise).
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        self.normal(mean as f64, std as f64) as f32
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Zipf-distributed integer sampler over [0, n) with exponent `s`,
/// using the rejection-inversion method of Hörmann & Derflinger.
/// Used to generate synthetic token corpora whose unigram statistics
/// resemble natural language.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dd: f64,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        let n = n as f64;
        let h = |x: f64, s: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let h_x1 = h(1.5, s) - 1.0;
        let h_n = h(n + 0.5, s);
        let dd = 1.0 - (h(1.5, s) - 1.0f64.powf(-s)).min(1.0);
        Zipf { n, s, h_x1, h_n, dd }
    }

    fn h_inv(&self, x: f64) -> f64 {
        if (self.s - 1.0).abs() < 1e-9 {
            x.exp() - 1.0
        } else {
            ((1.0 - self.s) * x + 1.0).powf(1.0 / (1.0 - self.s)) - 1.0
        }
    }

    /// Sample a rank in [0, n). Rank 0 is the most frequent.
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let _ = self.dd;
        loop {
            let u = self.h_x1 + rng.next_f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n);
            // Accept with probability proportional to the true pmf.
            let h = |y: f64| -> f64 {
                if (self.s - 1.0).abs() < 1e-9 {
                    (1.0 + y).ln()
                } else {
                    ((1.0 + y).powf(1.0 - self.s) - 1.0) / (1.0 - self.s)
                }
            };
            if u >= h(k + 0.5) - (k).powf(-self.s) {
                return k as usize - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the public-domain C implementation.
        let mut sm = SplitMix64::new(1234567);
        let v1 = sm.next_u64();
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(v1, sm2.next_u64());
        assert_ne!(sm.next_u64(), v1);
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            let k = rng.next_below(17);
            assert!(k < 17);
        }
    }

    #[test]
    fn uniform_below_unbiased() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[rng.usize_below(8)] += 1;
        }
        for &c in &counts {
            let expected = n / 8;
            assert!(
                (c as i64 - expected as i64).unsigned_abs() < (expected / 10) as u64,
                "bucket count {c} too far from {expected}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal(2.0, 3.0);
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn pareto_heavy_tail() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.pareto(1.0, 2.0)).collect();
        assert!(samples.iter().all(|&x| x >= 1.0));
        // Median of Pareto(1, 2) is 2^(1/2).
        let mut s = samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = s[n / 2];
        assert!((median - 2f64.sqrt()).abs() < 0.05, "median={median}");
    }

    #[test]
    fn zipf_rank_ordering() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 should dominate rank 10 which dominates rank 90.
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
        assert!(counts.iter().sum::<usize>() == 200_000);
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Xoshiro256::seed_from_u64(21);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_props() {
        let mut rng = Xoshiro256::seed_from_u64(22);
        for _ in 0..100 {
            let k = rng.usize_below(10);
            let s = rng.sample_distinct(32, k);
            assert_eq!(s.len(), k);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), k, "indices must be distinct");
            assert!(s.iter().all(|&i| i < 32));
        }
    }
}
