//! Deterministic fault injection for the engine and the simulator.
//!
//! A [`FaultPlan`] is a *seeded, declarative* description of everything
//! that goes wrong in a run: per-rank fail-stop crashes at a given
//! iteration, transient compute stalls, a per-rank compute-skew
//! multiplier, and per-link jitter/drop. Both the real collective engine
//! ([`crate::collectives::engine`]) and the discrete-event simulator
//! ([`crate::simulator`]) consume the same plan, so every messy-fleet
//! scenario is reproducible bit-for-bit and priceable analytically.
//!
//! Determinism is the load-bearing property: the plan is **stateless**.
//! Randomized faults (jitter, drops) are pure hash functions of
//! `(seed, src, dst, iteration[, phase])` — there is no RNG stream to
//! advance, so the engine's racy thread interleavings and the
//! simulator's sequential replay observe the *same* faults, and any
//! rank can evaluate any other rank's faults locally. That is what lets
//! [`Membership::apply_plan`] act as a shared membership oracle: all
//! survivors derive identical survivor sets at every version boundary
//! without a consensus round, which in turn is what keeps survivor
//! models rank-identical after the first post-failure τ-sync.
//!
//! The failure model is **deterministic fail-stop**: a crashed rank
//! stops sending anything (data and control) from its crash iteration
//! onward and never recovers. Transient faults (stalls, jitter, drops)
//! delay or suppress individual messages; the engine's bounded-retry
//! receive turns those into *suspect* peers whose butterfly phase
//! completes as identity (see `collectives/README.md`, "Failure model &
//! degraded paths").

use std::fmt;

/// A fail-stop crash: `rank` executes nothing from `at_iter` on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Crash {
    pub rank: usize,
    /// First iteration (collective version) the rank does NOT execute.
    pub at_iter: u64,
}

/// A transient stall: `rank`'s compute takes `seconds` longer for every
/// iteration `t` with `from <= t < to`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stall {
    pub rank: usize,
    pub from: u64,
    pub to: u64,
    pub seconds: f64,
}

/// Per-link fault knobs, applied to group-exchange traffic (never to
/// τ-sync traffic — the sync is the recovery barrier and must converge).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFaults {
    /// Upper bound of the per-message uniform extra latency, seconds.
    pub jitter_s: f64,
    /// Probability a group-exchange phase's payload is dropped on a
    /// given (src, dst, iteration, phase) link event, in `[0, 1]`.
    pub drop_prob: f64,
}

impl Default for LinkFaults {
    fn default() -> Self {
        LinkFaults { jitter_s: 0.0, drop_prob: 0.0 }
    }
}

/// Default receive deadline when a plan is active but no explicit
/// deadline was configured: 50 ms.
pub const DEFAULT_DEADLINE_S: f64 = 0.05;

/// A deterministic, seeded fault scenario. See the module docs for the
/// determinism contract. `FaultPlan::none()` (= `Default`) injects
/// nothing and keeps every engine/simulator code path bit-identical to
/// a fault-free build.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for the stateless per-event hashes (jitter, drops).
    pub seed: u64,
    pub crashes: Vec<Crash>,
    pub stalls: Vec<Stall>,
    /// Per-rank compute-time multiplier; empty means all `1.0`.
    pub skew: Vec<f64>,
    pub link: LinkFaults,
    /// Receive deadline (seconds) the engine and the simulator charge
    /// for detecting a missing peer. Shared so the simulated
    /// Allreduce-SGD stall penalty matches the engine's configured
    /// patience. Not part of [`is_empty`](Self::is_empty).
    pub deadline_s: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            crashes: Vec::new(),
            stalls: Vec::new(),
            skew: Vec::new(),
            link: LinkFaults::default(),
            deadline_s: DEFAULT_DEADLINE_S,
        }
    }
}

/// SplitMix64 finalizer — the stateless hash behind jitter/drop draws.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a hash to a uniform draw in `[0, 1)`.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

impl FaultPlan {
    /// The empty plan: no faults, default deadline.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// No faults configured at all (the deadline is a detection knob,
    /// not a fault, and does not count).
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.stalls.is_empty()
            && self.skew.iter().all(|&s| s == 1.0)
            && self.link.jitter_s == 0.0
            && self.link.drop_prob == 0.0
    }

    /// First iteration `rank` does not execute, if it crashes at all.
    pub fn crash_iter(&self, rank: usize) -> Option<u64> {
        self.crashes.iter().filter(|c| c.rank == rank).map(|c| c.at_iter).min()
    }

    /// Is `rank` crashed at (the start of) iteration `t`?
    pub fn crash_at(&self, rank: usize, t: u64) -> bool {
        self.crash_iter(rank).is_some_and(|ci| t >= ci)
    }

    /// Compute-time multiplier for `rank` (`1.0` when unspecified).
    pub fn skew_of(&self, rank: usize) -> f64 {
        self.skew.get(rank).copied().unwrap_or(1.0)
    }

    /// Extra compute seconds `rank` loses at iteration `t` (summed over
    /// overlapping stall windows).
    pub fn stall_s(&self, rank: usize, t: u64) -> f64 {
        self.stalls
            .iter()
            .filter(|s| s.rank == rank && s.from <= t && t < s.to)
            .map(|s| s.seconds)
            .sum()
    }

    /// Chain the seed with per-event coordinates into one hash.
    fn mix(&self, vals: [u64; 4]) -> u64 {
        let mut h = splitmix64(self.seed ^ 0xD6E8_FEB8_6659_FD93);
        for v in vals {
            h = splitmix64(h ^ v);
        }
        h
    }

    /// Deterministic extra latency (seconds) on the `src -> dst` link
    /// for iteration `t`, uniform in `[0, jitter_s)`.
    pub fn jitter_s(&self, src: usize, dst: usize, t: u64) -> f64 {
        if self.link.jitter_s <= 0.0 {
            return 0.0;
        }
        unit(self.mix([src as u64, dst as u64, t, 0x4A17])) * self.link.jitter_s
    }

    /// Deterministic drop decision for the payload of butterfly phase
    /// `r` of iteration `t` on the `src -> dst` link.
    pub fn drop_link(&self, src: usize, dst: usize, t: u64, r: u32) -> bool {
        self.link.drop_prob > 0.0
            && unit(self.mix([src as u64, dst as u64, t, 0xD0_0000 | r as u64]))
                < self.link.drop_prob
    }

    /// The configured detection deadline in nanoseconds.
    pub fn deadline_ns(&self) -> u64 {
        (self.deadline_s.max(0.0) * 1e9) as u64
    }

    /// Canonical smoke scenario: the last rank fail-stops halfway
    /// through the run.
    pub fn crash_mid(p: usize, steps: u64, seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            crashes: vec![Crash { rank: p.saturating_sub(1), at_iter: steps / 2 }],
            ..FaultPlan::default()
        }
    }

    /// Parse a CLI fault spec. Accepted: `none` (or empty), `crash@mid`,
    /// `crash@N` (last rank fail-stops at iteration `N`).
    pub fn parse(spec: &str, p: usize, steps: u64, seed: u64) -> Result<FaultPlan, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan { seed, ..FaultPlan::default() });
        }
        if let Some(at) = spec.strip_prefix("crash@") {
            if at == "mid" {
                return Ok(FaultPlan::crash_mid(p, steps, seed));
            }
            let at_iter: u64 = at
                .parse()
                .map_err(|_| format!("bad fault spec {spec:?}: crash@<iter|mid>"))?;
            return Ok(FaultPlan {
                seed,
                crashes: vec![Crash { rank: p.saturating_sub(1), at_iter }],
                ..FaultPlan::default()
            });
        }
        Err(format!("unknown fault spec {spec:?} (try: none, crash@mid, crash@<iter>)"))
    }
}

/// Health of a peer as seen by one rank's engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerState {
    /// Responding normally.
    Healthy,
    /// Missed a bounded-retry receive window; its phases complete as
    /// identity until it is heard from again.
    Suspect,
    /// Fail-stopped (plan-declared or death-notice). Terminal.
    Dead,
}

impl fmt::Display for PeerState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            PeerState::Healthy => "healthy",
            PeerState::Suspect => "suspect",
            PeerState::Dead => "dead",
        })
    }
}

/// Per-rank membership view: one [`PeerState`] per rank.
///
/// Dead is terminal; Suspect heals on the next successful receive. The
/// *deterministic* transitions (plan-declared crashes, applied at every
/// version-execution boundary via [`apply_plan`](Self::apply_plan)) are
/// what survivor bit-identity rests on — all live ranks derive the same
/// survivor set for a given version without communicating. Suspect is a
/// local, possibly-spurious judgement and deliberately never influences
/// the τ-sync participant set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Membership {
    states: Vec<PeerState>,
}

impl Membership {
    pub fn new(p: usize) -> Membership {
        Membership { states: vec![PeerState::Healthy; p] }
    }

    pub fn state(&self, rank: usize) -> PeerState {
        self.states[rank]
    }

    pub fn is_dead(&self, rank: usize) -> bool {
        self.states[rank] == PeerState::Dead
    }

    /// Dead or currently suspect — skip this peer's butterfly phase.
    pub fn is_down(&self, rank: usize) -> bool {
        self.states[rank] != PeerState::Healthy
    }

    pub fn mark_dead(&mut self, rank: usize) {
        self.states[rank] = PeerState::Dead;
    }

    /// Suspect a peer after a missed deadline window (no-op if Dead).
    pub fn mark_suspect(&mut self, rank: usize) {
        if self.states[rank] == PeerState::Healthy {
            self.states[rank] = PeerState::Suspect;
        }
    }

    /// A successful receive clears suspicion (Dead stays Dead).
    pub fn heal(&mut self, rank: usize) {
        if self.states[rank] == PeerState::Suspect {
            self.states[rank] = PeerState::Healthy;
        }
    }

    /// Clear every `Suspect` verdict (Dead stays Dead). Called when a
    /// global sync completes: its unbounded receives prove every awaited
    /// survivor live, so lingering suspicions were transient.
    pub fn heal_all(&mut self) {
        for s in &mut self.states {
            if *s == PeerState::Suspect {
                *s = PeerState::Healthy;
            }
        }
    }

    /// Fold the plan's fail-stop schedule in at a version boundary:
    /// every rank whose crash iteration is `<= v` is Dead before any
    /// rank executes version `v`. Deterministic — see the type docs.
    pub fn apply_plan(&mut self, plan: &FaultPlan, v: u64) {
        for c in &plan.crashes {
            if c.at_iter <= v && c.rank < self.states.len() {
                self.states[c.rank] = PeerState::Dead;
            }
        }
    }

    /// Sorted ranks not known dead (Suspect counts as surviving: only
    /// the deterministic Dead state may shrink the sync participant
    /// set, or survivor sets could disagree across ranks).
    pub fn survivors(&self) -> Vec<usize> {
        (0..self.states.len()).filter(|&r| !self.is_dead(r)).collect()
    }

    pub fn dead_count(&self) -> usize {
        self.states.iter().filter(|&&s| s == PeerState::Dead).count()
    }

    pub fn all_alive(&self) -> bool {
        self.dead_count() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_empty_and_injects_nothing() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        assert_eq!(plan.crash_iter(0), None);
        assert!(!plan.crash_at(3, 100));
        assert_eq!(plan.skew_of(7), 1.0);
        assert_eq!(plan.stall_s(0, 5), 0.0);
        assert_eq!(plan.jitter_s(0, 1, 9), 0.0);
        assert!(!plan.drop_link(0, 1, 9, 2));
        assert_eq!(plan.deadline_ns(), 50_000_000);
    }

    #[test]
    fn explicit_unit_skew_still_empty() {
        let plan = FaultPlan { skew: vec![1.0; 8], ..FaultPlan::default() };
        assert!(plan.is_empty());
        let plan = FaultPlan { skew: vec![1.0, 2.0], ..FaultPlan::default() };
        assert!(!plan.is_empty());
        assert_eq!(plan.skew_of(1), 2.0);
        assert_eq!(plan.skew_of(5), 1.0, "out of range defaults to 1.0");
    }

    #[test]
    fn crash_semantics() {
        let plan = FaultPlan::crash_mid(4, 12, 42);
        assert!(!plan.is_empty());
        assert_eq!(plan.crash_iter(3), Some(6));
        assert_eq!(plan.crash_iter(0), None);
        assert!(!plan.crash_at(3, 5));
        assert!(plan.crash_at(3, 6));
        assert!(plan.crash_at(3, 11));
        assert!(!plan.crash_at(2, 11));
    }

    #[test]
    fn stall_window_sums() {
        let plan = FaultPlan {
            stalls: vec![
                Stall { rank: 1, from: 2, to: 5, seconds: 0.1 },
                Stall { rank: 1, from: 4, to: 6, seconds: 0.2 },
            ],
            ..FaultPlan::default()
        };
        assert_eq!(plan.stall_s(1, 1), 0.0);
        assert_eq!(plan.stall_s(1, 2), 0.1);
        assert!((plan.stall_s(1, 4) - 0.3).abs() < 1e-12, "windows overlap");
        assert_eq!(plan.stall_s(1, 5), 0.2);
        assert_eq!(plan.stall_s(1, 6), 0.0, "`to` is exclusive");
        assert_eq!(plan.stall_s(0, 4), 0.0);
    }

    #[test]
    fn jitter_and_drop_are_deterministic_and_bounded() {
        let plan = FaultPlan {
            seed: 7,
            link: LinkFaults { jitter_s: 0.002, drop_prob: 0.5 },
            ..FaultPlan::default()
        };
        for t in 0..50u64 {
            let j = plan.jitter_s(1, 2, t);
            assert!((0.0..0.002).contains(&j), "jitter {j} out of bounds");
            assert_eq!(j, plan.jitter_s(1, 2, t), "stateless: same event, same draw");
            assert_eq!(plan.drop_link(2, 3, t, 1), plan.drop_link(2, 3, t, 1));
        }
        // Different seeds decorrelate.
        let other = FaultPlan { seed: 8, ..plan.clone() };
        let same = (0..50u64).filter(|&t| plan.jitter_s(1, 2, t) == other.jitter_s(1, 2, t)).count();
        assert!(same < 5, "seeds should decorrelate draws");
        // Roughly half the links drop at p = 0.5.
        let drops = (0..200u64).filter(|&t| plan.drop_link(0, 1, t, 0)).count();
        assert!((50..150).contains(&drops), "drop rate wildly off: {drops}/200");
    }

    #[test]
    fn parse_specs() {
        assert!(FaultPlan::parse("none", 4, 12, 1).unwrap().is_empty());
        assert!(FaultPlan::parse("", 4, 12, 1).unwrap().is_empty());
        let mid = FaultPlan::parse("crash@mid", 4, 12, 1).unwrap();
        assert_eq!(mid.crash_iter(3), Some(6));
        let at = FaultPlan::parse("crash@9", 8, 20, 1).unwrap();
        assert_eq!(at.crash_iter(7), Some(9));
        assert!(FaultPlan::parse("garbage", 4, 12, 1).is_err());
        assert!(FaultPlan::parse("crash@soon", 4, 12, 1).is_err());
    }

    #[test]
    fn membership_transitions() {
        let mut m = Membership::new(4);
        assert!(m.all_alive());
        assert_eq!(m.survivors(), vec![0, 1, 2, 3]);
        m.mark_suspect(2);
        assert!(m.is_down(2));
        assert!(!m.is_dead(2));
        assert_eq!(m.survivors(), vec![0, 1, 2, 3], "suspect still counts as survivor");
        m.heal(2);
        assert_eq!(m.state(2), PeerState::Healthy);
        m.mark_dead(3);
        m.mark_suspect(3);
        m.heal(3);
        assert!(m.is_dead(3), "dead is terminal");
        assert_eq!(m.survivors(), vec![0, 1, 2]);
        assert_eq!(m.dead_count(), 1);
    }

    #[test]
    fn apply_plan_is_a_shared_oracle() {
        let plan = FaultPlan::crash_mid(4, 12, 0);
        let mut a = Membership::new(4);
        let mut b = Membership::new(4);
        a.apply_plan(&plan, 5);
        b.apply_plan(&plan, 5);
        assert!(a.all_alive());
        a.apply_plan(&plan, 6);
        b.apply_plan(&plan, 6);
        assert_eq!(a, b);
        assert_eq!(a.survivors(), vec![0, 1, 2]);
    }
}
