//! The wait-avoiding group allreduce engine (paper §III-A).
//!
//! Every rank runs a dedicated **communication engine thread** next to its
//! application (training) thread — the in-process analogue of fflib's
//! asynchronously-progressed schedules. The engine owns the rank's
//! [`Endpoint`] and maintains the rank's *send slot* holding its newest
//! model contribution.
//!
//! Protocol (one collective instance = one `version`, the training
//! iteration number):
//!
//! 1. The first rank whose application reaches the call site — the
//!    *activator* — broadcasts `Activation{version}` down the binomial tree
//!    rooted at itself (§III-A1, Fig. 1). Forwarders propagate the message
//!    to their children in the same tree *immediately*, even from inside a
//!    running schedule (control traffic is handled inline by the matched
//!    receive), then execute the schedule themselves.
//! 2. Each engine executes the group allreduce schedule for `version`:
//!    `log2(S)` butterfly phases with partners drawn from the dynamic
//!    grouping (Algorithm 1). The contribution is whatever the send slot
//!    holds — a **stale** model if the rank's application has not caught up
//!    (§IV, Fig. 3); the stamp of the contributed buffer is recorded.
//! 3. Versions are executed strictly in order; a version is executed
//!    exactly once per rank (the paper's version-number check — a second
//!    activation or a late application arrival finds it already done).
//! 4. The application retrieves [`GroupResult`]: the group sum plus whether
//!    its *own* fresh contribution made it in. WAGMA-SGD turns that into
//!    `W_sum / S` (fresh, Alg. 2 line 11) or `(W_sum + W') / (S+1)`
//!    (stale, line 13).
//!
//! The every-τ global synchronization (Alg. 2 line 16) also runs on the
//! engine thread (`AppSync`), so the mailbox has a single consumer.
//!
//! ## Data path (zero-copy, lock-split)
//!
//! The steady-state data path performs **no payload copies and no
//! allocations**:
//!
//! * the send slot holds a refcounted [`SharedBuf`]; `publish_owned`
//!   installs the application's vector by move and the engine snapshots it
//!   with a refcount bump;
//! * every butterfly send is a [`Chunk`] view of the accumulator (chunked
//!   exchanges send range views — no per-chunk materialization);
//! * reductions are in-place when the partner has already released our
//!   buffer (`Arc::try_unwrap`), else a single fused `sum_into` pass into
//!   a buffer from the endpoint's [`BufferPool`]; pooled buffers return to
//!   their home pool wherever the last reference drops;
//! * the every-τ ring keeps the model as `P` segment views, reducing into
//!   pooled segments and forwarding allgather segments by reference.
//!
//! Application↔engine state is lock-split: the send slot, the result maps
//! (the only condvar — the blocking `group_allreduce`/`global_sync` edge),
//! and the staleness log each have their own mutex, so a `publish` never
//! contends with a result wait. [`EngineStats::copied_bytes`] counts the
//! residual memcpy'd payload bytes (ring reassembly, the borrowing
//! `publish`), which the measured-overlap bench compares against the
//! pre-refactor engine's per-phase clones.
//!
//! ## Failure model & degraded paths
//!
//! With a [`FaultPlan`] installed ([`CollectiveEngine::spawn_with_faults`])
//! the engine survives a messy fleet: group-phase receives are
//! deadline-bounded with exponential-backoff retries; a peer that misses
//! its window is marked *suspect* and its butterfly phase completes as
//! **identity** (the accumulator passes through unchanged — counted in
//! [`EngineStats::skipped_phases`]/[`EngineStats::degraded_iters`]); a
//! plan-crashed rank fail-stops at its crash iteration (broadcasting a
//! death notice, then going dark), and the every-τ sync re-forms over the
//! survivors — recursive doubling over survivor indices or a re-segmented
//! survivor ring — so all survivors hold bit-identical models after the
//! first post-failure sync. An empty plan with `recv_deadline_ns == 0`
//! takes literally the pre-fault code paths (bit-identical counters).
//! See `collectives/README.md` § "Failure model & degraded paths".

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::collectives::allreduce::{
    decode_sum_shared, reduce_shared, ring_allreduce_segments_compressed_over,
    ring_allreduce_segments_over, shared_into_vec, AllreduceAlgo, RING_THRESHOLD,
};
use crate::compress::{Compression, EncodeScratch};
use crate::comm::{
    BufferPool, Chunk, Endpoint, MailboxSender, Message, Payload, PoolStats, SharedBuf, Tag,
};
use crate::fault::{FaultPlan, Membership, PeerState};
use crate::telemetry::TelemetryRegistry;
use crate::topology::{log2_exact, BinomialTree, Grouping};
use crate::trace::{
    now_ns, Lane, LogHistogram, TraceEvent, TraceKind, TraceRecorder, TRACE_RING_CAPACITY,
};
use crate::util::sum_into;

/// Stamp of a send buffer that has never been published by the
/// application (the initial model W_0).
pub const STAMP_INITIAL: u64 = u64::MAX;

/// Result of one group allreduce as seen by the application.
#[derive(Debug, Clone)]
pub struct GroupResult {
    /// Elementwise sum over the group's contributions (size S).
    pub sum: Vec<f32>,
    /// Iteration stamp of the buffer THIS rank contributed
    /// ([`STAMP_INITIAL`] if it was still the initial model).
    pub contributed_stamp: u64,
}

impl GroupResult {
    /// Did this rank's fresh `W'_t` make the collective (Alg. 2 line 10)?
    pub fn is_fresh(&self, t: u64) -> bool {
        self.contributed_stamp == t
    }

    /// Iterations of staleness of this rank's contribution at iteration
    /// `t` (the initial model counts as maximally stale: `t + 1`).
    pub fn staleness(&self, t: u64) -> u64 {
        if self.contributed_stamp > t {
            t + 1
        } else {
            t - self.contributed_stamp
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Total ranks (power of two).
    pub p: usize,
    /// Group size (power of two, ≤ P).
    pub group_size: usize,
    /// Global synchronization period τ: iterations t with
    /// `(t+1) % tau == 0` run the global allreduce instead of a group
    /// collective. `0` disables global syncs (unbounded staleness —
    /// used by ablations).
    pub tau: u64,
    /// Dynamic (paper default) vs fixed grouping (ablation ❷).
    pub dynamic_groups: bool,
    /// Algorithm for the every-τ global allreduce.
    pub sync_algo: AllreduceAlgo,
    /// Activation quorum (paper §VI): [`ActivationMode::Solo`] triggers on
    /// the first arrival (wait-avoiding group collectives, this paper);
    /// [`ActivationMode::Majority`] waits for ⌈P/2⌉ arrivals before the
    /// version leader broadcasts activation (the PPoPP'20 eager-SGD
    /// majority collectives, used by the eager-SGD baseline).
    pub activation: ActivationMode,
    /// Bucketed-exchange granularity in f32 elements (0 = send the whole
    /// payload in one message, the seed behaviour). When nonzero, each
    /// butterfly phase streams the buffer as `ceil(n / chunk_elems)`
    /// independently-tagged chunks — the engine-level counterpart of the
    /// scheduler's fused gradient buckets ([`crate::sched`]), so a fused
    /// bucket can be injected as soon as it is ready instead of waiting for
    /// the full flat payload. Chunks are range views of one shared buffer,
    /// not copies.
    pub chunk_elems: usize,
    /// Per-bucket wire compression ([`crate::compress`]). With anything
    /// other than [`Compression::None`] every butterfly phase encodes its
    /// contribution (per chunk, so the fusion buckets are the compression
    /// units) into a pooled buffer, sends the encoding, and folds the
    /// partner's encoding in via the fused decompress-sum; the every-τ
    /// global sync runs the compressed ring (rank-identical decode) for
    /// ring-sized payloads and stays exact below [`RING_THRESHOLD`]
    /// (latency-bound — compression buys nothing there).
    /// `Compression::None` takes the exact pre-compression code paths,
    /// bit-identical to the uncompressed build.
    pub compression: Compression,
    /// Always-on tracing ([`crate::trace`]): one span per butterfly phase
    /// and τ-sync on the engine lane, publish/wait spans on the app lane.
    /// Recording is fixed-capacity drop-oldest with zero steady-state
    /// allocations and never touches the data path (`copied_bytes` /
    /// `pool_allocs` are bit-identical with tracing on or off); `false`
    /// turns the recorder into a no-op.
    pub trace: bool,
    /// Bounded-receive deadline for group butterfly phases, in
    /// nanoseconds. `0` (the default everywhere) keeps the legacy
    /// unbounded blocking receive; when a non-empty [`FaultPlan`] is
    /// installed and this stays `0`, the plan's own
    /// [`FaultPlan::deadline_ns`] applies. τ-sync receives always park in
    /// deadline-sized rounds but retry without limit — the sync is the
    /// recovery barrier and must complete over the survivors.
    pub recv_deadline_ns: u64,
    /// Extra bounded-retry attempts after the first deadline expires on a
    /// group-phase receive, each waiting `deadline · 2^attempt`
    /// (exponential backoff). `0` means a single attempt. When the whole
    /// budget expires the peer is marked suspect and the phase completes
    /// as identity.
    pub recv_retries: u32,
}

/// How a collective instance gets triggered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActivationMode {
    /// First arrival activates (solo) — WAGMA's wait-avoiding collectives.
    Solo,
    /// The version leader (`rank = version mod P`) activates once at least
    /// half the ranks have arrived.
    Majority,
}

impl EngineConfig {
    pub fn is_sync_iter(&self, t: u64) -> bool {
        self.tau != 0 && (t + 1) % self.tau == 0
    }

    /// Leader responsible for counting majority arrivals of `version`.
    pub fn majority_leader(&self, version: u64) -> usize {
        (version % self.p as u64) as usize
    }

    /// Arrivals needed before a majority activation fires.
    pub fn quorum(&self) -> usize {
        self.p.div_ceil(2)
    }

    /// Smallest group-collective version ≥ `t`.
    fn next_group_version(&self, mut t: u64) -> u64 {
        while self.is_sync_iter(t) {
            t += 1;
        }
        t
    }

    /// Effective chunk size for an `n`-element payload: honours
    /// `chunk_elems` but caps the chunk count so phase/chunk tags stay
    /// disjoint (see [`chunk_tag`]). Public so error-feedback callers can
    /// model the engine's per-chunk encoding exactly
    /// ([`crate::compress::ErrorFeedback::fold_chunked`]).
    pub fn effective_chunk(&self, n: usize) -> usize {
        if self.chunk_elems == 0 || n <= self.chunk_elems {
            return 0; // unchunked
        }
        self.chunk_elems.max(n.div_ceil(MAX_CHUNKS))
    }
}

/// Upper bound on chunks per butterfly phase (tag-space partitioning).
const MAX_CHUNKS: usize = 1 << 16;

/// Tag for chunk `c` of butterfly phase `r` in version `v`. Unchunked
/// phases use plain `Tag::exchange(v, r)` (`r` < 32), chunked phases live
/// in disjoint high ranges — both sides of an exchange share the engine
/// config, so the schedules agree.
fn chunk_tag(v: u64, r: u32, c: usize) -> Tag {
    debug_assert!(c < MAX_CHUNKS);
    Tag::exchange(v, (r + 1) * (MAX_CHUNKS as u32 * 2) + c as u32)
}

/// The rank's newest model contribution (its own small lock: `publish`
/// never contends with result waits or the engine's result inserts).
struct SendSlot {
    buf: SharedBuf,
    stamp: u64,
}

/// Completed collectives, waited on by the application. This is the only
/// condvar edge left in the engine: the blocking
/// `group_allreduce`/`global_sync` retrieval.
#[derive(Default)]
struct ResultMaps {
    group: HashMap<u64, GroupResult>,
    sync: HashMap<u64, Vec<f32>>,
    engine_done: bool,
}

/// Aggregate staleness counters (cheap accessors for metrics; backed by
/// the log-bucketed staleness histogram).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StalenessStats {
    pub count: u64,
    pub total: u64,
    pub max: u64,
}

impl StalenessStats {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total as f64 / self.count as f64
        }
    }
}

/// The staleness log: the drainable sample buffer and the running
/// log-bucketed histogram live under **one** mutex so a sample is pushed
/// and histogrammed atomically. (They used to be two locks; a
/// `staleness_samples` drain could then slip between a push and its
/// histogram record and observe more drained samples than the aggregates
/// admitted — the accessors were not read-consistent.)
#[derive(Default)]
struct StalenessLog {
    /// Samples since the last `staleness_samples` drain.
    samples: Vec<u64>,
    /// Running aggregates (exact count/sum/max, bucketed quantiles).
    hist: LogHistogram,
}

struct EngineShared {
    slot: Mutex<SendSlot>,
    results: Mutex<ResultMaps>,
    results_cv: Condvar,
    /// Staleness samples + running aggregates, one lock (read-consistent).
    staleness: Mutex<StalenessLog>,
    /// Payload bytes the application-side API memcpy'd (the borrowing
    /// `publish`); merged into [`EngineStats::copied_bytes`] at shutdown.
    app_copied_bytes: AtomicU64,
    /// Per-rank span recorder (app + engine lanes, lock-split).
    trace: Arc<TraceRecorder>,
    /// Live-telemetry registry (None when the run is not instrumented).
    /// Publishing is atomics-only, so it neither copies nor allocates —
    /// the P=1 bit-identity test pins `copied_bytes`/`pool_allocs` equal
    /// with and without a registry installed.
    telemetry: Option<Arc<TelemetryRegistry>>,
}

/// Handle owned by the application thread.
pub struct CollectiveEngine {
    shared: Arc<EngineShared>,
    to_engine: MailboxSender,
    pool: BufferPool,
    rank: usize,
    cfg: EngineConfig,
    join: Option<JoinHandle<EngineStats>>,
}

/// Counters reported by the engine thread at shutdown.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub group_collectives: u64,
    /// Collectives this rank activated (vs. joined passively).
    pub activations_sent: u64,
    /// Collectives executed before the application arrived (stale
    /// contributions).
    pub passive_executions: u64,
    pub global_syncs: u64,
    pub sent_msgs: u64,
    pub sent_bytes: u64,
    /// Payload bytes memcpy'd end to end (engine + application API). The
    /// steady-state group path contributes zero; the τ-ring reassembly and
    /// the borrowing `publish` are the residual copiers.
    pub copied_bytes: u64,
    /// Fresh allocations the endpoint's buffer pool had to make (fixed
    /// after warmup when the application publishes by move).
    pub pool_allocs: u64,
    /// Trace events lost to ring overflow (drop-oldest), both lanes.
    pub dropped_trace_events: u64,
    /// Engine-thread ns blocked in matched receives during group
    /// butterfly phases (wait-for-peer; always counted, traced or not).
    pub wait_group_ns: u64,
    /// Engine-thread ns blocked in matched receives during every-τ syncs.
    pub wait_sync_ns: u64,
    /// Butterfly phases that completed as identity because the peer was
    /// dead or suspect (fault injection / elastic membership).
    pub skipped_phases: u64,
    /// Group collectives in which at least one phase was skipped.
    pub degraded_iters: u64,
    /// Matched data receives that carried a causal wire
    /// [`Stamp`](crate::comm::Stamp) (producing span identity) — the
    /// edges the cross-rank causal DAG is stitched from. On the current transport every matched receive
    /// is stamped, so this doubles as a receive count.
    pub stamped_receives: u64,
}

impl CollectiveEngine {
    /// Spawn the engine thread for `ep`. `init_buf` seeds the send slot
    /// (the initial model, stamp [`STAMP_INITIAL`]). No faults: identical
    /// to [`spawn_with_faults`](Self::spawn_with_faults) with an empty
    /// plan (which takes literally the pre-fault code paths).
    pub fn spawn(ep: Endpoint, cfg: EngineConfig, init_buf: Vec<f32>) -> CollectiveEngine {
        CollectiveEngine::spawn_with_faults(ep, cfg, init_buf, Arc::new(FaultPlan::none()))
    }

    /// Spawn the engine thread with a [`FaultPlan`] installed: the engine
    /// consults the plan for its own fail-stop schedule, derives the
    /// deterministic membership view from it at every version boundary,
    /// and injects link drops/jitter into its group phases.
    pub fn spawn_with_faults(
        ep: Endpoint,
        cfg: EngineConfig,
        init_buf: Vec<f32>,
        faults: Arc<FaultPlan>,
    ) -> CollectiveEngine {
        CollectiveEngine::spawn_instrumented(ep, cfg, init_buf, faults, None)
    }

    /// Spawn with an optional live-telemetry registry installed: the app
    /// API publishes steps/staleness/exposed wait and the engine thread
    /// publishes per-class wait, bytes-on-wire, degraded-mode counters,
    /// and membership verdicts into the rank slots. `None` is bit-wise
    /// the uninstrumented engine.
    pub fn spawn_instrumented(
        ep: Endpoint,
        cfg: EngineConfig,
        init_buf: Vec<f32>,
        faults: Arc<FaultPlan>,
        telemetry: Option<Arc<TelemetryRegistry>>,
    ) -> CollectiveEngine {
        let rank = ep.rank();
        assert_eq!(ep.p(), cfg.p);
        if let Some(t) = &telemetry {
            assert_eq!(t.p(), cfg.p, "telemetry registry sized for a different world");
        }
        let pool = ep.pool().clone();
        let shared = Arc::new(EngineShared {
            slot: Mutex::new(SendSlot {
                buf: Arc::new(pool.adopt(init_buf)),
                stamp: STAMP_INITIAL,
            }),
            results: Mutex::new(ResultMaps::default()),
            results_cv: Condvar::new(),
            staleness: Mutex::new(StalenessLog::default()),
            app_copied_bytes: AtomicU64::new(0),
            trace: Arc::new(TraceRecorder::new(rank as u32, cfg.trace, TRACE_RING_CAPACITY)),
            telemetry,
        });
        let to_engine = ep.self_sender();
        let sh = shared.clone();
        let join = std::thread::Builder::new()
            .name(format!("wagma-engine-{rank}"))
            .spawn(move || engine_main(ep, cfg, sh, faults))
            .expect("spawn engine thread");
        CollectiveEngine { shared, to_engine, pool, rank, cfg, join: Some(join) }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn config(&self) -> EngineConfig {
        self.cfg
    }

    /// Publish this rank's freshest model `w` (iteration stamp `t`) into the
    /// send slot. Called right after the local update, *before*
    /// [`group_allreduce`](Self::group_allreduce) — and also before a global
    /// sync so passive participation in later versions uses the newest
    /// model (paper Fig. 3: "the data in the send buffer of P1 is updated").
    ///
    /// This borrowing form copies `w` into a pooled buffer; prefer
    /// [`publish_owned`](Self::publish_owned) on hot paths.
    pub fn publish(&self, w: &[f32], t: u64) {
        let mut pv = self.pool.take(w.len());
        pv.data_mut().copy_from_slice(w);
        self.shared.app_copied_bytes.fetch_add((w.len() * 4) as u64, Ordering::Relaxed);
        self.publish_shared(Arc::new(pv), t);
    }

    /// Zero-copy publish: the vector moves into the send slot (and, once
    /// superseded, retires into the endpoint's buffer pool).
    pub fn publish_owned(&self, w: Vec<f32>, t: u64) {
        self.publish_shared(Arc::new(self.pool.adopt(w)), t);
    }

    /// Install an already-shared buffer as the contribution for stamp `t`.
    pub fn publish_shared(&self, buf: SharedBuf, t: u64) {
        let t0 = now_ns();
        let bytes = (buf.len() * 4) as u64;
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.buf = buf; // the superseded buffer retires to its home pool
            slot.stamp = t;
        }
        let mut ev = TraceEvent::new(TraceKind::Publish, Lane::App, t0, now_ns() - t0);
        ev.version = t;
        ev.bytes = bytes;
        self.shared.trace.record(ev);
    }

    /// Wait-avoiding group allreduce for iteration `t`. Returns the group
    /// sum and the stamp of this rank's contribution. If the collective has
    /// already run (this rank participated passively with an older buffer),
    /// returns immediately with `contributed_stamp < t`.
    pub fn group_allreduce(&self, t: u64) -> GroupResult {
        debug_assert!(!self.cfg.is_sync_iter(t), "iteration {t} is a sync point");
        let t0 = now_ns();
        // Wake the engine: request active participation.
        self.to_engine.send(Message {
            src: self.rank,
            tag: Tag::exchange(t, 0),
            payload: Payload::AppGroup { version: t },
        });
        let r = {
            let mut g = self.shared.results.lock().unwrap();
            loop {
                if let Some(r) = g.group.remove(&t) {
                    break r;
                }
                assert!(!g.engine_done, "engine terminated with pending collective {t}");
                g = self.shared.results_cv.wait(g).unwrap();
            }
        };
        // The request→result window is the rank's exposed communication.
        let wait_ns = now_ns() - t0;
        let mut ev = TraceEvent::new(TraceKind::Wait, Lane::App, t0, wait_ns);
        ev.version = t;
        self.shared.trace.record(ev);
        let s = r.staleness(t);
        if let Some(tel) = &self.shared.telemetry {
            let slot = tel.rank(self.rank);
            slot.add_step();
            slot.add_wait_app_ns(wait_ns);
            slot.add_staleness(s);
        }
        // Single lock: the sample and its histogram entry land atomically,
        // so a concurrent `staleness_samples` drain can never observe one
        // without the other.
        let mut log = self.shared.staleness.lock().unwrap();
        log.samples.push(s);
        log.hist.record(s);
        drop(log);
        r
    }

    /// Global synchronous allreduce for iteration `t` (Alg. 2 line 16).
    /// `w` must already be published. Returns the global sum over all P.
    pub fn global_sync(&self, t: u64) -> Vec<f32> {
        let t0 = now_ns();
        self.to_engine.send(Message {
            src: self.rank,
            tag: Tag::sync(t, 0),
            payload: Payload::AppSync { version: t },
        });
        let r = {
            let mut g = self.shared.results.lock().unwrap();
            loop {
                if let Some(r) = g.sync.remove(&t) {
                    break r;
                }
                assert!(!g.engine_done, "engine terminated with pending sync {t}");
                g = self.shared.results_cv.wait(g).unwrap();
            }
        };
        let wait_ns = now_ns() - t0;
        let mut ev = TraceEvent::new(TraceKind::Wait, Lane::App, t0, wait_ns);
        ev.version = t;
        self.shared.trace.record(ev);
        if let Some(tel) = &self.shared.telemetry {
            let slot = tel.rank(self.rank);
            slot.add_step();
            slot.add_wait_app_ns(wait_ns);
        }
        r
    }

    /// Staleness samples observed since the previous call (a cheap
    /// buffer swap — nothing is cloned under the lock). Use
    /// [`staleness_stats`](Self::staleness_stats) for running aggregates.
    pub fn staleness_samples(&self) -> Vec<u64> {
        std::mem::take(&mut self.shared.staleness.lock().unwrap().samples)
    }

    /// Running staleness aggregates (count / total / max), read off the
    /// log-bucketed histogram's exact counters. Consistent with
    /// [`staleness_samples`](Self::staleness_samples): both live under one
    /// lock, so `stats().count` is always ≥ the number of samples drained
    /// so far, and exactly equal once publishing has quiesced.
    pub fn staleness_stats(&self) -> StalenessStats {
        let log = self.shared.staleness.lock().unwrap();
        StalenessStats { count: log.hist.count(), total: log.hist.sum(), max: log.hist.max() }
    }

    /// The full staleness distribution (log-bucketed; exact
    /// count/sum/min/max, quantiles to bucket resolution).
    pub fn staleness_histogram(&self) -> LogHistogram {
        self.shared.staleness.lock().unwrap().hist.clone()
    }

    /// Handle to this rank's span recorder. Clone-cheap (`Arc`); keep one
    /// around to [`TraceRecorder::drain`] events after
    /// [`shutdown`](Self::shutdown) has consumed the engine.
    pub fn tracer(&self) -> Arc<TraceRecorder> {
        self.shared.trace.clone()
    }

    /// Drain all trace events recorded so far (both lanes, time-sorted).
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        self.shared.trace.drain()
    }

    /// The endpoint buffer pool's counters (test/bench hook).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Shut the engine down and collect its statistics.
    pub fn shutdown(mut self) -> EngineStats {
        self.to_engine.send(Message {
            src: self.rank,
            tag: Tag::exchange(0, 0),
            payload: Payload::Quit,
        });
        self.join.take().unwrap().join().expect("engine thread panicked")
    }
}

impl Drop for CollectiveEngine {
    fn drop(&mut self) {
        if let Some(j) = self.join.take() {
            self.to_engine.send(Message {
                src: self.rank,
                tag: Tag::exchange(0, 0),
                payload: Payload::Quit,
            });
            let _ = j.join();
        }
    }
}

/// State carried through the engine main loop.
struct EngineRun {
    cfg: EngineConfig,
    grouping: Grouping,
    tree: BinomialTree,
    shared: Arc<EngineShared>,
    pool: BufferPool,
    /// Versions for which an activation has been seen (not yet executed).
    activated: BTreeSet<u64>,
    /// Next group version this engine will execute.
    next: u64,
    /// Pending own-application request (active participation).
    app_group: Option<u64>,
    app_sync: Option<u64>,
    /// Majority mode: arrival counts per version (leader only).
    arrivals: HashMap<u64, usize>,
    /// Encoder workspace (top-k index selection), reused across phases so
    /// steady-state compressed exchanges allocate nothing.
    scratch: EncodeScratch,
    quit: bool,
    stats: EngineStats,
    /// Blocked-receive ns accumulated by `recv_with_ctrl` since the last
    /// reset — read out per phase/sync to emit nested `Wait` spans.
    phase_wait_ns: u64,
    /// Causal cause of the largest single blocked receive since the last
    /// span reset (from the wire stamp; `NO_PEER` if nothing blocked).
    /// Pins the `peer` of the nested `Wait` sub-span — and of the τ-sync
    /// span, whose schedule has no single partner.
    phase_blocked_peer: u32,
    /// Duration of that largest single blocked receive.
    phase_blocked_max_ns: u64,
    /// Codec encode ns accumulated by the compressed exchange paths.
    phase_encode_ns: u64,
    /// Codec decode/decompress-sum ns, likewise.
    phase_decode_ns: u64,
    /// The installed fault schedule (empty for `spawn`).
    faults: Arc<FaultPlan>,
    /// This rank's view of every peer's health. Deterministically refreshed
    /// from the plan at each version boundary, locally downgraded to
    /// `Suspect` on exchange deadline expiry, healed at sync completion.
    membership: Membership,
    /// This rank has fail-stopped per the plan: death notice sent, all
    /// pending work dropped, only control traffic (waiting for Quit) left.
    crashed: bool,
    /// Set by `recv_exchange` when the bounded receive gave up on a
    /// partner; consumed per phase by `execute_group`.
    phase_skipped: bool,
    /// Live-telemetry registry (clone of the shared handle, kept here so
    /// the hot paths skip the `shared` indirection).
    telemetry: Option<Arc<TelemetryRegistry>>,
}

impl EngineRun {
    /// Publish blocked-receive time into the *waited-on* rank's slot:
    /// the fleet's wait-for-peer distribution accumulates on the rank
    /// being waited for, which is what the straggler detector thresholds.
    /// The waiter's own slot records who it blamed (per-peer histogram,
    /// surfaced as the `wagma top` blames column).
    fn telemetry_wait_for(&self, partner: usize, ns: u64) {
        if let Some(t) = &self.telemetry {
            t.rank(partner).record_wait_for_ns(ns);
            t.rank(self.shared.trace.rank() as usize).record_blame_ns(partner, ns);
        }
    }

    /// Track the largest single blocked receive since the last span reset
    /// so the enclosing span's wait sub-span can name its causal peer.
    fn note_blocked(&mut self, peer: u32, waited_ns: u64) {
        if waited_ns > self.phase_blocked_max_ns {
            self.phase_blocked_max_ns = waited_ns;
            self.phase_blocked_peer = peer;
        }
    }

    /// Mirror a deterministic membership view into the registry. Healthy
    /// is *not* pushed from here — a plan view saying healthy must not
    /// clear a locally-observed suspect verdict; heals flow from
    /// successful receives and sync completion.
    fn telemetry_membership(&self, membership: &Membership, p: usize) {
        if let Some(t) = &self.telemetry {
            for r in 0..p {
                match membership.state(r) {
                    PeerState::Dead => t.rank(r).mark_dead(),
                    PeerState::Suspect => t.rank(r).mark_suspect(),
                    PeerState::Healthy => {}
                }
            }
        }
    }
}

/// Majority-mode arrival bookkeeping at the version leader: activate once
/// the quorum is reached (paper §VI's majority collectives).
fn note_arrival(ep: &mut Endpoint, run: &mut EngineRun, version: u64) {
    if version < run.next {
        return;
    }
    let count = run.arrivals.entry(version).or_insert(0);
    *count += 1;
    if *count >= run.cfg.quorum() && !run.activated.contains(&version) {
        run.activated.insert(version);
        run.arrivals.remove(&version);
        run.stats.activations_sent += 1;
        forward_activation(ep, run, ep.rank(), version);
    }
}

/// Route an own-application group request according to the activation mode.
fn app_group_request(ep: &mut Endpoint, run: &mut EngineRun, version: u64) {
    if version < run.next {
        return; // benign race: already executed passively
    }
    match run.cfg.activation {
        ActivationMode::Solo => run.app_group = Some(version),
        ActivationMode::Majority => {
            let leader = run.cfg.majority_leader(version);
            if leader == ep.rank() {
                note_arrival(ep, run, version);
            } else {
                ep.send_ctrl(leader, Payload::Arrival { version });
            }
        }
    }
}

fn engine_main(
    mut ep: Endpoint,
    cfg: EngineConfig,
    shared: Arc<EngineShared>,
    faults: Arc<FaultPlan>,
) -> EngineStats {
    let pool = ep.pool().clone();
    let membership = Membership::new(cfg.p);
    let telemetry = shared.telemetry.clone();
    let mut run = EngineRun {
        cfg,
        grouping: if cfg.dynamic_groups {
            Grouping::new(cfg.p, cfg.group_size)
        } else {
            Grouping::fixed(cfg.p, cfg.group_size)
        },
        tree: BinomialTree::new(cfg.p),
        shared,
        pool,
        activated: BTreeSet::new(),
        next: cfg.next_group_version(0),
        app_group: None,
        app_sync: None,
        arrivals: HashMap::new(),
        scratch: EncodeScratch::default(),
        quit: false,
        stats: EngineStats::default(),
        phase_wait_ns: 0,
        phase_blocked_peer: crate::trace::NO_PEER,
        phase_blocked_max_ns: 0,
        phase_encode_ns: 0,
        phase_decode_ns: 0,
        faults,
        membership,
        crashed: false,
        phase_skipped: false,
        telemetry,
    };

    loop {
        // Execute all work that is ready, in version order.
        loop {
            let want_active = run.app_group == Some(run.next);
            let want_passive = run.activated.contains(&run.next);
            // Fail-stop check at the version boundary: once the plan says
            // this rank is dead, it must not execute (even passively) —
            // a crashed rank silently joining a butterfly would hang its
            // partners' bounded receives for nothing. Announce instead.
            if !run.crashed {
                if let Some(ci) = run.faults.crash_iter(ep.rank()) {
                    let group_due = (want_active || want_passive) && run.next >= ci;
                    let sync_due = run.app_sync.is_some_and(|ts| ts >= ci);
                    if group_due || sync_due {
                        crash_self(&mut ep, &mut run);
                    }
                }
            }
            if run.crashed {
                // Drop all pending work; stay responsive to ctrl (Quit).
                run.app_group = None;
                run.app_sync = None;
                run.activated.clear();
                break;
            }
            if want_active || want_passive {
                execute_group(&mut ep, &mut run, want_active && !want_passive);
            } else if let Some(ts) = run.app_sync.take() {
                execute_sync(&mut ep, &mut run, ts);
            } else {
                break;
            }
        }
        if run.quit {
            break;
        }
        // Idle: only control traffic can unblock us; data for future
        // versions waits in its sender's lane until the matching schedule
        // runs.
        let msg = ep.recv_ctrl();
        handle_ctrl(&mut ep, &mut run, msg);
    }

    run.stats.sent_msgs = ep.sent_msgs;
    run.stats.sent_bytes = ep.sent_bytes;
    run.stats.copied_bytes =
        ep.copied_bytes + run.shared.app_copied_bytes.load(Ordering::Relaxed);
    run.stats.pool_allocs = run.pool.stats().allocs;
    run.stats.dropped_trace_events = run.shared.trace.dropped();
    if let Some(t) = &run.telemetry {
        t.add_dropped_trace_events(run.stats.dropped_trace_events);
    }
    let mut g = run.shared.results.lock().unwrap();
    g.engine_done = true;
    drop(g);
    run.shared.results_cv.notify_all();
    run.stats
}

/// Process a control message — from the idle loop or from inside a blocked
/// matched receive. Activations are forwarded and recorded; app requests
/// are routed; Quit is deferred until the current schedule completes (the
/// partner still needs our traffic).
fn handle_ctrl(ep: &mut Endpoint, run: &mut EngineRun, msg: Message) {
    match msg.payload {
        Payload::Activation { root, version } => {
            // Version check (paper §III-A1): only react to versions not yet
            // executed; forward down OUR subtree of the activator's tree
            // exactly once.
            if version >= run.next && run.activated.insert(version) {
                forward_activation(ep, run, root, version);
            }
        }
        Payload::AppGroup { version } => {
            // A request for an already-executed version is a benign race:
            // the engine ran it passively first; the app will find the
            // result in the map.
            app_group_request(ep, run, version);
        }
        Payload::Arrival { version } => {
            note_arrival(ep, run, version);
        }
        Payload::AppSync { version } => {
            run.app_sync = Some(version);
        }
        Payload::Dead { rank } => {
            run.membership.mark_dead(rank);
            if let Some(t) = &run.telemetry {
                t.rank(rank).mark_dead();
            }
        }
        Payload::Quit => {
            run.quit = true;
        }
    }
}

/// Fail-stop this rank per its fault plan: broadcast the death notice once
/// so peers need not burn a full deadline discovering us, then go silent.
fn crash_self(ep: &mut Endpoint, run: &mut EngineRun) {
    run.crashed = true;
    let me = ep.rank();
    if let Some(t) = &run.telemetry {
        t.rank(me).mark_dead();
    }
    for peer in 0..run.cfg.p {
        if peer != me {
            ep.send_ctrl(peer, Payload::Dead { rank: me });
        }
    }
    if run.shared.trace.is_enabled() {
        let now = now_ns();
        let mut ev = TraceEvent::new(TraceKind::Fault, Lane::Engine, now, 0);
        ev.version = run.next;
        run.shared.trace.record(ev);
    }
}

/// Forward an activation down our subtree, routing around dead children:
/// a dead child's own children are adopted so the broadcast still reaches
/// every live rank.
fn forward_activation(ep: &mut Endpoint, run: &EngineRun, root: usize, version: u64) {
    let mut stack = run.tree.children(root, ep.rank());
    while let Some(child) = stack.pop() {
        if run.membership.is_dead(child) {
            stack.extend(run.tree.children(root, child));
        } else {
            ep.send_ctrl(child, Payload::Activation { root, version });
        }
    }
}

/// Effective deadline for group-phase receives: explicit config wins;
/// otherwise a non-empty fault plan supplies its detection deadline; with
/// neither, `0` selects the literal pre-fault unbounded path (bit-identical
/// behavior and counters for fault-free runs).
fn group_deadline_ns(run: &EngineRun) -> u64 {
    if run.cfg.recv_deadline_ns > 0 {
        run.cfg.recv_deadline_ns
    } else if !run.faults.is_empty() {
        run.faults.deadline_ns()
    } else {
        0
    }
}

/// Group-phase receive: unbounded (ctrl-aware) when no deadline is
/// configured, otherwise bounded with exponential backoff across
/// `cfg.recv_retries` extra attempts. Giving up marks the partner
/// `Suspect` and sets `run.phase_skipped` so the caller completes the
/// phase as identity; a successful receive heals a suspected partner.
fn recv_exchange(ep: &mut Endpoint, run: &mut EngineRun, partner: usize, tag: Tag) -> Option<Chunk> {
    let deadline = group_deadline_ns(run);
    if deadline == 0 {
        return Some(recv_with_ctrl(ep, run, partner, tag));
    }
    let w0 = now_ns();
    let mut attempt: u32 = 0;
    let data = 'attempts: loop {
        if run.membership.is_down(partner) {
            // Known-down partner (death notice, or an earlier chunk of
            // this phase already timed out): don't burn another deadline.
            break None;
        }
        let wait_ns = deadline.saturating_mul(1u64 << attempt.min(20));
        let until = Instant::now() + Duration::from_nanos(wait_ns);
        loop {
            let mut ctrl: Vec<Message> = Vec::new();
            match ep.recv_data_or_ctrl_deadline(partner, tag, until, &mut ctrl) {
                Ok(Some(data)) => {
                    for m in ctrl {
                        handle_ctrl(ep, run, m);
                    }
                    break 'attempts Some(data);
                }
                Ok(None) => {
                    for m in ctrl {
                        handle_ctrl(ep, run, m);
                    }
                    if run.membership.is_dead(partner) {
                        break 'attempts None;
                    }
                }
                Err(_) => {
                    if attempt >= run.cfg.recv_retries {
                        break 'attempts None;
                    }
                    attempt += 1;
                    continue 'attempts;
                }
            }
        }
    };
    let waited = now_ns() - w0;
    run.phase_wait_ns += waited;
    run.telemetry_wait_for(partner, waited);
    if ep.take_stamp().is_some() {
        run.stats.stamped_receives += 1;
    }
    run.note_blocked(partner as u32, waited);
    match &data {
        Some(_) => {
            run.membership.heal(partner);
            if let Some(t) = &run.telemetry {
                t.rank(partner).heal();
            }
        }
        None => {
            run.membership.mark_suspect(partner);
            if let Some(t) = &run.telemetry {
                t.rank(partner).mark_suspect();
            }
            run.phase_skipped = true;
        }
    }
    data
}

/// One unchunked butterfly phase: refcount send, ctrl-aware receive, fused
/// reduce ([`reduce_shared`] — in place when the partner already released
/// our buffer, else one pooled `sum_into` pass). `dropped` simulates the
/// outbound link losing our contribution (the send is suppressed); a
/// receive that gives up completes the phase as identity.
fn exchange_reduce(
    ep: &mut Endpoint,
    run: &mut EngineRun,
    partner: usize,
    tag: Tag,
    acc: SharedBuf,
    dropped: bool,
) -> SharedBuf {
    if !dropped {
        ep.send_chunk(partner, tag, Chunk::full(acc.clone()));
    }
    match recv_exchange(ep, run, partner, tag) {
        Some(rhs) => reduce_shared(&run.pool, acc, rhs.as_slice()),
        None => acc,
    }
}

/// One chunked butterfly phase: all sends are issued up front as range
/// views so the partner can overlap its reductions with our remaining
/// traffic; receives reduce range-by-range into one pooled output.
#[allow(clippy::too_many_arguments)]
fn exchange_reduce_chunked(
    ep: &mut Endpoint,
    run: &mut EngineRun,
    partner: usize,
    v: u64,
    r: u32,
    chunk: usize,
    acc: SharedBuf,
    dropped: bool,
) -> SharedBuf {
    let n = acc.len();
    let n_chunks = n.div_ceil(chunk);
    if !dropped {
        for c in 0..n_chunks {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            ep.send_chunk(partner, chunk_tag(v, r, c), Chunk::range(acc.clone(), lo, hi));
        }
    }
    let mut out = run.pool.take(n);
    for c in 0..n_chunks {
        let lo = c * chunk;
        let hi = (lo + chunk).min(n);
        match recv_exchange(ep, run, partner, chunk_tag(v, r, c)) {
            Some(rhs) => {
                sum_into(&mut out.data_mut()[lo..hi], &acc.as_slice()[lo..hi], rhs.as_slice());
            }
            // Any chunk timing out degrades the whole phase to identity:
            // a half-reduced accumulator is neither our model nor a sum.
            None => return acc,
        }
    }
    Arc::new(out)
}

/// One compressed unchunked butterfly phase: encode the accumulator into a
/// pooled buffer, send the (shorter) encoding, and fold the partner's
/// encoding in via the fused decompress-sum ([`decode_sum_shared`] — in
/// place when the partner already released our buffer). `sent_bytes`
/// therefore counts bytes-on-wire, not raw payload bytes.
fn exchange_reduce_compressed(
    ep: &mut Endpoint,
    run: &mut EngineRun,
    partner: usize,
    tag: Tag,
    acc: SharedBuf,
    dropped: bool,
) -> SharedBuf {
    let comp = run.cfg.compression;
    if !dropped {
        let mut enc = run.pool.take(comp.encoded_words(acc.len()));
        let e0 = now_ns();
        comp.encode(acc.as_slice(), enc.data_mut(), &mut run.scratch);
        run.phase_encode_ns += now_ns() - e0;
        ep.send_chunk(partner, tag, Chunk::full(Arc::new(enc)));
    }
    match recv_exchange(ep, run, partner, tag) {
        Some(rhs) => {
            let d0 = now_ns();
            let out = decode_sum_shared(&run.pool, comp, acc, rhs.as_slice());
            run.phase_decode_ns += now_ns() - d0;
            out
        }
        None => acc,
    }
}

/// One compressed chunked butterfly phase: each chunk — the engine-level
/// image of a fused gradient bucket — is encoded and sent independently
/// (per-bucket compression), then the receives fold into one pooled output
/// range by range.
#[allow(clippy::too_many_arguments)]
fn exchange_reduce_chunked_compressed(
    ep: &mut Endpoint,
    run: &mut EngineRun,
    partner: usize,
    v: u64,
    r: u32,
    chunk: usize,
    acc: SharedBuf,
    dropped: bool,
) -> SharedBuf {
    let comp = run.cfg.compression;
    let n = acc.len();
    let n_chunks = n.div_ceil(chunk);
    if !dropped {
        for c in 0..n_chunks {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut enc = run.pool.take(comp.encoded_words(hi - lo));
            let e0 = now_ns();
            comp.encode(&acc.as_slice()[lo..hi], enc.data_mut(), &mut run.scratch);
            run.phase_encode_ns += now_ns() - e0;
            ep.send_chunk(partner, chunk_tag(v, r, c), Chunk::full(Arc::new(enc)));
        }
    }
    let mut out = run.pool.take(n);
    out.data_mut().copy_from_slice(acc.as_slice());
    for c in 0..n_chunks {
        let lo = c * chunk;
        let hi = (lo + chunk).min(n);
        match recv_exchange(ep, run, partner, chunk_tag(v, r, c)) {
            Some(rhs) => {
                let d0 = now_ns();
                comp.decode_add(rhs.as_slice(), &mut out.data_mut()[lo..hi]);
                run.phase_decode_ns += now_ns() - d0;
            }
            None => return acc,
        }
    }
    Arc::new(out)
}

/// Emit the span for one completed exchange phase / sync, plus nested
/// `Wait`/`Encode`/`Decode` sub-spans aggregated from the accumulators
/// (anchored at the span start, so nesting invariants hold by
/// construction), and fold the blocked time into the per-phase stats.
#[allow(clippy::too_many_arguments)]
fn record_engine_span(
    run: &mut EngineRun,
    kind: TraceKind,
    v: u64,
    phase: u32,
    t0: u64,
    end: u64,
    wire_bytes: u64,
    passive: bool,
    peer: u32,
) {
    match kind {
        TraceKind::TauSync => run.stats.wait_sync_ns += run.phase_wait_ns,
        _ => run.stats.wait_group_ns += run.phase_wait_ns,
    }
    if let Some(t) = &run.telemetry {
        let slot = t.rank(run.shared.trace.rank() as usize);
        match kind {
            TraceKind::TauSync => slot.add_wait_sync_ns(run.phase_wait_ns),
            _ => slot.add_wait_group_ns(run.phase_wait_ns),
        }
        slot.add_wire_bytes(wire_bytes);
    }
    if run.shared.trace.is_enabled() {
        // The span's causal peer: the schedule partner for butterfly
        // phases; for τ-syncs (no single partner) the wire-stamped cause
        // of the window's largest blocked receive.
        let span_peer =
            if peer != crate::trace::NO_PEER { peer } else { run.phase_blocked_peer };
        let mut ev = TraceEvent::new(kind, Lane::Engine, t0, end - t0);
        ev.version = v;
        ev.phase = phase;
        ev.bytes = wire_bytes;
        ev.passive = passive;
        ev.peer = span_peer;
        run.shared.trace.record(ev);
        for (sub, dur) in [
            (TraceKind::Wait, run.phase_wait_ns),
            (TraceKind::Encode, run.phase_encode_ns),
            (TraceKind::Decode, run.phase_decode_ns),
        ] {
            if dur > 0 {
                let mut ev = TraceEvent::new(sub, Lane::Engine, t0, dur.min(end - t0));
                ev.version = v;
                ev.phase = phase;
                ev.passive = passive;
                if sub == TraceKind::Wait {
                    ev.peer = run.phase_blocked_peer;
                }
                run.shared.trace.record(ev);
            }
        }
    }
    run.phase_wait_ns = 0;
    run.phase_blocked_peer = crate::trace::NO_PEER;
    run.phase_blocked_max_ns = 0;
    run.phase_encode_ns = 0;
    run.phase_decode_ns = 0;
}

/// Execute the group allreduce schedule for `run.next`.
///
/// Degraded paths: the deterministic membership view is refreshed from the
/// fault plan at the version boundary; phases whose partner is `Dead` or
/// `Suspect` (or whose bounded receive gives up) complete as **identity** —
/// the accumulator passes through unchanged, counted in `skipped_phases`,
/// and the iteration is counted once in `degraded_iters`.
fn execute_group(ep: &mut Endpoint, run: &mut EngineRun, initiate: bool) {
    let v = run.next;
    run.membership.apply_plan(&run.faults, v);
    run.telemetry_membership(&run.membership, run.cfg.p);
    // NOTE: v stays in `activated` until the schedule completes so that
    // quorum bookkeeping (majority mode) does not re-activate a version
    // that is mid-execution; both sets are cleared below.
    let passive = run.app_group != Some(v);
    if run.app_group == Some(v) {
        run.app_group = None;
    } else {
        run.stats.passive_executions += 1;
    }

    if initiate {
        // We are (an) activator: broadcast down the tree rooted at us.
        run.stats.activations_sent += 1;
        forward_activation(ep, run, ep.rank(), v);
    }

    // Snapshot the send slot (refcount bump — no copy) as our contribution.
    let (mut acc, stamp): (SharedBuf, u64) = {
        let slot = run.shared.slot.lock().unwrap();
        (slot.buf.clone(), slot.stamp)
    };

    // Butterfly phases within the (dynamic) group. With chunking enabled
    // (layered/fused mode) each phase streams the payload as independent
    // range views: all sends are issued up front so the partner can overlap
    // its reductions with our remaining traffic.
    let chunk = run.cfg.effective_chunk(acc.len());
    let compressed = !run.cfg.compression.is_none();
    let deadline = group_deadline_ns(run);
    let mut skipped_iter = false;
    for r in 0..run.grouping.phases() {
        let partner = run.grouping.partner(ep.rank(), v, r);
        let wire0 = ep.sent_bytes;
        let t0 = now_ns();
        if run.membership.is_down(partner) {
            // Degraded phase: the down peer contributes identity. No
            // traffic is posted at all — a dead partner never drains it
            // and a suspect one gets healed via the sync path, not here.
            run.stats.skipped_phases += 1;
            skipped_iter = true;
            if let Some(t) = &run.telemetry {
                t.rank(ep.rank()).add_skipped_phases(1);
            }
            if run.shared.trace.is_enabled() {
                let mut ev = TraceEvent::new(TraceKind::Fault, Lane::Engine, t0, now_ns() - t0);
                ev.version = v;
                ev.phase = r;
                ev.passive = passive;
                ev.peer = partner as u32;
                run.shared.trace.record(ev);
            }
            continue;
        }
        // Inbound-link jitter: injected as real engine-thread latency so
        // it shows up in partners' wait attribution like a slow link would.
        let jitter = run.faults.jitter_s(partner, ep.rank(), v);
        if jitter > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(jitter));
        }
        // Outbound-link drop: only injected when a deadline bounds the
        // partner's receive, so a lost contribution degrades the partner's
        // phase instead of deadlocking it.
        let dropped = deadline > 0 && run.faults.drop_link(ep.rank(), partner, v, r);
        acc = match (chunk, compressed) {
            (0, false) => exchange_reduce(ep, run, partner, Tag::exchange(v, r), acc, dropped),
            (0, true) => {
                exchange_reduce_compressed(ep, run, partner, Tag::exchange(v, r), acc, dropped)
            }
            (_, false) => exchange_reduce_chunked(ep, run, partner, v, r, chunk, acc, dropped),
            (_, true) => {
                exchange_reduce_chunked_compressed(ep, run, partner, v, r, chunk, acc, dropped)
            }
        };
        let end = now_ns();
        if std::mem::take(&mut run.phase_skipped) {
            run.stats.skipped_phases += 1;
            skipped_iter = true;
            if let Some(t) = &run.telemetry {
                t.rank(ep.rank()).add_skipped_phases(1);
            }
            if run.shared.trace.is_enabled() {
                let mut ev = TraceEvent::new(TraceKind::Fault, Lane::Engine, t0, end - t0);
                ev.version = v;
                ev.phase = r;
                ev.passive = passive;
                ev.peer = partner as u32;
                run.shared.trace.record(ev);
            }
        }
        record_engine_span(
            run,
            TraceKind::GroupExchangePhase,
            v,
            r,
            t0,
            end,
            ep.sent_bytes - wire0,
            passive,
            partner as u32,
        );
    }
    if skipped_iter {
        run.stats.degraded_iters += 1;
        if let Some(t) = &run.telemetry {
            t.rank(ep.rank()).add_degraded_iter();
        }
    }

    run.stats.group_collectives += 1;
    run.activated.remove(&v);
    run.arrivals.remove(&v);
    run.next = run.cfg.next_group_version(v + 1);

    let sum = shared_into_vec(acc, &mut ep.copied_bytes);
    let mut g = run.shared.results.lock().unwrap();
    g.group.insert(v, GroupResult { sum, contributed_stamp: stamp });
    drop(g);
    run.shared.results_cv.notify_all();
}

/// Execute the every-τ global allreduce for iteration `ts`.
///
/// Uses the *ctrl-aware* receive throughout: late or duplicate activation
/// messages from co-activators of previous group versions can still be in
/// flight and must be forwarded/ignored, not treated as protocol errors.
/// Algorithm choice mirrors [`crate::collectives::allreduce`]: a
/// bandwidth-optimal ring for model-sized payloads, recursive doubling for
/// tiny ones (perf pass; EXPERIMENTS.md §Perf).
fn execute_sync(ep: &mut Endpoint, run: &mut EngineRun, ts: u64) {
    run.membership.apply_plan(&run.faults, ts);
    run.telemetry_membership(&run.membership, run.cfg.p);
    let contrib: SharedBuf = run.shared.slot.lock().unwrap().buf.clone();
    let survivors = run.membership.survivors();
    let k = survivors.len();
    let wire0 = ep.sent_bytes;
    let t0 = now_ns();
    // Survivor-only schedules. All survivors compute the same `survivors`
    // list from the same plan at the same version, so they pick the same
    // branch and the same peer ordering — which is what makes the synced
    // model bit-identical across survivors even after failures. The sync
    // *never* skips: receives here are the unbounded ctrl-aware kind
    // (fail-stop is deterministic, so every awaited peer is live).
    let result: Vec<f32> = if k <= 1 {
        ep.copied_bytes += (contrib.len() * 4) as u64;
        contrib.as_slice().to_vec()
    } else if k > 2 && contrib.len() >= RING_THRESHOLD {
        if run.cfg.compression.is_none() {
            ring_sync(ep, run, ts, contrib, &survivors)
        } else {
            ring_sync_compressed(ep, run, ts, contrib, &survivors)
        }
    } else if k.is_power_of_two() {
        let idx = survivors
            .iter()
            .position(|&m| m == ep.rank())
            .expect("sync caller must be a survivor");
        let mut acc = contrib;
        for kb in 0..log2_exact(k) {
            let partner = survivors[idx ^ (1usize << kb)];
            ep.send_chunk(partner, Tag::sync(ts, kb), Chunk::full(acc.clone()));
            let rhs = recv_with_ctrl(ep, run, partner, Tag::sync(ts, kb));
            acc = reduce_shared(&run.pool, acc, rhs.as_slice());
        }
        shared_into_vec(acc, &mut ep.copied_bytes)
    } else {
        // Small payload, non-power-of-two survivor count: gather at the
        // lowest survivor, which sums in member order and broadcasts the
        // bytes — trivially rank-identical.
        star_sync(ep, run, ts, contrib, &survivors)
    };
    // Sync completion proves liveness of every survivor: any `Suspect`
    // verdicts accumulated from group-phase deadlines this τ window were
    // transient — clear them so degradation stays bounded to the window.
    run.membership.heal_all();
    if let Some(t) = &run.telemetry {
        for r in 0..run.cfg.p {
            t.rank(r).heal();
        }
    }
    let end = now_ns();
    record_engine_span(
        run,
        TraceKind::TauSync,
        ts,
        crate::trace::NO_PHASE,
        t0,
        end,
        ep.sent_bytes - wire0,
        false,
        crate::trace::NO_PEER,
    );
    run.stats.global_syncs += 1;
    // The sync is a barrier: every rank has executed all group versions
    // below ts, so the engine's next pointer can jump past it.
    run.next = run.cfg.next_group_version(run.next.max(ts + 1));
    let mut g = run.shared.results.lock().unwrap();
    g.sync.insert(ts, result);
    drop(g);
    run.shared.results_cv.notify_all();
}

/// Segmented zero-copy ring allreduce for the global sync: the shared
/// [`ring_allreduce_segments`] core driven with the *ctrl-aware* receive,
/// so activation traffic keeps flowing during the barrier. Segment sums
/// come from the endpoint's pool and allgather segments are adopted by
/// reference; the final reassembly is the sync path's single counted copy.
fn ring_sync(
    ep: &mut Endpoint,
    run: &mut EngineRun,
    ts: u64,
    contrib: SharedBuf,
    members: &[usize],
) -> Vec<f32> {
    ring_allreduce_segments_over(ep, ts, contrib, members, |ep, src, tag| {
        recv_with_ctrl(ep, run, src, tag)
    })
}

/// Degraded-sync fallback for payloads below the ring threshold when the
/// survivor count is not a power of two: gather at `members[0]`, reduce in
/// member order, broadcast the result bytes. O(k) messages — fine for the
/// small payloads this branch is reserved for.
fn star_sync(
    ep: &mut Endpoint,
    run: &mut EngineRun,
    ts: u64,
    contrib: SharedBuf,
    members: &[usize],
) -> Vec<f32> {
    let root = members[0];
    if ep.rank() == root {
        let mut acc = contrib;
        for &m in &members[1..] {
            let rhs = recv_with_ctrl(ep, run, m, Tag::sync(ts, 0));
            acc = reduce_shared(&run.pool, acc, rhs.as_slice());
        }
        for &m in &members[1..] {
            ep.send_chunk(m, Tag::sync(ts, 1), Chunk::full(acc.clone()));
        }
        shared_into_vec(acc, &mut ep.copied_bytes)
    } else {
        ep.send_chunk(root, Tag::sync(ts, 0), Chunk::full(contrib));
        let res = recv_with_ctrl(ep, run, root, Tag::sync(ts, 1));
        ep.copied_bytes += (res.as_slice().len() * 4) as u64;
        res.as_slice().to_vec()
    }
}

/// Compressed τ-sync: the compressed ring core with the ctrl-aware
/// receive. The allgather distributes one encoding per segment that every
/// rank (owner included) decodes, so the synced model stays identical on
/// all ranks — lossy, but rank-agreeing, which is the property the
/// every-τ barrier exists to restore. Small payloads never reach here
/// (the caller keeps them on the exact recursive-doubling path:
/// latency-bound traffic gains nothing from compression).
fn ring_sync_compressed(
    ep: &mut Endpoint,
    run: &mut EngineRun,
    ts: u64,
    contrib: SharedBuf,
    members: &[usize],
) -> Vec<f32> {
    let comp = run.cfg.compression;
    // The scratch moves out of `run` for the duration of the call: the
    // receive closure needs `run` mutably for activation forwarding.
    let mut scratch = std::mem::take(&mut run.scratch);
    let out = ring_allreduce_segments_compressed_over(
        ep,
        ts,
        contrib,
        comp,
        &mut scratch,
        members,
        |ep, src, tag| recv_with_ctrl(ep, run, src, tag),
    );
    run.scratch = scratch;
    out
}

/// Matched receive that keeps servicing control traffic (activation
/// forwarding must not stall while we wait for a butterfly partner).
fn recv_with_ctrl(ep: &mut Endpoint, run: &mut EngineRun, src: usize, tag: Tag) -> Chunk {
    // We cannot borrow `run` inside the closure while also using it after,
    // so collect control messages and process them after each wait.
    let w0 = now_ns();
    let data = loop {
        let mut ctrl: Vec<Message> = Vec::new();
        let got = ep.recv_data_or_ctrl(src, tag, &mut ctrl);
        for m in ctrl {
            handle_ctrl(ep, run, m);
        }
        if let Some(data) = got {
            break data;
        }
    };
    let waited = now_ns() - w0;
    run.phase_wait_ns += waited;
    run.telemetry_wait_for(src, waited);
    // The wire stamp names the producing span; it is the causal identity
    // the receive's wait inherits (src is the fallback — same rank, no
    // producing-span time).
    let cause = match ep.take_stamp() {
        Some(st) => {
            run.stats.stamped_receives += 1;
            st.src
        }
        None => src as u32,
    };
    run.note_blocked(cause, waited);
    data
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::comm::world;
    use crate::util::add_assign;
    use std::thread;
    use std::time::Duration;

    fn cfg(p: usize, s: usize, tau: u64) -> EngineConfig {
        EngineConfig {
            p,
            group_size: s,
            tau,
            dynamic_groups: true,
            sync_algo: AllreduceAlgo::RecursiveDoubling,
            activation: ActivationMode::Solo,
            chunk_elems: 0,
            compression: Compression::None,
            trace: true,
            recv_deadline_ns: 0,
            recv_retries: 0,
        }
    }

    /// Chunked (bucketed) exchanges produce the exact same group sums as
    /// the flat path — the engine-level contract of the fusion scheduler.
    #[test]
    fn chunked_group_allreduce_matches_flat() {
        use std::sync::{Arc, Barrier};
        let p = 8;
        let s = 4;
        let dim = 10;
        let chunked = EngineConfig { chunk_elems: 3, ..cfg(p, s, 0) };
        let barrier = Arc::new(Barrier::new(p));
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| {
                let r = ep.rank() as f32;
                CollectiveEngine::spawn(ep, chunked, vec![r; dim])
            })
            .collect();
        let grouping = Grouping::new(p, s);
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                let grouping = grouping;
                let barrier = barrier.clone();
                thread::spawn(move || {
                    for t in 0..4u64 {
                        let w: Vec<f32> =
                            (0..dim).map(|j| eng.rank() as f32 + (j + t as usize) as f32).collect();
                        eng.publish(&w, t);
                        barrier.wait();
                        let res = eng.group_allreduce(t);
                        let members = grouping.group_of(eng.rank(), t);
                        let want: Vec<f32> = (0..dim)
                            .map(|j| {
                                members
                                    .iter()
                                    .map(|&m| m as f32 + (j + t as usize) as f32)
                                    .sum()
                            })
                            .collect();
                        assert_eq!(res.sum, want, "rank {} t {}", eng.rank(), t);
                        barrier.wait();
                    }
                    eng.shutdown()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn effective_chunk_caps_chunk_count() {
        let mut c = cfg(4, 2, 0);
        assert_eq!(c.effective_chunk(100), 0, "chunking disabled by default");
        c.chunk_elems = 8;
        assert_eq!(c.effective_chunk(4), 0, "small payloads stay unchunked");
        assert_eq!(c.effective_chunk(100), 8);
        // Pathologically small chunks get raised so the count fits the
        // tag range.
        c.chunk_elems = 1;
        let n = MAX_CHUNKS * 3;
        assert!(n.div_ceil(c.effective_chunk(n)) <= MAX_CHUNKS);
    }

    /// All ranks publish before any requests (barrier-enforced): every
    /// contribution carries stamp t, so group sums are exact — whether a
    /// rank participated actively or passively.
    #[test]
    fn group_allreduce_fresh_sums() {
        use std::sync::{Arc, Barrier};
        let p = 8;
        let s = 4;
        let barrier = Arc::new(Barrier::new(p));
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| {
                let r = ep.rank() as f32;
                CollectiveEngine::spawn(ep, cfg(p, s, 0), vec![r, 2.0 * r])
            })
            .collect();
        let grouping = Grouping::new(p, s);
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                let grouping = grouping;
                let barrier = barrier.clone();
                thread::spawn(move || {
                    for t in 0..5u64 {
                        let r = eng.rank() as f32;
                        let w = vec![r + t as f32, 2.0 * r + t as f32];
                        eng.publish_owned(w, t);
                        // Everyone has published W'_t: even passive
                        // contributions are now stamp-t fresh.
                        barrier.wait();
                        let res = eng.group_allreduce(t);
                        let members = grouping.group_of(eng.rank(), t);
                        let want: Vec<f32> = vec![
                            members.iter().map(|&m| m as f32 + t as f32).sum(),
                            members.iter().map(|&m| 2.0 * m as f32 + t as f32).sum(),
                        ];
                        assert_eq!(res.sum, want, "rank {} t {}", eng.rank(), t);
                        // Wait for everyone to consume before the next
                        // publish overwrites the send slots.
                        barrier.wait();
                    }
                    eng.shutdown()
                })
            })
            .collect();
        let stats: Vec<EngineStats> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(stats.iter().map(|s| s.group_collectives).sum::<u64>(), 5 * p as u64);
        // publish_owned + refcount sends: the engines memcpy'd nothing.
        for st in &stats {
            assert_eq!(st.copied_bytes, 0, "{st:?}");
        }
    }

    /// A deliberately slow rank must not block the fast ranks: the fast
    /// ranks' collectives complete with the slow rank's stale buffer.
    #[test]
    fn straggler_does_not_block() {
        let p = 4;
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| {
                let r = ep.rank() as f32;
                CollectiveEngine::spawn(ep, cfg(p, 2, 0), vec![r])
            })
            .collect();
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                thread::spawn(move || {
                    let mut stale_seen = 0u64;
                    for t in 0..6u64 {
                        if eng.rank() == 1 {
                            // Rank 1 is the straggler (paper Fig. 3).
                            thread::sleep(Duration::from_millis(30));
                        }
                        eng.publish(&[eng.rank() as f32 + 100.0 * t as f32], t);
                        let res = eng.group_allreduce(t);
                        if !res.is_fresh(t) {
                            stale_seen += 1;
                        }
                    }
                    (eng.rank(), stale_seen, eng.shutdown())
                })
            })
            .collect();
        let mut results: Vec<(usize, u64, EngineStats)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        results.sort_by_key(|r| r.0);
        // The straggler must have been passively executed at least once.
        let passive_total: u64 = results.iter().map(|r| r.2.passive_executions).sum();
        assert!(passive_total > 0, "expected some passive executions");
        // Everyone completed all 6 collectives.
        for (_, _, st) in &results {
            assert_eq!(st.group_collectives, 6);
        }
    }

    /// τ-periodic global sync returns the exact global sum on every rank.
    #[test]
    fn tau_sync_global_sum() {
        let p = 4;
        let tau = 3; // iterations 2, 5, ... are sync points
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| CollectiveEngine::spawn(ep, cfg(p, 2, tau), vec![0.0]))
            .collect();
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                thread::spawn(move || {
                    let mut w = vec![eng.rank() as f32];
                    for t in 0..7u64 {
                        eng.publish(&w, t);
                        if eng.config().is_sync_iter(t) {
                            let sum = eng.global_sync(t);
                            w = sum.iter().map(|x| x / p as f32).collect();
                        } else {
                            let res = eng.group_allreduce(t);
                            if res.is_fresh(t) {
                                w = res.sum.iter().map(|x| x / 2.0).collect();
                            } else {
                                let mut v = res.sum.clone();
                                add_assign(&mut v, &w);
                                w = v.iter().map(|x| x / 3.0).collect();
                            }
                        }
                    }
                    (w, eng.shutdown())
                })
            })
            .collect();
        let outs: Vec<(Vec<f32>, EngineStats)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // After the final sync at t=5 and subsequent group averaging the
        // models stay finite and close; after any sync they are identical.
        for (_, st) in &outs {
            assert_eq!(st.global_syncs, 2); // t = 2 and t = 5
        }
        // Conservation check after first sync: average preserved = mean of
        // ranks = 1.5 (model averaging preserves the global mean when all
        // contributions are fresh; with no stragglers here they are).
        for (w, _) in &outs {
            assert!(w[0].is_finite());
        }
    }

    /// Engine executes versions in order even when activations arrive
    /// out of order (a fast rank can run ahead within the τ window).
    #[test]
    fn version_ordering_under_skew() {
        let p = 4;
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| {
                let r = ep.rank() as f32;
                CollectiveEngine::spawn(ep, cfg(p, 2, 0), vec![r])
            })
            .collect();
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                thread::spawn(move || {
                    for t in 0..10u64 {
                        if eng.rank() == 3 && t < 5 {
                            thread::sleep(Duration::from_millis(5));
                        }
                        eng.publish(&[t as f32], t);
                        let _ = eng.group_allreduce(t);
                    }
                    eng.shutdown()
                })
            })
            .collect();
        for h in handles {
            let st = h.join().unwrap();
            assert_eq!(st.group_collectives, 10);
        }
    }

    /// The staleness accessors: `staleness_samples` drains (cheap swap),
    /// `staleness_stats` aggregates without locking the sample log.
    #[test]
    fn staleness_accessors() {
        use std::sync::{Arc, Barrier};
        let p = 2;
        let steps = 4u64;
        let barrier = Arc::new(Barrier::new(p));
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| CollectiveEngine::spawn(ep, cfg(p, 2, 0), vec![0.0]))
            .collect();
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                let barrier = barrier.clone();
                thread::spawn(move || {
                    for t in 0..steps {
                        eng.publish(&[1.0], t);
                        barrier.wait();
                        let _ = eng.group_allreduce(t);
                        barrier.wait();
                    }
                    let stats = eng.staleness_stats();
                    assert_eq!(stats.count, steps);
                    // Barriered publishes: every contribution was fresh.
                    assert_eq!(stats.total, 0);
                    assert_eq!(stats.max, 0);
                    assert_eq!(stats.mean(), 0.0);
                    let drained = eng.staleness_samples();
                    assert_eq!(drained.len(), steps as usize);
                    assert!(eng.staleness_samples().is_empty(), "drain must reset");
                    // Aggregates survive the drain.
                    assert_eq!(eng.staleness_stats().count, steps);
                    eng.shutdown()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    /// Regression: the sample log and the running histogram live under
    /// ONE lock, so a concurrent drain can never observe a sample whose
    /// histogram entry has not landed yet (with the old two-mutex scheme
    /// a `staleness_samples` swap could slip between the push and the
    /// record, leaving `stats.count` behind the drained total).
    #[test]
    fn staleness_stats_consistent_under_concurrent_drain() {
        use std::sync::{Arc, Barrier};
        let p = 2;
        let steps = 200u64;
        let barrier = Arc::new(Barrier::new(p));
        let engines: Vec<Arc<CollectiveEngine>> = world(p)
            .into_iter()
            .map(|ep| Arc::new(CollectiveEngine::spawn(ep, cfg(p, 2, 0), vec![0.0])))
            .collect();
        let probe = engines[0].clone();
        let prober = thread::spawn(move || {
            let mut drained_total = 0u64;
            loop {
                drained_total += probe.staleness_samples().len() as u64;
                let stats = probe.staleness_stats();
                assert!(
                    stats.count >= drained_total,
                    "histogram count {} behind drained samples {drained_total}",
                    stats.count
                );
                if stats.count >= steps {
                    break drained_total;
                }
                thread::yield_now();
            }
        });
        let workers: Vec<_> = engines
            .iter()
            .map(|eng| {
                let eng = eng.clone();
                let barrier = barrier.clone();
                thread::spawn(move || {
                    for t in 0..steps {
                        eng.publish(&[1.0], t);
                        barrier.wait();
                        let _ = eng.group_allreduce(t);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        let drained_total = prober.join().unwrap();
        let rest = engines[0].staleness_samples().len() as u64;
        assert_eq!(drained_total + rest, steps, "every sample drained exactly once");
        assert_eq!(engines[0].staleness_stats().count, steps);
        // Engines shut down via Drop (Arc-held: `shutdown` needs ownership).
    }
}

#[cfg(test)]
mod majority_tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::comm::world;
    use std::thread;
    use std::time::Duration;

    /// Majority activation (§VI): the collective fires only after ⌈P/2⌉
    /// ranks arrive, and the whole loop still completes with a straggler.
    #[test]
    fn majority_quorum_collectives_complete() {
        let p = 4;
        let cfg = EngineConfig {
            p,
            group_size: 4,
            tau: 0,
            dynamic_groups: true,
            sync_algo: AllreduceAlgo::Auto,
            activation: ActivationMode::Majority,
            chunk_elems: 0,
            compression: Compression::None,
            trace: true,
            recv_deadline_ns: 0,
            recv_retries: 0,
        };
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| {
                let r = ep.rank() as f32;
                CollectiveEngine::spawn(ep, cfg, vec![r])
            })
            .collect();
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                thread::spawn(move || {
                    let mut fresh = 0u64;
                    for t in 0..8u64 {
                        if eng.rank() == 3 {
                            thread::sleep(Duration::from_millis(6));
                        }
                        eng.publish(&[eng.rank() as f32], t);
                        let res = eng.group_allreduce(t);
                        if res.is_fresh(t) {
                            fresh += 1;
                            assert_eq!(res.sum, vec![6.0], "t={t}");
                        }
                    }
                    (eng.rank(), fresh, eng.shutdown())
                })
            })
            .collect();
        let outs: Vec<(usize, u64, EngineStats)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // All 8 collectives ran on every rank.
        for (_, _, st) in &outs {
            assert_eq!(st.group_collectives, 8);
        }
        // Quorum means at least 2 ranks are fresh for every version;
        // the fast ranks (0..3) should be fresh nearly always.
        let total_fresh: u64 = outs.iter().map(|o| o.1).sum();
        assert!(total_fresh >= 8 * 2, "fresh contributions {total_fresh}");
    }

    /// In Majority mode the activation is leader-driven: exactly one
    /// activation broadcast per version (no duplicate storms).
    #[test]
    fn majority_single_activator_per_version() {
        let p = 8;
        let cfg = EngineConfig {
            p,
            group_size: 8,
            tau: 0,
            dynamic_groups: true,
            sync_algo: AllreduceAlgo::Auto,
            activation: ActivationMode::Majority,
            chunk_elems: 0,
            compression: Compression::None,
            trace: true,
            recv_deadline_ns: 0,
            recv_retries: 0,
        };
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| CollectiveEngine::spawn(ep, cfg, vec![0.0]))
            .collect();
        let steps = 6u64;
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                thread::spawn(move || {
                    for t in 0..steps {
                        eng.publish(&[1.0], t);
                        let _ = eng.group_allreduce(t);
                    }
                    eng.shutdown()
                })
            })
            .collect();
        let stats: Vec<EngineStats> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let activations: u64 = stats.iter().map(|s| s.activations_sent).sum();
        assert_eq!(activations, steps, "one leader activation per version");
    }
}

#[cfg(test)]
mod compression_tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::comm::world;
    use std::sync::{Arc, Barrier};
    use std::thread;

    /// Barriered run: every rank publishes stamp-t data before any rank
    /// requests the collective, so group sums are deterministic. Returns
    /// per-rank (sums over steps, engine stats).
    fn run_world(
        cfg: EngineConfig,
        dim: usize,
        steps: u64,
    ) -> Vec<(Vec<Vec<f32>>, EngineStats)> {
        let p = cfg.p;
        let barrier = Arc::new(Barrier::new(p));
        let engines: Vec<CollectiveEngine> = world(p)
            .into_iter()
            .map(|ep| CollectiveEngine::spawn(ep, cfg, vec![0.0; dim]))
            .collect();
        let handles: Vec<_> = engines
            .into_iter()
            .map(|eng| {
                let barrier = barrier.clone();
                thread::spawn(move || {
                    let rank = eng.rank();
                    let mut outs = Vec::new();
                    for t in 0..steps {
                        let w: Vec<f32> = (0..dim)
                            .map(|j| {
                                ((rank * 31 + j * 7 + t as usize * 13) % 23) as f32 * 0.37 - 3.7
                            })
                            .collect();
                        eng.publish_owned(w, t);
                        barrier.wait();
                        if eng.config().is_sync_iter(t) {
                            outs.push(eng.global_sync(t));
                        } else {
                            outs.push(eng.group_allreduce(t).sum);
                        }
                        barrier.wait();
                    }
                    let st = eng.shutdown();
                    (rank, outs, st)
                })
            })
            .collect();
        let mut res: Vec<(usize, Vec<Vec<f32>>, EngineStats)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        res.sort_by_key(|r| r.0);
        res.into_iter().map(|(_, o, s)| (o, s)).collect()
    }

    fn cfg(p: usize, s: usize, tau: u64, chunk: usize, comp: Compression) -> EngineConfig {
        EngineConfig {
            p,
            group_size: s,
            tau,
            dynamic_groups: true,
            sync_algo: AllreduceAlgo::Auto,
            activation: ActivationMode::Solo,
            chunk_elems: chunk,
            compression: comp,
            trace: true,
            recv_deadline_ns: 0,
            recv_retries: 0,
        }
    }

    /// Top-k at ratio 1.0 keeps every value bit-exactly and adds in the
    /// same order as the dense reduce: compressed exchanges (chunked and
    /// unchunked) are bitwise-identical to the uncompressed engine.
    #[test]
    fn ratio_one_topk_bitwise_matches_uncompressed() {
        for chunk in [0usize, 5] {
            let plain = run_world(cfg(4, 2, 3, chunk, Compression::None), 17, 6);
            let topk =
                run_world(cfg(4, 2, 3, chunk, Compression::TopK { ratio: 1.0 }), 17, 6);
            for (rank, ((a, _), (b, _))) in plain.iter().zip(&topk).enumerate() {
                for (t, (va, vb)) in a.iter().zip(b).enumerate() {
                    for (x, y) in va.iter().zip(vb) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "rank {rank} t {t} chunk {chunk}"
                        );
                    }
                }
            }
        }
    }

    /// `compression = "none"` IS the pre-compression engine: the group
    /// sums equal the exactly-computed expected contributions (the guard
    /// the acceptance criterion asks for, pinned against an independent
    /// computation rather than a second engine run).
    #[test]
    fn none_matches_expected_group_sums_exactly() {
        let p = 4;
        let s = 2;
        let dim = 9;
        let steps = 4u64;
        let grouping = Grouping::new(p, s);
        let out = run_world(cfg(p, s, 0, 0, Compression::None), dim, steps);
        for t in 0..steps {
            for rank in 0..p {
                let members = grouping.group_of(rank, t);
                let want: Vec<f32> = (0..dim)
                    .map(|j| {
                        members
                            .iter()
                            .map(|&m| {
                                ((m * 31 + j * 7 + t as usize * 13) % 23) as f32 * 0.37 - 3.7
                            })
                            .sum()
                    })
                    .collect();
                assert_eq!(out[rank].0[t as usize], want, "rank {rank} t {t}");
            }
        }
    }

    /// Bytes-on-wire acceptance at the engine level: top-k ratio 0.1 cuts
    /// `sent_bytes` by at least 4x on a group-collective schedule, and the
    /// collectives still complete everywhere.
    #[test]
    fn topk_tenth_cuts_wire_bytes_4x() {
        let dim = 4096;
        let steps = 6u64;
        let plain = run_world(cfg(4, 2, 0, 0, Compression::None), dim, steps);
        let topk =
            run_world(cfg(4, 2, 0, 0, Compression::TopK { ratio: 0.1 }), dim, steps);
        let bytes = |runs: &[(Vec<Vec<f32>>, EngineStats)]| -> u64 {
            runs.iter().map(|(_, st)| st.sent_bytes).sum()
        };
        let (raw, wire) = (bytes(&plain), bytes(&topk));
        assert!(
            raw as f64 / wire as f64 >= 4.0,
            "wire reduction {raw} -> {wire} below 4x"
        );
        for (_, st) in &topk {
            assert_eq!(st.group_collectives, steps);
        }
    }

    /// The compressed τ-sync leaves every rank with the *identical* model
    /// (one encoding per segment, decoded by everyone — owner included).
    #[test]
    fn compressed_sync_is_rank_identical() {
        let dim = RING_THRESHOLD; // big enough for the ring path, P > 2
        let tau = 2u64;
        let steps = 4u64;
        for comp in [Compression::QuantizeQ8, Compression::TopK { ratio: 0.1 }] {
            let out = run_world(cfg(4, 2, tau, 0, comp), dim, steps);
            for t in (0..steps).filter(|&t| (t + 1) % tau == 0) {
                let first = &out[0].0[t as usize];
                for (rank, (sums, _)) in out.iter().enumerate().skip(1) {
                    for (x, y) in sums[t as usize].iter().zip(first) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "rank {rank} diverged at sync t={t} ({comp:?})"
                        );
                    }
                }
            }
            for (_, st) in &out {
                assert_eq!(st.global_syncs, 2);
            }
        }
    }

    /// Compressed exchanges draw encode buffers from the pool: allocations
    /// stabilize after warmup instead of growing per phase.
    #[test]
    fn compressed_pool_allocs_stabilize() {
        let out = run_world(
            cfg(4, 2, 0, 0, Compression::TopK { ratio: 0.25 }),
            512,
            12,
        );
        let out_long = run_world(
            cfg(4, 2, 0, 0, Compression::TopK { ratio: 0.25 }),
            512,
            24,
        );
        let allocs = |runs: &[(Vec<Vec<f32>>, EngineStats)]| -> u64 {
            runs.iter().map(|(_, st)| st.pool_allocs).sum()
        };
        // Twice the steps must not mean twice the allocations: the pool
        // absorbs the steady state (some warmup slack allowed).
        assert!(
            allocs(&out_long) < allocs(&out) * 2,
            "allocs grew with steps: {} -> {}",
            allocs(&out),
            allocs(&out_long)
        );
    }
}
