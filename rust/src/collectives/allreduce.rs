//! Blocking synchronous allreduce implementations (the "standard allreduce"
//! the paper compares against and falls back to every τ iterations).
//!
//! Two algorithms:
//! * **Recursive doubling** — `log2(P)` phases, each sending the full
//!   vector: latency-optimal, the classic choice for small/medium payloads.
//! * **Ring (reduce-scatter + allgather)** — `2(P-1)` phases sending
//!   `N/P` each: bandwidth-optimal for large models (Baidu-style), added in
//!   the performance pass as the default for vectors above a threshold.

use crate::comm::{Endpoint, Tag};
use crate::topology::log2_exact;
use crate::util::add_assign;

/// Which allreduce algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllreduceAlgo {
    RecursiveDoubling,
    Ring,
    /// Recursive doubling below `RING_THRESHOLD` elements, ring above.
    Auto,
}

/// Payload size (elements) above which `Auto` switches to the ring
/// algorithm. Tuned in the performance pass (EXPERIMENTS.md §Perf): over
/// in-memory channels the α term is tiny, so ring's bandwidth optimality
/// wins from a few KiB up (measured 1.7–2.1× over recursive doubling at
/// 16k–64k elements, P=4–8); recursive doubling is kept only for
/// latency-bound tiny payloads.
pub const RING_THRESHOLD: usize = 2048;

/// In-place global sum over all ranks using `algo`. Blocking: every rank
/// must call with the same `version`. Vector contents are replaced by the
/// elementwise sum across ranks.
pub fn allreduce(ep: &mut Endpoint, buf: &mut Vec<f32>, version: u64, algo: AllreduceAlgo) {
    match algo {
        AllreduceAlgo::RecursiveDoubling => allreduce_sum(ep, buf, version),
        AllreduceAlgo::Ring => allreduce_sum_ring(ep, buf, version),
        AllreduceAlgo::Auto => {
            if buf.len() >= RING_THRESHOLD && ep.p() > 2 {
                allreduce_sum_ring(ep, buf, version)
            } else {
                allreduce_sum(ep, buf, version)
            }
        }
    }
}

/// Recursive-doubling allreduce (sum), in place. `P` must be a power of two.
pub fn allreduce_sum(ep: &mut Endpoint, buf: &mut Vec<f32>, version: u64) {
    let p = ep.p();
    if p == 1 {
        return;
    }
    let log_p = log2_exact(p);
    let rank = ep.rank();
    for k in 0..log_p {
        let partner = rank ^ (1usize << k);
        let rhs = ep.sendrecv(partner, Tag::sync(version, k), buf.clone());
        add_assign(buf, &rhs);
    }
}

/// Ring allreduce (sum), in place: reduce-scatter then allgather.
/// Sends `2(P-1)` messages of `~N/P` elements each — bandwidth optimal.
/// Works for any `P >= 2` (power of two not required).
pub fn allreduce_sum_ring(ep: &mut Endpoint, buf: &mut Vec<f32>, version: u64) {
    let p = ep.p();
    if p == 1 {
        return;
    }
    let rank = ep.rank();
    let n = buf.len();
    let next = (rank + 1) % p;
    let prev = (rank + p - 1) % p;
    // Chunk boundaries: chunk c covers [off(c), off(c+1)).
    let off = |c: usize| -> usize { (n * c) / p };

    // Reduce-scatter: after step s, rank owns the full sum of chunk
    // (rank + 1) mod p ... converging so that rank ends owning chunk
    // (rank + 1) mod p. Standard ring schedule.
    for s in 0..p - 1 {
        let send_c = (rank + p - s) % p;
        let recv_c = (rank + p - s - 1) % p;
        let chunk = buf[off(send_c)..off(send_c + 1)].to_vec();
        ep.send(next, Tag::sync(version, s as u32), chunk);
        let rhs = ep.recv_data(prev, Tag::sync(version, s as u32), |_, m| {
            panic!("unexpected control message in ring allreduce: {m:?}")
        });
        add_assign(&mut buf[off(recv_c)..off(recv_c + 1)], &rhs);
    }
    // Allgather: circulate the reduced chunks.
    for s in 0..p - 1 {
        let send_c = (rank + 1 + p - s) % p;
        let recv_c = (rank + p - s) % p;
        let chunk = buf[off(send_c)..off(send_c + 1)].to_vec();
        ep.send(next, Tag::sync(version, (p - 1 + s) as u32), chunk);
        let rhs = ep.recv_data(prev, Tag::sync(version, (p - 1 + s) as u32), |_, m| {
            panic!("unexpected control message in ring allreduce: {m:?}")
        });
        buf[off(recv_c)..off(recv_c + 1)].copy_from_slice(&rhs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::world;
    use std::thread;

    fn run_allreduce(p: usize, n: usize, algo: AllreduceAlgo) -> Vec<Vec<f32>> {
        let eps = world(p);
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                thread::spawn(move || {
                    // Rank r contributes [r, r+1, ...].
                    let mut buf: Vec<f32> = (0..n).map(|i| (rank + i) as f32).collect();
                    allreduce(&mut ep, &mut buf, 0, algo);
                    assert_eq!(ep.unmatched_len(), 0);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn expected(p: usize, n: usize) -> Vec<f32> {
        // sum_r (r + i) = p*i + p(p-1)/2
        (0..n).map(|i| (p * i + p * (p - 1) / 2) as f32).collect()
    }

    #[test]
    fn recursive_doubling_sums() {
        for p in [1usize, 2, 4, 8, 16] {
            let out = run_allreduce(p, 13, AllreduceAlgo::RecursiveDoubling);
            let want = expected(p, 13);
            for buf in out {
                assert_eq!(buf, want, "P={p}");
            }
        }
    }

    #[test]
    fn ring_sums_power_of_two() {
        for p in [2usize, 4, 8] {
            let out = run_allreduce(p, 64, AllreduceAlgo::Ring);
            let want = expected(p, 64);
            for buf in out {
                assert_eq!(buf, want, "P={p}");
            }
        }
    }

    #[test]
    fn ring_sums_non_power_of_two_and_ragged() {
        // Ring works for any P and for N not divisible by P.
        for (p, n) in [(3usize, 10usize), (5, 7), (6, 1), (7, 97)] {
            let out = run_allreduce(p, n, AllreduceAlgo::Ring);
            let want = expected(p, n);
            for buf in out {
                assert_eq!(buf, want, "P={p} N={n}");
            }
        }
    }

    #[test]
    fn auto_matches_both() {
        let small = run_allreduce(4, 16, AllreduceAlgo::Auto);
        assert_eq!(small[0], expected(4, 16));
        let big = run_allreduce(4, RING_THRESHOLD + 3, AllreduceAlgo::Auto);
        assert_eq!(big[2], expected(4, RING_THRESHOLD + 3));
    }

    #[test]
    fn distinct_versions_do_not_collide() {
        // Two consecutive allreduces with different versions on the same
        // endpoints must not cross-match.
        let eps = world(4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let mut a = vec![ep.rank() as f32];
                    allreduce_sum(&mut ep, &mut a, 1);
                    let mut b = vec![(ep.rank() * 10) as f32];
                    allreduce_sum(&mut ep, &mut b, 2);
                    (a, b)
                })
            })
            .collect();
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert_eq!(a, vec![6.0]);
            assert_eq!(b, vec![60.0]);
        }
    }
}
