//! Blocking synchronous allreduce implementations (the "standard allreduce"
//! the paper compares against and falls back to every τ iterations).
//!
//! Two algorithms:
//! * **Recursive doubling** — `log2(P)` phases, each sending the full
//!   vector: latency-optimal, the classic choice for small/medium payloads.
//! * **Ring (reduce-scatter + allgather)** — `2(P-1)` phases sending
//!   `N/P` each: bandwidth-optimal for large models (Baidu-style), added in
//!   the performance pass as the default for vectors above a threshold.
//!
//! Both are zero-copy on the send side: payloads travel as refcounted
//! [`Chunk`] views of a shared buffer. Recursive doubling circulates the
//! accumulator as an `Arc` (reducing in place once the partner has dropped
//! its reference); the ring keeps the vector as `P` segment views, reduces
//! into fresh segments, and forwards received segments by reference during
//! the allgather — the classic implementation's per-step `to_vec()` chunk
//! copies are gone entirely.

use std::sync::Arc;

use crate::compress::{Compression, EncodeScratch};
use crate::comm::{shared, BufferPool, Chunk, Endpoint, SharedBuf, Tag};
use crate::topology::log2_exact;
use crate::util::{add_assign, sum_into};

/// Which allreduce algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllreduceAlgo {
    RecursiveDoubling,
    Ring,
    /// Recursive doubling below `RING_THRESHOLD` elements, ring above.
    Auto,
}

/// Payload size (elements) above which `Auto` switches to the ring
/// algorithm. Tuned in the performance pass (EXPERIMENTS.md §Perf): over
/// in-memory channels the α term is tiny, so ring's bandwidth optimality
/// wins from a few KiB up (measured 1.7–2.1× over recursive doubling at
/// 16k–64k elements, P=4–8); recursive doubling is kept only for
/// latency-bound tiny payloads.
pub const RING_THRESHOLD: usize = 2048;

/// One step of the ring schedule. `gather = false` is the reduce-scatter
/// pass, `gather = true` the allgather; the two passes share this index
/// map (the allgather simply walks the same orbit shifted by one chunk)
/// and differ only in how the received segment is combined.
///
/// Returns `(send_chunk, recv_chunk, phase_tag)` for step `s ∈ 0..p-1`.
pub fn ring_step(rank: usize, p: usize, s: usize, gather: bool) -> (usize, usize, u32) {
    let shift = usize::from(gather);
    let send_c = (rank + shift + p - s) % p;
    let recv_c = (rank + shift + p - s - 1) % p;
    let phase = if gather { (p - 1 + s) as u32 } else { s as u32 };
    (send_c, recv_c, phase)
}

/// In-place global sum over all ranks using `algo`. Blocking: every rank
/// must call with the same `version`. Vector contents are replaced by the
/// elementwise sum across ranks.
pub fn allreduce(ep: &mut Endpoint, buf: &mut Vec<f32>, version: u64, algo: AllreduceAlgo) {
    match algo {
        AllreduceAlgo::RecursiveDoubling => allreduce_sum(ep, buf, version),
        AllreduceAlgo::Ring => allreduce_sum_ring(ep, buf, version),
        AllreduceAlgo::Auto => {
            if buf.len() >= RING_THRESHOLD && ep.p() > 2 {
                allreduce_sum_ring(ep, buf, version)
            } else {
                allreduce_sum(ep, buf, version)
            }
        }
    }
}

fn recv_plain(ep: &mut Endpoint, src: usize, tag: Tag) -> Chunk {
    ep.recv_data(src, tag, |_, m| {
        panic!("unexpected control message in direct-mode allreduce: {m:?}")
    })
}

/// Combine an accumulator with a received contribution: in place when the
/// partner has already released our buffer (`Arc::try_unwrap` proves sole
/// ownership), else one fused `sum_into` pass into a pooled buffer. Both
/// branches compute `lhs[i] + rhs[i]` in the same operand order, so the
/// result is bitwise independent of which path timing selects. Either way
/// the returned `Arc` is unique. Shared by the direct-mode recursive
/// doubling and the engine's butterfly phases.
pub(crate) fn reduce_shared(pool: &BufferPool, lhs: SharedBuf, rhs: &[f32]) -> SharedBuf {
    match Arc::try_unwrap(lhs) {
        Ok(mut own) => {
            add_assign(own.data_mut(), rhs);
            Arc::new(own)
        }
        Err(held) => {
            let mut out = pool.take(held.len());
            sum_into(out.data_mut(), held.as_slice(), rhs);
            Arc::new(out)
        }
    }
}

/// The compressed counterpart of [`reduce_shared`]: combine an accumulator
/// with a received **encoded** contribution via the fused decompress-sum.
/// In place when the accumulator is uniquely owned; otherwise one pooled
/// materialization (`out = lhs` then `out += decode(encoded)` — the
/// sparse/quantized analogue of `sum_into`'s dense read-combine-write, so
/// it is reduction work, not a counted copy). Either way the returned
/// `Arc` is unique.
pub(crate) fn decode_sum_shared(
    pool: &BufferPool,
    comp: Compression,
    lhs: SharedBuf,
    encoded: &[f32],
) -> SharedBuf {
    match Arc::try_unwrap(lhs) {
        Ok(mut own) => {
            comp.decode_add(encoded, own.data_mut());
            Arc::new(own)
        }
        Err(held) => {
            let mut out = pool.take(held.len());
            out.data_mut().copy_from_slice(held.as_slice());
            comp.decode_add(encoded, out.data_mut());
            Arc::new(out)
        }
    }
}

/// Extract a final accumulator as a plain vector for the caller. After at
/// least one [`reduce_shared`] the `Arc` is provably unique, so this is a
/// move; degenerate schedules (zero phases) fall back to one counted copy.
pub(crate) fn shared_into_vec(acc: SharedBuf, copied_bytes: &mut u64) -> Vec<f32> {
    match Arc::try_unwrap(acc) {
        Ok(own) => own.into_data(),
        Err(held) => {
            *copied_bytes += (held.len() * 4) as u64;
            held.as_slice().to_vec()
        }
    }
}

/// Recursive-doubling allreduce (sum), in place. `P` must be a power of two.
pub fn allreduce_sum(ep: &mut Endpoint, buf: &mut Vec<f32>, version: u64) {
    let p = ep.p();
    if p == 1 {
        return;
    }
    let log_p = log2_exact(p);
    let rank = ep.rank();
    let pool = ep.pool().clone();
    let mut acc: SharedBuf = shared(std::mem::take(buf));
    for k in 0..log_p {
        let partner = rank ^ (1usize << k);
        ep.send_chunk(partner, Tag::sync(version, k), Chunk::full(acc.clone()));
        let rhs = recv_plain(ep, partner, Tag::sync(version, k));
        acc = reduce_shared(&pool, acc, rhs.as_slice());
    }
    *buf = shared_into_vec(acc, &mut ep.copied_bytes);
}

/// The segmented zero-copy ring allreduce core, shared by the direct-mode
/// [`allreduce_sum_ring`] and the engine's ctrl-aware τ-sync (which only
/// differ in how they receive). Segments start as range views of the
/// local contribution; the reduce-scatter replaces reduced segments with
/// freshly-summed pooled ones and the allgather adopts received segments
/// by reference (pure refcount forwarding). The final reassembly into one
/// contiguous vector is the path's single counted copy.
pub(crate) fn ring_allreduce_segments(
    ep: &mut Endpoint,
    version: u64,
    contrib: SharedBuf,
    recv: impl FnMut(&mut Endpoint, usize, Tag) -> Chunk,
) -> Vec<f32> {
    let members: Vec<usize> = (0..ep.p()).collect();
    ring_allreduce_segments_over(ep, version, contrib, &members, recv)
}

/// [`ring_allreduce_segments`] generalized over an explicit (sorted)
/// participant list — the elastic-membership τ-sync re-segments the model
/// over the *survivors* instead of all `P` ranks. The schedule is the
/// ordinary ring on the participants' *indices* (ring position = index in
/// `members`, neighbours = adjacent members), so with `members == 0..P`
/// this is byte-for-byte the classic full ring. The caller must appear in
/// `members` and all members must drive the same list (deterministic:
/// survivor sets come from the shared [`crate::fault::FaultPlan`] oracle).
pub(crate) fn ring_allreduce_segments_over(
    ep: &mut Endpoint,
    version: u64,
    contrib: SharedBuf,
    members: &[usize],
    mut recv: impl FnMut(&mut Endpoint, usize, Tag) -> Chunk,
) -> Vec<f32> {
    let k = members.len();
    let idx = members
        .iter()
        .position(|&m| m == ep.rank())
        .expect("ring caller must be in the member list");
    debug_assert!(k >= 2, "degenerate rings are the caller's fast path");
    let n = contrib.len();
    let next = members[(idx + 1) % k];
    let prev = members[(idx + k - 1) % k];
    // Chunk boundaries: segment c covers [off(c), off(c+1)).
    let off = |c: usize| -> usize { (n * c) / k };
    let pool = ep.pool().clone();

    let mut segs: Vec<Chunk> =
        (0..k).map(|c| Chunk::range(contrib.clone(), off(c), off(c + 1))).collect();
    for gather in [false, true] {
        for s in 0..k - 1 {
            let (send_c, recv_c, phase) = ring_step(idx, k, s, gather);
            ep.send_chunk(next, Tag::sync(version, phase), segs[send_c].clone());
            let rhs = recv(ep, prev, Tag::sync(version, phase));
            debug_assert_eq!(rhs.len(), segs[recv_c].len());
            if gather {
                segs[recv_c] = rhs;
            } else {
                let mut out = pool.take(segs[recv_c].len());
                sum_into(out.data_mut(), segs[recv_c].as_slice(), rhs.as_slice());
                segs[recv_c] = Chunk::full(std::sync::Arc::new(out));
            }
        }
    }

    // Reassemble the full vector (the one unavoidable copy of this path).
    let mut out = pool.take(n);
    for (c, seg) in segs.iter().enumerate() {
        out.data_mut()[off(c)..off(c + 1)].copy_from_slice(seg.as_slice());
    }
    ep.copied_bytes += (n * 4) as u64;
    out.into_data()
}

/// Compressed segmented ring allreduce: the [`ring_allreduce_segments`]
/// schedule with every segment encoded before it travels.
///
/// * **Reduce-scatter**: each step sends `encode(segs[send_c])` and folds
///   the received encoding into the local segment with the fused
///   decompress-sum, so the segment owner ends with
///   `own_exact + Σ decode(encode(partial))`.
/// * **Allgather**: the owner broadcasts `encode(final_segment)` once and
///   **adopts its own decode** — every rank, owner included, ends with the
///   decode of the same encoding, so the synced model is *identical on all
///   ranks* (the property WAGMA's every-τ synchronization exists to
///   restore; lossy but rank-agreeing). Forwarders pass the received
///   encoding along by reference — no re-encode, no divergence.
///
/// Per-element loss is bounded by the codec (exact for kept top-k entries,
/// `scale/2` for q8) and applied once per segment, not once per hop.
pub(crate) fn ring_allreduce_segments_compressed(
    ep: &mut Endpoint,
    version: u64,
    contrib: SharedBuf,
    comp: Compression,
    scratch: &mut EncodeScratch,
    recv: impl FnMut(&mut Endpoint, usize, Tag) -> Chunk,
) -> Vec<f32> {
    let members: Vec<usize> = (0..ep.p()).collect();
    ring_allreduce_segments_compressed_over(ep, version, contrib, comp, scratch, &members, recv)
}

/// [`ring_allreduce_segments_compressed`] over an explicit participant
/// list — see [`ring_allreduce_segments_over`] for the membership
/// contract.
#[allow(clippy::too_many_arguments)]
pub(crate) fn ring_allreduce_segments_compressed_over(
    ep: &mut Endpoint,
    version: u64,
    contrib: SharedBuf,
    comp: Compression,
    scratch: &mut EncodeScratch,
    members: &[usize],
    mut recv: impl FnMut(&mut Endpoint, usize, Tag) -> Chunk,
) -> Vec<f32> {
    debug_assert!(!comp.is_none(), "use ring_allreduce_segments for the exact path");
    let k = members.len();
    let idx = members
        .iter()
        .position(|&m| m == ep.rank())
        .expect("ring caller must be in the member list");
    debug_assert!(k >= 2, "degenerate rings are the caller's fast path");
    let n = contrib.len();
    let next = members[(idx + 1) % k];
    let prev = members[(idx + k - 1) % k];
    let off = |c: usize| -> usize { (n * c) / k };
    let pool = ep.pool().clone();

    let mut segs: Vec<Chunk> =
        (0..k).map(|c| Chunk::range(contrib.clone(), off(c), off(c + 1))).collect();

    // Reduce-scatter: encoded partial sums travel; the local segment folds
    // each arrival in via the fused decompress-sum.
    for s in 0..k - 1 {
        let (send_c, recv_c, phase) = ring_step(idx, k, s, false);
        let mut enc = pool.take(comp.encoded_words(segs[send_c].len()));
        comp.encode(segs[send_c].as_slice(), enc.data_mut(), scratch);
        ep.send_chunk(next, Tag::sync(version, phase), Chunk::full(Arc::new(enc)));
        let rhs = recv(ep, prev, Tag::sync(version, phase));
        let mut out = pool.take(segs[recv_c].len());
        out.data_mut().copy_from_slice(segs[recv_c].as_slice());
        comp.decode_add(rhs.as_slice(), out.data_mut());
        segs[recv_c] = Chunk::full(Arc::new(out));
    }

    // Allgather: the owner encodes its finished segment once (and adopts
    // the decode so it agrees with everyone else bitwise); every other rank
    // forwards the received encoding untouched and stores its decode.
    let mut fwd: Option<Chunk> = None;
    for s in 0..k - 1 {
        let (send_c, recv_c, phase) = ring_step(idx, k, s, true);
        let enc_send = match fwd.take() {
            Some(c) => c,
            None => {
                // First gather step: send_c is the segment this rank owns
                // in full after the reduce-scatter.
                let mut enc = pool.take(comp.encoded_words(segs[send_c].len()));
                comp.encode(segs[send_c].as_slice(), enc.data_mut(), scratch);
                let enc = Chunk::full(Arc::new(enc));
                let mut own = pool.take(segs[send_c].len());
                comp.decode_overwrite(enc.as_slice(), own.data_mut());
                segs[send_c] = Chunk::full(Arc::new(own));
                enc
            }
        };
        ep.send_chunk(next, Tag::sync(version, phase), enc_send);
        let rhs = recv(ep, prev, Tag::sync(version, phase));
        let mut dec = pool.take(segs[recv_c].len());
        comp.decode_overwrite(rhs.as_slice(), dec.data_mut());
        segs[recv_c] = Chunk::full(Arc::new(dec));
        fwd = Some(rhs);
    }

    // Reassemble (same single counted copy as the exact ring).
    let mut out = pool.take(n);
    for (c, seg) in segs.iter().enumerate() {
        out.data_mut()[off(c)..off(c + 1)].copy_from_slice(seg.as_slice());
    }
    ep.copied_bytes += (n * 4) as u64;
    out.into_data()
}

/// Ring allreduce (sum), in place: reduce-scatter then allgather.
/// Sends `2(P-1)` messages of `~N/P` elements each — bandwidth optimal.
/// Works for any `P >= 2` (power of two not required).
pub fn allreduce_sum_ring(ep: &mut Endpoint, buf: &mut Vec<f32>, version: u64) {
    let p = ep.p();
    if p == 1 {
        return;
    }
    let contrib: SharedBuf = shared(std::mem::take(buf));
    *buf = ring_allreduce_segments(ep, version, contrib, recv_plain);
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]

    use super::*;
    use crate::comm::world;
    use std::thread;

    fn run_allreduce(p: usize, n: usize, algo: AllreduceAlgo) -> Vec<Vec<f32>> {
        let eps = world(p);
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                thread::spawn(move || {
                    // Rank r contributes [r, r+1, ...].
                    let mut buf: Vec<f32> = (0..n).map(|i| (rank + i) as f32).collect();
                    allreduce(&mut ep, &mut buf, 0, algo);
                    assert_eq!(ep.unmatched_len(), 0);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn expected(p: usize, n: usize) -> Vec<f32> {
        // sum_r (r + i) = p*i + p(p-1)/2
        (0..n).map(|i| (p * i + p * (p - 1) / 2) as f32).collect()
    }

    #[test]
    fn recursive_doubling_sums() {
        for p in [1usize, 2, 4, 8, 16] {
            let out = run_allreduce(p, 13, AllreduceAlgo::RecursiveDoubling);
            let want = expected(p, 13);
            for buf in out {
                assert_eq!(buf, want, "P={p}");
            }
        }
    }

    #[test]
    fn ring_sums_power_of_two() {
        for p in [2usize, 4, 8] {
            let out = run_allreduce(p, 64, AllreduceAlgo::Ring);
            let want = expected(p, 64);
            for buf in out {
                assert_eq!(buf, want, "P={p}");
            }
        }
    }

    #[test]
    fn ring_sums_non_power_of_two_and_ragged() {
        // Ring works for any P and for N not divisible by P.
        for (p, n) in [(3usize, 10usize), (5, 7), (6, 1), (7, 97)] {
            let out = run_allreduce(p, n, AllreduceAlgo::Ring);
            let want = expected(p, n);
            for buf in out {
                assert_eq!(buf, want, "P={p} N={n}");
            }
        }
    }

    #[test]
    fn auto_matches_both() {
        let small = run_allreduce(4, 16, AllreduceAlgo::Auto);
        assert_eq!(small[0], expected(4, 16));
        let big = run_allreduce(4, RING_THRESHOLD + 3, AllreduceAlgo::Auto);
        assert_eq!(big[2], expected(4, RING_THRESHOLD + 3));
    }

    #[test]
    fn distinct_versions_do_not_collide() {
        // Two consecutive allreduces with different versions on the same
        // endpoints must not cross-match.
        let eps = world(4);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let mut a = vec![ep.rank() as f32];
                    allreduce_sum(&mut ep, &mut a, 1);
                    let mut b = vec![(ep.rank() * 10) as f32];
                    allreduce_sum(&mut ep, &mut b, 2);
                    (a, b)
                })
            })
            .collect();
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert_eq!(a, vec![6.0]);
            assert_eq!(b, vec![60.0]);
        }
    }

    fn run_ring_compressed(p: usize, n: usize, comp: Compression) -> Vec<Vec<f32>> {
        let eps = world(p);
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                thread::spawn(move || {
                    let buf: Vec<f32> = (0..n).map(|i| (rank + i) as f32).collect();
                    let contrib = shared(buf);
                    let mut scratch = EncodeScratch::default();
                    let out = ring_allreduce_segments_compressed(
                        &mut ep, 0, contrib, comp, &mut scratch, recv_plain,
                    );
                    assert_eq!(ep.unmatched_len(), 0);
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Compressed ring at top-k ratio 1.0 degenerates to the exact sum —
    /// bitwise identical to the uncompressed ring on every rank.
    #[test]
    fn compressed_ring_ratio_one_is_bitwise_exact() {
        for (p, n) in [(4usize, 64usize), (3, 10), (6, 97)] {
            let out = run_ring_compressed(p, n, Compression::TopK { ratio: 1.0 });
            let want = expected(p, n);
            for buf in out {
                for (a, b) in buf.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "P={p} N={n}");
                }
            }
        }
    }

    /// Lossy compressed ring: every rank ends with the *identical* vector
    /// (the allgather distributes one encoding that all ranks — owner
    /// included — decode), and q8's loss stays within the per-hop bound.
    #[test]
    fn compressed_ring_is_rank_identical_and_bounded() {
        let (p, n) = (4usize, 64usize);
        let out = run_ring_compressed(p, n, Compression::QuantizeQ8);
        for buf in &out[1..] {
            for (a, b) in buf.iter().zip(&out[0]) {
                assert_eq!(a.to_bits(), b.to_bits(), "ranks disagree after compressed sync");
            }
        }
        // Loss bound: p-1 reduce-scatter decodes + 1 allgather decode, each
        // within scale/2 of its input; values here are O(p·n) so the summed
        // result must still be close to the exact sum.
        let want = expected(p, n);
        let max_val = want.iter().cloned().fold(0.0f32, f32::max);
        let scale_bound = (p as f32) * (max_val / 127.0);
        for (a, b) in out[0].iter().zip(&want) {
            assert!((a - b).abs() <= scale_bound, "{a} vs {b} (bound {scale_bound})");
        }
    }

    /// Survivor ring: the member-parameterized core over a strict subset
    /// of the world sums exactly over the participants, and every
    /// participant ends with the identical (bitwise) vector — the
    /// elastic τ-sync's contract after a rank death.
    #[test]
    fn ring_over_survivors_sums_and_agrees() {
        let p = 4;
        let n = 37;
        let members = vec![0usize, 2, 3]; // rank 1 is "dead"
        let eps = world(p);
        let handles: Vec<_> = eps
            .into_iter()
            .enumerate()
            .map(|(rank, mut ep)| {
                let members = members.clone();
                thread::spawn(move || {
                    if !members.contains(&rank) {
                        return None; // the dead rank sends nothing
                    }
                    let buf: Vec<f32> = (0..n).map(|i| (rank + i) as f32).collect();
                    let out = ring_allreduce_segments_over(
                        &mut ep,
                        0,
                        shared(buf),
                        &members,
                        recv_plain,
                    );
                    assert_eq!(ep.unmatched_len(), 0);
                    Some(out)
                })
            })
            .collect();
        let outs: Vec<Vec<f32>> =
            handles.into_iter().filter_map(|h| h.join().unwrap()).collect();
        assert_eq!(outs.len(), members.len());
        // sum over members of (m + i)
        let want: Vec<f32> = (0..n)
            .map(|i| members.iter().map(|&m| (m + i) as f32).sum())
            .collect();
        for out in &outs {
            assert_eq!(out.len(), n);
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "survivors must agree bitwise");
            }
        }
    }

    /// The unified ring schedule: both passes send the segment that was
    /// combined in the previous step, every segment is reduced exactly
    /// once, and the allgather visits every segment.
    #[test]
    fn ring_step_schedule_invariants() {
        for p in [2usize, 3, 5, 8] {
            for rank in 0..p {
                let mut reduced = vec![false; p];
                let mut prev_recv = None;
                for s in 0..p - 1 {
                    let (send_c, recv_c, phase) = ring_step(rank, p, s, false);
                    assert_eq!(phase, s as u32);
                    assert_ne!(send_c, recv_c);
                    if let Some(pr) = prev_recv {
                        // We forward what we just reduced.
                        assert_eq!(send_c, pr, "P={p} rank={rank} s={s}");
                    }
                    assert!(!reduced[recv_c], "segment reduced twice");
                    reduced[recv_c] = true;
                    prev_recv = Some(recv_c);
                }
                // Every segment except our own was a reduce target; the
                // last one reduced is (rank + 1) mod p — the segment this
                // rank ends up owning in full.
                assert!(!reduced[rank]);
                assert_eq!(reduced.iter().filter(|&&b| b).count(), p - 1);
                assert_eq!(prev_recv, Some((rank + 1) % p));
                let mut gathered = vec![false; p];
                for s in 0..p - 1 {
                    let (send_c, recv_c, phase) = ring_step(rank, p, s, true);
                    assert_eq!(phase, (p - 1 + s) as u32);
                    assert!(!gathered[recv_c]);
                    gathered[recv_c] = true;
                    // The first gather send is the segment we own in full.
                    if s == 0 {
                        assert_eq!(send_c, (rank + 1) % p);
                    }
                }
                assert_eq!(gathered.iter().filter(|&&b| b).count(), p - 1);
            }
        }
    }
}
