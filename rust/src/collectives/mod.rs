//! Collective operations.
//!
//! * [`allreduce`] — blocking, synchronous allreduces (recursive doubling
//!   and bandwidth-optimal ring), used by the synchronous baselines and by
//!   WAGMA's every-τ global synchronization.
//! * [`engine`] — the paper's contribution: the **wait-avoiding group
//!   allreduce** (§III), realized as a per-rank communication engine that
//!   can participate in collectives *passively* on behalf of a busy
//!   application thread, triggered by activation messages traveling down
//!   binomial trees.
//!
//! See `README.md` in this directory for the architecture, the
//! compressed data path, and the failure model / degraded paths.

// Hot-path panics are lint debt: every `unwrap` on the engine thread is
// a potential abort that faults can now actually trigger.
#![warn(clippy::unwrap_used)]

pub mod allreduce;
pub mod engine;

pub use allreduce::{allreduce_sum, allreduce_sum_ring, ring_step, AllreduceAlgo};
pub use engine::{CollectiveEngine, EngineConfig, EngineStats, GroupResult, StalenessStats};
